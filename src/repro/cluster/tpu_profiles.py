"""Serving profiles for the model zoo: the ESG <-> TPU bridge.

The paper reads function latencies from measured profile tables; here each
architecture becomes a servable function whose latency over the
(batch, vcpu, vtpu-chips) lattice comes from the v5e roofline model —
calibrated against the dry-run's compiled cost analysis when the cell JSONs
exist (useful-FLOPs overhead factor), analytic otherwise.

A "job" = one inference request: prefill(prompt_len) + gen_len decode steps.
vTPU semantics per DESIGN §2: g chips serve the task as a pjit sub-mesh —
batch data-parallel + per-inference tensor-parallel, with an ICI efficiency
penalty that grows with g.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from repro.configs.registry import ModelConfig, get_config, ARCH_IDS
from repro.core.profiles import FunctionProfile, ProfileTable
from repro.launch.roofline import PEAK_FLOPS, HBM_BW, ICI_BW

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    prompt_len: int = 512
    gen_len: int = 64
    cpu_ms_per_job: float = 3.0        # tokenize/detokenize host work
    cold_ms: float = 8000.0            # weights load + compile cache hit
    input_mb: float = 0.05             # request payload


class TPUFunctionProfile(FunctionProfile):
    """FunctionProfile whose exec_ms comes from the roofline model."""

    def __init__(self, cfg: ModelConfig, spec: ServingSpec = ServingSpec(),
                 overhead: float | None = None):
        self._cfg = cfg
        self._spec = spec
        self._overhead = overhead if overhead is not None \
            else _calibrated_overhead(cfg.name)
        t1 = self._exec_ms_raw(1, 1, 1)
        super().__init__(name=cfg.name, t1_ms=t1, cold_ms=spec.cold_ms,
                         input_mb=spec.input_mb, cpu_frac=0.0,
                         model_mb=2.0 * cfg.n_params / 1e6)  # bf16 weights

    # latency model --------------------------------------------------------
    def _decode_ms(self, batch: int, chips: int) -> float:
        n = self._cfg.n_active_params
        w_bytes = 2.0 * self._cfg.n_params          # bf16 weights read
        kv_bytes = 2.0 * 2 * self._cfg.n_layers * self._cfg.n_kv_heads * \
            self._cfg.d_head * self._spec.prompt_len * batch
        t_mem = (w_bytes + kv_bytes) / (chips * HBM_BW)
        t_flop = 2.0 * n * batch / (chips * PEAK_FLOPS)
        ici = 1.0 + 0.08 * np.log2(max(chips, 1))   # collective penalty
        return max(t_mem, t_flop) * ici * self._overhead * 1e3

    def _prefill_ms(self, batch: int, chips: int) -> float:
        n = self._cfg.n_active_params
        toks = batch * self._spec.prompt_len
        t_flop = 2.0 * n * toks / (chips * PEAK_FLOPS)
        t_mem = 2.0 * self._cfg.n_params / (chips * HBM_BW)
        ici = 1.0 + 0.08 * np.log2(max(chips, 1))
        return max(t_flop, t_mem) * ici * self._overhead * 1e3

    def _exec_ms_raw(self, batch: int, vcpu: int, chips: int) -> float:
        t = self._prefill_ms(batch, chips) + \
            self._spec.gen_len * self._decode_ms(batch, chips)
        t_cpu = self._spec.cpu_ms_per_job * batch / (vcpu ** 0.7)
        return t + t_cpu

    def exec_ms(self, c, quota_vgpu=None) -> float:  # Config(batch,vcpu,vgpu)
        # fractional quota throttles the TPU part only — host tokenize/
        # detokenize work is unaffected by the accelerator share
        t_tpu = self._prefill_ms(c.batch, c.vgpu) + \
            self._spec.gen_len * self._decode_ms(c.batch, c.vgpu)
        t_cpu = self._spec.cpu_ms_per_job * c.batch / (c.vcpu ** 0.7)
        return t_tpu * self.quota_factor(c, quota_vgpu) + t_cpu


def _calibrated_overhead(arch: str) -> float:
    """Compiled-FLOPs / model-FLOPs from the decode dry-run cell — how much
    wider the real compiled graph is than the 2ND ideal."""
    f = DRYRUN_DIR / f"{arch}__decode_32k__single.json"
    try:
        d = json.loads(f.read_text())
        r = d["roofline"]
        useful = r.get("useful_ratio", 1.0)
        if useful and 0.02 < useful <= 1.0:
            return float(np.clip(1.0 / useful, 1.0, 4.0))
    except Exception:
        pass
    return 1.3


def zoo_tables(archs: list[str] | None = None,
               spec: ServingSpec = ServingSpec(),
               max_chips: int = 8) -> dict[str, ProfileTable]:
    out = {}
    for a in archs or ARCH_IDS:
        fp = TPUFunctionProfile(get_config(a), spec)
        out[a] = ProfileTable.build(fp, vgpus=tuple(range(1, max_chips + 1)))
    return out
