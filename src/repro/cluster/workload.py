"""Workload generation (paper §4.1).

Job arrival intervals drawn uniformly from the paper's Azure-trace-derived
ranges: heavy [10, 16.8]ms, normal [20, 33.6]ms, light [40, 67.2]ms; each
arrival randomly picks one of the four applications.  SLO settings: strict
0.8 x L, moderate 1.0 x L, relaxed 1.2 x L, where L is the app's end-to-end
minimum-configuration latency.  The paper pairs them as strict-light,
moderate-normal and relaxed-heavy.
"""
from __future__ import annotations

import numpy as np

from repro.core.profiles import Config, FunctionProfile
from repro.core.workflows import Workflow

INTERVALS_MS = {
    "heavy": (10.0, 16.8),
    "normal": (20.0, 33.6),
    "light": (40.0, 67.2),
}
SLO_MULT = {"strict": 0.8, "moderate": 1.0, "relaxed": 1.2}
SETTINGS = {
    "strict-light": ("strict", "light"),
    "moderate-normal": ("moderate", "normal"),
    "relaxed-heavy": ("relaxed", "heavy"),
}


def critical_path(app: Workflow, stage_time) -> float:
    """Longest root->sink path with per-stage times from ``stage_time``."""
    memo: dict[str, float] = {}

    def longest(stage: str) -> float:
        if stage in memo:
            return memo[stage]
        t = stage_time(stage)
        succ = app.edges.get(stage, ())
        memo[stage] = t + (max(longest(s) for s in succ) if succ else 0.0)
        return memo[stage]

    return max(longest(r) for r in app.roots)


def min_config_latency(app: Workflow,
                       profiles: dict[str, FunctionProfile]) -> float:
    """L — end-to-end time alone at the minimum configuration (1,1,1)."""
    c = Config(1, 1, 1)
    return critical_path(app, lambda s: profiles[app.func_of[s]].exec_ms(c))


def generate(sim, setting: str, n_arrivals: int,
             profiles: dict[str, FunctionProfile],
             seed: int = 0):
    """Feed ``n_arrivals`` application invocations into the simulator."""
    slo_name, load_name = SETTINGS[setting]
    lo, hi = INTERVALS_MS[load_name]
    mult = SLO_MULT[slo_name]
    rng = np.random.default_rng(seed)
    app_names = list(sim.apps)
    slos = {a: mult * min_config_latency(sim.apps[a], profiles)
            for a in app_names}
    t = 0.0
    for uid in range(n_arrivals):
        t += rng.uniform(lo, hi)
        app = app_names[rng.integers(len(app_names))]
        sim.add_arrival(app, t, slos[app], uid)
    return slos
