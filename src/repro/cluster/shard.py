"""Sharded replay engine: partition the app population and invoker
fleet across worker processes, each running its own ``ClusterSim``, and
merge the results exactly.

The model is *topology partitioning*, the way a physical cluster is
split into cells: a shard owns a disjoint subset of the apps (stable
``crc32(app) % n_shards`` assignment) and a disjoint slice of the
invoker fleet, and placement inside a shard goes through the same
stable ``home_invoker`` hash over the shard's own fleet — shard-local
by construction, no cross-shard coordination ever needed.  That buys
three exact properties, each digest-verified in
``tests/test_sharded_replay.py`` / ``benchmarks/replay_bench.py``:

  * ``n_shards=1`` is **bit-identical** to the legacy single-process
    emulator on every scenario (the streaming retention, pooled
    allocations and lazy arrival feed change no arithmetic);
  * for a fixed shard count, the merged result is **independent of the
    worker count** — running the shards in N processes or sequentially
    in one yields the same per-shard digests and merged telemetry
    (workers are pure mechanism);
  * the merge is **exact**, not approximate: counters/costs/busy-time
    add, ``LatencyHistogram.merge`` folds bucket counts, shed scoring
    adds because a shed's scoring neighbours are same-app completions
    and an app lives in exactly one shard.

Different shard counts are different (all valid) cluster topologies —
the bench reports SLO attainment and $/1k next to wall-clock so the
fidelity of a partitioning is a number, not an assumption.

Day-scale machinery: each shard streams the *global* arrival sequence
(lazily — ``Scenario.iter_arrivals`` / a presorted on-disk trace) and
keeps only its own apps' slice, so no process ever materializes the
trace; sims run ``retain="stream"`` (Task/Job free-list pooling, O(1)
retained state) with telemetry fed online through the retire/complete
hooks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing
import time
import zlib
from typing import Any, Optional

from repro.cluster.emulator import ClusterSim
from repro.cluster.workload import min_config_latency
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS, Workflow


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ReplayConfig:
    """One replay experiment, picklable so worker processes can be
    handed the whole spec.  ``n_apps=None`` serves the paper's four
    apps verbatim (the legacy-comparison arm); an integer clones the
    paper pipelines into a population of that size."""
    scenario: str = "azure-tail"
    scenario_kw: dict = dataclasses.field(default_factory=dict)
    n: int = 10_000                  # total arrivals across all shards
    n_apps: Optional[int] = None
    n_invokers: int = 16
    vcpus: int = 16
    vgpus: int = 8
    seed: int = 0
    slo_mult: float = 1.0
    noise_sigma: float = 0.05
    retain: str = "stream"
    track_digest: bool = True
    stream_arrivals: bool = True
    shed_doomed: bool = True
    backlog_aware: bool = True
    device_checks: bool = False      # ledger re-verification off on the hot path
    sparse: bool = True
    fast_planner: bool = True
    record: bool = False             # per-shard flight recorder (full mode)


@dataclasses.dataclass
class ShardResult:
    """What one shard sends back to the merger (picklable)."""
    shard: int
    n_shards: int
    summary: dict
    telemetry: Any                   # repro.serving.telemetry.Telemetry
    digest: Optional[str]
    wall_s: float
    peak_rss_mb: float
    n_apps: int
    n_invokers: int
    n_arrivals: int
    exports: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------
def shard_of(app_name: str, n_shards: int) -> int:
    """Stable shard assignment — same hash family as ``home_invoker``,
    so the partition never depends on dict order or PYTHONHASHSEED."""
    return zlib.crc32(app_name.encode()) % n_shards


def fleet_split(n_invokers: int, n_shards: int) -> list[int]:
    """Invoker counts per shard: as even as possible, remainder to the
    low shards, every shard non-empty."""
    if n_shards > n_invokers:
        raise ValueError(f"cannot split {n_invokers} invokers across "
                         f"{n_shards} shards (empty shard fleets)")
    base, rem = divmod(n_invokers, n_shards)
    return [base + (1 if i < rem else 0) for i in range(n_shards)]


def shard_seed(seed: int, shard: int, n_shards: int) -> int:
    """Per-shard noise seed.  Shard 0 of 1 *is* the global seed, so the
    single-shard path replays the legacy emulator bit-for-bit."""
    return seed if n_shards == 1 else seed + 0x9E3779B1 * (shard + 1) % (2**31)


def make_apps(n_apps: Optional[int]) -> dict[str, Workflow]:
    """The replay app population: the paper's four pipelines verbatim
    (``n_apps=None``), or ``n_apps`` clones of them round-robin —
    cloned apps share function suffixes, so the shape-keyed plan cache
    collapses the population to a handful of entries."""
    if n_apps is None:
        return dict(PAPER_APPS)
    protos = list(PAPER_APPS.values())
    out: dict[str, Workflow] = {}
    for k in range(n_apps):
        proto = protos[k % len(protos)]
        funcs = [proto.func_of[s] for s in proto.stages]
        name = f"{proto.name}~{k:04d}"
        out[name] = Workflow.pipeline(name, funcs)
    return out


def paper_tables() -> dict[str, ProfileTable]:
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _rss_mb(peak: bool = True) -> float:
    """Current (or high-watermark) RSS of this process in MB, from
    /proc/self/status — per-process, so forked shard workers report
    their own footprint."""
    field = "VmHWM" if peak else "VmRSS"
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field):
                    return float(line.split()[1]) / 1024.0
    except OSError:
        pass
    try:  # non-Linux fallback: high-watermark only
        import resource
        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    except Exception:
        return 0.0


# ---------------------------------------------------------------------------
# one shard
# ---------------------------------------------------------------------------
def run_shard(cfg: ReplayConfig, shard: int, n_shards: int,
              export_dir: Optional[str] = None) -> ShardResult:
    """Run one shard's ``ClusterSim`` over its apps' slice of the
    global arrival stream and return the mergeable result."""
    from repro.serving import Gateway, get_autoscaler, get_scenario

    apps_all = make_apps(cfg.n_apps)
    names_all = list(apps_all)
    if n_shards == 1:
        mine = apps_all
    else:
        mine = {a: w for a, w in apps_all.items()
                if shard_of(a, n_shards) == shard}
    tables = paper_tables()
    sched = ESGScheduler(mine, tables, plan_cache=cfg.fast_planner,
                         vectorized=cfg.fast_planner)
    recorder = None
    if cfg.record:
        if cfg.retain != "full":
            raise ValueError("record=True requires retain='full' "
                             "(the recorder keeps per-task spans)")
        from repro.obs import Recorder
        recorder = Recorder()
    fleet_n = fleet_split(cfg.n_invokers, n_shards)[shard]
    sim = ClusterSim(mine, tables, PAPER_FUNCTIONS, sched,
                     n_invokers=fleet_n, vcpus=cfg.vcpus, vgpus=cfg.vgpus,
                     noise_sigma=cfg.noise_sigma,
                     seed=shard_seed(cfg.seed, shard, n_shards),
                     count_overhead=False,
                     autoscaler=get_autoscaler("ewma"),
                     sparse=cfg.sparse, recorder=recorder,
                     retain=cfg.retain, track_digest=cfg.track_digest,
                     device_checks=cfg.device_checks)
    gw = Gateway(sim, shed_doomed=cfg.shed_doomed,
                 backlog_aware=cfg.backlog_aware)
    # SLOs over the *global* app set (any shard computes the same map);
    # arrivals stream over the global sequence and keep this shard's
    # apps — uid/t/remap all global, so the union over shards is
    # exactly the unsharded trace
    slos = {a: cfg.slo_mult * min_config_latency(apps_all[a],
                                                 PAPER_FUNCTIONS)
            for a in names_all}
    sc = get_scenario(cfg.scenario, app_names=names_all,
                      **dict(cfg.scenario_kw))
    src = sc.iter_arrivals(names_all, cfg.n, seed=cfg.seed + 1)
    if n_shards > 1:
        src = (arr for arr in src if arr.app in mine)
    n_arrivals = 0
    t0 = time.perf_counter()
    if cfg.stream_arrivals:
        def _feed():
            nonlocal n_arrivals
            for arr in src:
                n_arrivals += 1
                yield (arr.app, arr.t_ms, slos[arr.app], arr.uid)
        # cfg.n is an upper bound on this shard's arrival count: the
        # reserved seq block is what the *unsharded* pre-injection path
        # would have used, which is exactly what single-shard
        # bit-identity needs (unused reservations are harmless)
        sim.add_arrival_stream(_feed(), cfg.n)
    else:
        for arr in src:
            n_arrivals += 1
            sim.add_arrival(arr.app, arr.t_ms, slos[arr.app], arr.uid)
    sim.run()
    gw.telemetry.collect(sim)
    wall = time.perf_counter() - t0
    exports: dict[str, str] = {}
    if recorder is not None and export_dir is not None:
        import pathlib
        d = pathlib.Path(export_dir)
        d.mkdir(parents=True, exist_ok=True)
        exports = recorder.export(
            trace_path=str(d / f"trace_shard{shard}.json"),
            metrics_path=str(d / f"metrics_shard{shard}.json"),
            audit_path=str(d / f"audit_shard{shard}.jsonl"))
    return ShardResult(
        shard=shard, n_shards=n_shards, summary=sim.summary(),
        telemetry=gw.telemetry,
        digest=sim.run_digest() if cfg.track_digest else None,
        wall_s=wall, peak_rss_mb=_rss_mb(peak=True),
        n_apps=len(mine), n_invokers=fleet_n, n_arrivals=n_arrivals,
        exports=exports)


def _run_shard_star(args) -> ShardResult:
    return run_shard(*args)


# ---------------------------------------------------------------------------
# merge
# ---------------------------------------------------------------------------
def merge_digests(digests: list[Optional[str]]) -> Optional[str]:
    """Fleet digest: per-shard schedule digests folded in shard order.
    Worker-count independent by construction (shards are merged by
    index, not completion order)."""
    if any(d is None for d in digests):
        return None
    h = hashlib.blake2b(digest_size=16)
    for d in digests:
        h.update(d.encode())
    return h.hexdigest()


def merge_results(results: list[ShardResult]) -> dict[str, Any]:
    """Exact aggregate of a sharded run: merged telemetry summary,
    fleet digest, per-shard wall/RSS/size breakdown."""
    from repro.serving.telemetry import Telemetry

    results = sorted(results, key=lambda r: r.shard)
    tel = Telemetry()
    for r in results:
        tel.merge(r.telemetry)
    total_wall = max(r.wall_s for r in results)   # parallel wall bound
    return {
        "n_shards": results[0].n_shards,
        "completed": tel.completed,
        "shed": tel.n_shed,
        "arrivals": sum(r.n_arrivals for r in results),
        "slo_attainment": tel.slo_attainment(),
        "cost_per_1k": tel.cost_per_1k(),
        "total_cost": tel.total_cost,
        "cold_starts": tel.cold_starts,
        "utilization": tel.utilization(),
        "latency": tel.e2e.to_dict(),
        "digest": merge_digests([r.digest for r in results]),
        "wall_s_max": total_wall,
        "wall_s_sum": sum(r.wall_s for r in results),
        "per_shard": [{
            "shard": r.shard, "apps": r.n_apps, "invokers": r.n_invokers,
            "arrivals": r.n_arrivals, "completed": r.summary["completed"],
            "wall_s": r.wall_s, "peak_rss_mb": r.peak_rss_mb,
            "digest": r.digest,
        } for r in results],
    }


def merged_telemetry(results: list[ShardResult]):
    """The merged ``Telemetry`` object itself (summary() for the dict)."""
    from repro.serving.telemetry import Telemetry
    tel = Telemetry()
    for r in sorted(results, key=lambda r: r.shard):
        tel.merge(r.telemetry)
    return tel


# ---------------------------------------------------------------------------
# shard-tagged observability export concatenation
# ---------------------------------------------------------------------------
def merge_audit_jsonl(paths: list[str], out_path: str) -> int:
    """Concatenate per-shard audit JSONL exports, tagging every record
    with its shard id.  Returns the line count."""
    n = 0
    with open(out_path, "w") as out:
        for i, p in enumerate(paths):
            with open(p) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    rec = json.loads(line)
                    rec["shard"] = i
                    out.write(json.dumps(rec, sort_keys=True) + "\n")
                    n += 1
    return n


def merge_metrics_json(paths: list[str], out_path: str) -> dict[str, Any]:
    """Concatenate per-shard metrics-bus exports into one document, each
    series renamed ``shard<i>/<name>`` (windows are on simulated time,
    which is per-shard — renaming keeps them distinguishable instead of
    pretending to interleave them)."""
    merged: dict[str, Any] = {"window_ms": None, "series": {}}
    for i, p in enumerate(paths):
        with open(p) as f:
            doc = json.load(f)
        if merged["window_ms"] is None:
            merged["window_ms"] = doc.get("window_ms")
        for name, series in doc.get("series", {}).items():
            merged["series"][f"shard{i}/{name}"] = series
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
        f.write("\n")
    return merged


def merge_chrome_traces(paths: list[str], out_path: str) -> dict[str, Any]:
    """Concatenate per-shard Chrome traces; each shard's pids are offset
    into their own block so Perfetto renders shards as separate process
    groups."""
    PID_BLOCK = 10_000
    events: list[dict] = []
    unit = "ms"
    for i, p in enumerate(paths):
        with open(p) as f:
            doc = json.load(f)
        unit = doc.get("displayTimeUnit", unit)
        for e in doc.get("traceEvents", []):
            e = dict(e)
            if "pid" in e:
                e["pid"] = int(e["pid"]) + i * PID_BLOCK
            events.append(e)
    doc = {"displayTimeUnit": unit, "traceEvents": events}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
        f.write("\n")
    return doc


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
def run_sharded(cfg: ReplayConfig, n_shards: int,
                workers: Optional[int] = None,
                export_dir: Optional[str] = None) -> dict[str, Any]:
    """Run ``n_shards`` shard sims on ``workers`` processes (default:
    one per shard; 1 = sequential in-process) and merge.  The merged
    output is a pure function of (cfg, n_shards) — never of workers."""
    workers = n_shards if workers is None else workers
    jobs = [(cfg, i, n_shards, export_dir) for i in range(n_shards)]
    t0 = time.perf_counter()
    if workers <= 1 or n_shards == 1:
        results = [run_shard(*j) for j in jobs]
    else:
        ctx = multiprocessing.get_context("fork")
        with ctx.Pool(min(workers, n_shards)) as pool:
            results = pool.map(_run_shard_star, jobs)
    merged = merge_results(results)
    merged["wall_s"] = time.perf_counter() - t0
    merged["workers"] = min(workers, n_shards) if n_shards > 1 else 1
    if export_dir is not None and all(r.exports for r in results):
        results = sorted(results, key=lambda r: r.shard)
        import pathlib
        d = pathlib.Path(export_dir)
        merged["exports"] = {
            "audit": str(d / "audit_merged.jsonl"),
            "metrics": str(d / "metrics_merged.json"),
            "trace": str(d / "trace_merged.json"),
        }
        merge_audit_jsonl([r.exports["audit"] for r in results],
                          merged["exports"]["audit"])
        merge_metrics_json([r.exports["metrics"] for r in results],
                           merged["exports"]["metrics"])
        merge_chrome_traces([r.exports["trace"] for r in results],
                            merged["exports"]["trace"])
    return merged
