"""Discrete-event serverless-cluster emulator (paper §4 methodology).

Mirrors the paper's own evaluation setup: an OpenWhisk-like controller
driving emulated invokers, with
  * the (vcpu, vgpu) resource lattice per invoker (16 vCPU + 8 vTPU here —
    the TPU-host adaptation of "16 vCPUs + 1 A100 split into 7 MIGs"),
  * cold starts + 10-min keep-alive container pools,
  * pluggable warm-pool autoscaling (``repro.serving.autoscaler``; the
    default ``EwmaPrewarm`` policy is the paper-§4 EWMA pre-warming),
  * the local-vs-remote data-passing model (locality benefit),
  * Gaussian execution noise on top of the profile model,
  * measured scheduling overhead folded into simulated latency (this is
    what Fig 9 / Fig 10 measure).

Schedulers plug in via the ``SchedulerPolicy`` protocol; the event loop,
batching, dispatch bookkeeping, recheck list and accounting are shared so
comparisons isolate the scheduling algorithm (paper §4.2).  Warm-pool
policies plug in via the ``autoscaler`` argument, and an optional
``admission`` callback (see ``repro.serving.gateway``) may reject
arrivals at the door (load shedding).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time as _walltime
import zlib
from collections import defaultdict, deque
from typing import Any, Callable, Optional

import numpy as np

from repro.core.profiles import (Config, FunctionProfile, ProfileTable,
                                 VCPU_PRICE_PER_H, VGPU_PRICE_PER_H)
from repro.core.workflows import Workflow

KEEPALIVE_MS = 600_000.0          # OpenWhisk 10-minute keep-alive
LOCAL_TRANSFER_MS = 1.0
REMOTE_TRANSFER_FIXED_MS = 20.0
REMOTE_TRANSFER_MS_PER_MB = 8.0   # ~125 MB/s remote store
RECHECK_LIMIT = 3


def home_invoker(app_name: str, func: str, n_invokers: int) -> int:
    """Stable home-invoker choice for a root stage (shared with the
    autoscalers so seeded warm pools land where placement will look).
    Builtin str hash is per-process randomised, hence crc32."""
    return zlib.crc32(f"{app_name}/{func}".encode()) % n_invokers


# ---------------------------------------------------------------------------
# Jobs / instances
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AppInstance:
    app: Workflow
    uid: int
    arrival_ms: float
    slo_ms: float                     # end-to-end budget
    stage_invoker: dict = dataclasses.field(default_factory=dict)
    pending_preds: dict = dataclasses.field(default_factory=dict)
    done: bool = False
    finish_ms: float = -1.0
    plan: Any = None                  # Orion/Aquatope static plans

    @property
    def deadline_ms(self) -> float:
        return self.arrival_ms + self.slo_ms


@dataclasses.dataclass
class Job:
    inst: AppInstance
    stage: str
    ready_ms: float                   # when inputs became available


@dataclasses.dataclass
class Task:
    jobs: list[Job]
    stage: str
    func: str
    config: Config
    invoker: int
    start_ms: float
    end_ms: float
    cold: bool
    cost: float


# ---------------------------------------------------------------------------
# Invokers
# ---------------------------------------------------------------------------
class Invoker:
    def __init__(self, idx: int, vcpus: int, vgpus: int):
        self.idx = idx
        self.vcpus = vcpus
        self.vgpus = vgpus
        self.free_vcpu = vcpus
        self.free_vgpu = vgpus
        self.warm: dict[str, list[float]] = defaultdict(list)  # expiry times

    def fits(self, c: Config) -> bool:
        return self.free_vcpu >= c.vcpu and self.free_vgpu >= c.vgpu

    def alloc(self, c: Config):
        self.free_vcpu -= c.vcpu
        self.free_vgpu -= c.vgpu

    def release(self, c: Config):
        self.free_vcpu += c.vcpu
        self.free_vgpu += c.vgpu

    def take_warm(self, func: str, now: float) -> bool:
        pool = self.warm[func]
        while pool and pool[0] < now:
            pool.pop(0)               # expired keep-alive
        if pool:
            pool.pop(0)
            return True
        return False

    def add_warm(self, func: str, expiry: float):
        self.warm[func].append(expiry)
        self.warm[func].sort()

    def has_warm(self, func: str, now: float) -> bool:
        return any(e >= now for e in self.warm[func])


# ---------------------------------------------------------------------------
# Scheduler protocol
# ---------------------------------------------------------------------------
class SchedulerPolicy:
    """Interface the emulator drives.  ``plan`` returns a priority-ordered
    list of configs for the queue's *current* stage (paper: configPQ);
    ``placement`` is 'locality' (ESG/Orion/Aquatope) or 'fragmentation'
    (INFless/FaST-GShare)."""
    name = "base"
    placement = "locality"
    charged_overhead_ms = 0.0

    def plan(self, sim: "ClusterSim", app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        raise NotImplementedError

    def on_arrival(self, sim: "ClusterSim", inst: AppInstance, now: float):
        pass


# ---------------------------------------------------------------------------
# The emulator
# ---------------------------------------------------------------------------
class ClusterSim:
    def __init__(self,
                 apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 profiles: dict[str, FunctionProfile],
                 scheduler: SchedulerPolicy,
                 n_invokers: int = 16,
                 vcpus: int = 16,
                 vgpus: int = 8,
                 noise_sigma: float = 0.05,
                 seed: int = 0,
                 count_overhead: bool = True,
                 prewarm: bool = True,
                 batching: bool = True,
                 gpu_sharing: bool = True,
                 initial_warm: int = 2,
                 autoscaler: Any = None,
                 admission: Optional[Callable] = None):
        self.apps = apps
        self.tables = tables
        self.profiles = profiles
        self.sched = scheduler
        self.invokers = [Invoker(i, vcpus, vgpus) for i in range(n_invokers)]
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self.count_overhead = count_overhead
        self.batching = batching
        self.gpu_sharing = gpu_sharing

        self.now = 0.0
        self._events: list[tuple] = []
        self._seq = itertools.count()
        self.queues: dict[tuple[str, str], deque[Job]] = defaultdict(deque)
        self.recheck: dict[tuple[str, str], int] = {}
        self._blocked: set[tuple[str, str]] = set()
        # warm-pool policy: the legacy prewarm/initial_warm knobs map onto
        # the default policies; pass ``autoscaler`` to swap in another
        if autoscaler is None:
            from repro.serving.autoscaler import EwmaPrewarm, NoPrewarm
            autoscaler = (EwmaPrewarm(initial_warm=initial_warm) if prewarm
                          else NoPrewarm())
        self.autoscaler = autoscaler
        self.admission = admission    # callable(sim, inst) -> bool, or None
        self.autoscaler.seed_pools(self)

        # metrics
        self.completed: list[AppInstance] = []
        self.shed: list[AppInstance] = []
        self.total_cost = 0.0
        self.tasks: list[Task] = []
        self.sched_overheads_ms: list[float] = []
        self.cold_starts = 0
        self.remote_transfers = 0
        self.config_misses = 0        # pre-planned config infeasible (Table 4)
        self.plan_uses = 0

    # ---- events ----------------------------------------------------------
    def push_event(self, t: float, kind: str, payload: Any):
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def add_arrival(self, app_name: str, t: float, slo_ms: float, uid: int):
        inst = AppInstance(self.apps[app_name], uid, t, slo_ms)
        self.push_event(t, "arrival", inst)

    # ---- main loop -------------------------------------------------------
    def run(self):
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            if kind == "arrival":
                self._on_arrival(payload)
            elif kind == "complete":
                self._on_complete(payload)
                self._blocked.clear()        # capacity changed: retry queues
            elif kind == "prewarm":
                func, inv = payload
                self.invokers[inv].add_warm(func, self.now + KEEPALIVE_MS)
                self._blocked.clear()
            elif kind == "autoscale":
                self.autoscaler.on_tick(self, payload)
                self._blocked.clear()
            self._schedule_pass()
        return self

    # ---- handlers --------------------------------------------------------
    def _on_arrival(self, inst: AppInstance):
        if self.admission is not None and not self.admission(self, inst):
            self.shed.append(inst)       # load-shed at the door
            return
        self.sched.on_arrival(self, inst, self.now)
        for s in inst.app.stages:
            inst.pending_preds[s] = len(inst.app.predecessors(s))
        for root in inst.app.roots:
            key = (inst.app.name, root)
            self.queues[key].append(Job(inst, root, self.now))
            self._blocked.discard(key)

    def _on_complete(self, task: Task):
        inv = self.invokers[task.invoker]
        inv.release(task.config)
        inv.add_warm(task.func, self.now + KEEPALIVE_MS)
        for job in task.jobs:
            inst = job.inst
            inst.stage_invoker[task.stage] = task.invoker
            succs = inst.app.edges.get(task.stage, ())
            if not succs and not inst.done:
                inst.done = True
                inst.finish_ms = self.now
                self.completed.append(inst)
            for s in succs:
                inst.pending_preds[s] -= 1
                if inst.pending_preds[s] == 0:
                    skey = (inst.app.name, s)
                    self.queues[skey].append(Job(inst, s, self.now))
                    self._blocked.discard(skey)

    # ---- scheduling pass ---------------------------------------------------
    def _schedule_pass(self):
        keys = [k for k, q in self.queues.items()
                if q and k not in self._blocked]
        for key in keys:
            # round-robin over AFW queues, draining each (paper Fig 2d);
            # blocked queues wait for a capacity-changing event (the recheck
            # list retry is capacity-driven: within a pass capacity only
            # shrinks, so immediate retries are provably futile)
            while self.queues[key] and key not in self._blocked:
                if not self._try_queue(key):
                    break

    def _try_queue(self, key: tuple[str, str]) -> bool:
        """Dispatch from one AFW queue; returns True if a task was launched."""
        q = self.queues[key]
        if not q:
            self.recheck.pop(key, None)
            return False
        app_name, stage = key
        app = self.apps[app_name]
        jobs = list(q)

        t0 = _walltime.perf_counter()
        self.sched.charged_overhead_ms = 0.0
        candidates = self.sched.plan(self, app, stage, jobs, self.now)
        overhead_ms = (_walltime.perf_counter() - t0) * 1e3
        # schedulers may charge a (deterministic, pre-measured) overhead
        # instead of re-running an identical search per instance (Orion)
        charged = getattr(self.sched, "charged_overhead_ms", 0.0)
        if charged:
            overhead_ms = charged
        self.sched_overheads_ms.append(overhead_ms)
        # scheduling overhead delays the task being scheduled (the controller
        # runs one proxy thread per queue — paper §4); it is charged to the
        # dispatched task's start below, not serialised on the global clock.
        overhead_charge = overhead_ms if self.count_overhead else 0.0

        forced = self.recheck.get(key, 0) >= RECHECK_LIMIT
        if forced:
            # stuck in recheck: force the cheapest config (ensures progress
            # without pinning huge models to a single accelerator)
            tbl = self.tables[app.func_of[stage]]
            cheapest = tbl.configs[int(np.argmin(tbl.job_costs))]
            candidates = (candidates or []) + [cheapest, Config(1, 1, 1)]

        for cfg in candidates:
            if not self.batching:
                cfg = Config(1, cfg.vcpu, cfg.vgpu)
            if not self.gpu_sharing:
                cfg = Config(cfg.batch, cfg.vcpu, self.invokers[0].vgpus)
            miss = cfg.batch > len(jobs)
            cfg = Config(min(cfg.batch, len(jobs)), cfg.vcpu, cfg.vgpu)
            inv = self._place(app, stage, jobs[: cfg.batch], cfg)
            if inv is not None:
                if getattr(self.sched, "static_plan", False):
                    self.plan_uses += 1
                    self.config_misses += int(miss)
                self._dispatch(key, jobs[: cfg.batch], cfg, inv,
                               overhead_charge)
                self.recheck.pop(key, None)
                return True
        self.recheck[key] = self.recheck.get(key, 0) + 1
        self._blocked.add(key)
        return False

    # ---- placement ---------------------------------------------------------
    def _place(self, app: Workflow, stage: str, jobs: list[Job],
               cfg: Config) -> Optional[int]:
        func = app.func_of[stage]
        n = len(self.invokers)
        if self.sched.placement == "fragmentation":
            # best-fit: minimise leftover GPU after placement (INFless/FaST)
            best, best_left = None, None
            for inv in self.invokers:
                if inv.fits(cfg):
                    left = inv.free_vgpu - cfg.vgpu
                    if best_left is None or left < best_left:
                        best, best_left = inv.idx, left
            return best
        # locality policy (paper §3.4)
        preds = app.predecessors(stage)
        order: list[int] = []
        if not preds:
            order.append(home_invoker(app.name, func, n))
        else:
            pred_invs = [j.inst.stage_invoker.get(p)
                         for j in jobs for p in preds]
            pred_invs = [p for p in pred_invs if p is not None]
            if pred_invs:
                vals, counts = np.unique(pred_invs, return_counts=True)
                order.extend(int(v) for v in vals[np.argsort(-counts)])
        for idx in order:
            if self.invokers[idx].fits(cfg):
                return idx
        # other warm invokers
        warm = [i for i in self.invokers
                if i.has_warm(func, self.now) and i.fits(cfg)
                and i.idx not in order]
        if warm:
            return max(warm, key=lambda i: (i.free_vgpu, i.free_vcpu)).idx
        # cold invoker with most available resources
        cold = [i for i in self.invokers if i.fits(cfg)]
        if cold:
            return max(cold, key=lambda i: (i.free_vgpu, i.free_vcpu)).idx
        return None

    # ---- dispatch ----------------------------------------------------------
    def _dispatch(self, key: tuple[str, str], jobs: list[Job], cfg: Config,
                  inv_idx: int, overhead_ms: float = 0.0):
        app_name, stage = key
        app = self.apps[app_name]
        func = app.func_of[stage]
        inv = self.invokers[inv_idx]
        q = self.queues[key]
        for _ in jobs:
            q.popleft()

        # data transfer: remote if any predecessor output lives elsewhere
        transfer = 0.0
        for job in jobs:
            for p in app.predecessors(stage):
                src = job.inst.stage_invoker.get(p)
                if src is None:
                    continue
                if src == inv_idx:
                    transfer = max(transfer, LOCAL_TRANSFER_MS)
                else:
                    self.remote_transfers += 1
                    transfer = max(
                        transfer, REMOTE_TRANSFER_FIXED_MS +
                        REMOTE_TRANSFER_MS_PER_MB * self.profiles[func].input_mb)

        cold = not inv.take_warm(func, self.now)
        if cold:
            self.cold_starts += 1
        cold_ms = self.profiles[func].cold_ms if cold else 0.0

        noise = float(np.clip(
            1.0 + self.rng.normal(0.0, self.noise_sigma), 0.5, 2.0))
        exec_ms = self.profiles[func].exec_ms(cfg) * noise
        start = self.now + overhead_ms + transfer
        end = start + cold_ms + exec_ms

        inv.alloc(cfg)
        rate = cfg.vcpu * VCPU_PRICE_PER_H + cfg.vgpu * VGPU_PRICE_PER_H
        cost = rate * (cold_ms + exec_ms) / 3.6e6
        self.total_cost += cost
        task = Task(jobs, stage, func, cfg, inv_idx, start, end, cold, cost)
        self.tasks.append(task)
        self.push_event(end, "complete", task)
        # warm-pool policy hook: reactive scale-up / pre-warm scheduling /
        # scale-down all live in repro.serving.autoscaler
        self.autoscaler.on_dispatch(self, func, inv_idx, cold,
                                    cold_ms + exec_ms)

    # ---- metrics -------------------------------------------------------------
    def slo_hit_rate(self) -> float:
        if not self.completed:
            return 0.0
        hits = sum(1 for i in self.completed
                   if i.finish_ms - i.arrival_ms <= i.slo_ms)
        return hits / len(self.completed)

    def summary(self) -> dict[str, Any]:
        lat = np.array([i.finish_ms - i.arrival_ms for i in self.completed]) \
            if self.completed else np.array([0.0])
        ovh = np.array(self.sched_overheads_ms) if self.sched_overheads_ms \
            else np.array([0.0])
        return {
            "scheduler": self.sched.name,
            "autoscaler": getattr(self.autoscaler, "name", "?"),
            "completed": len(self.completed),
            "shed": len(self.shed),
            "slo_hit_rate": self.slo_hit_rate(),
            "total_cost": self.total_cost,
            "mean_latency_ms": float(lat.mean()),
            "p95_latency_ms": float(np.percentile(lat, 95)),
            "mean_sched_overhead_ms": float(ovh.mean()),
            "p95_sched_overhead_ms": float(np.percentile(ovh, 95)),
            "cold_starts": self.cold_starts,
            "remote_transfers": self.remote_transfers,
            "config_misses": self.config_misses,
            "plan_uses": self.plan_uses,
        }
