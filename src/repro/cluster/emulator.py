"""Discrete-event serverless-cluster emulator (paper §4 methodology).

Mirrors the paper's own evaluation setup: an OpenWhisk-like controller
driving emulated invokers, with
  * the (vcpu, vgpu) resource lattice per invoker (16 vCPU + 8 vTPU here —
    the TPU-host adaptation of "16 vCPUs + 1 A100 split into 7 MIGs"),
  * cold starts + 10-min keep-alive container pools,
  * pluggable warm-pool autoscaling (``repro.serving.autoscaler``; the
    default ``EwmaPrewarm`` policy is the paper-§4 EWMA pre-warming),
  * the local-vs-remote data-passing model (locality benefit),
  * Gaussian execution noise on top of the profile model,
  * measured scheduling overhead folded into simulated latency (this is
    what Fig 9 / Fig 10 measure).

Schedulers plug in via the ``SchedulerPolicy`` protocol; the event loop,
batching, dispatch bookkeeping, recheck list and accounting are shared so
comparisons isolate the scheduling algorithm (paper §4.2).  Warm-pool
policies plug in via the ``autoscaler`` argument, and an optional
``admission`` callback (see ``repro.serving.gateway``) may reject
arrivals at the door (load shedding).

The scheduling core is *event-sparse* by default (``sparse=True``):
queue retries only run when the triggering event could actually have
changed their placement feasibility or candidate configs, and placement
fallbacks walk a cached capacity-sorted invoker order.  The full-scan
reference behaviour (``sparse=False``) replays bit-identically — the
differential tests in ``tests/test_planner_fastpath.py`` pin it.

Day-scale replay additions (all bit-identical to the legacy paths, the
differential tests in ``tests/test_sharded_replay.py`` pin them):

  * the scheduling pass walks an *active ready set* (non-empty,
    non-blocked queues ordered by queue-creation index — exactly the
    dict-insertion order the full scan iterated) instead of scanning
    every queue key ever created per event;
  * ``retain="stream"`` drops the O(invocations) retention lists
    (``tasks``/``completed``/``shed``/``sched_overheads_ms``) in favour
    of streaming accumulators + log-bucketed histograms, and recycles
    ``Task``/``Job`` dataclasses through free-list pools (``gen`` keeps
    counting across reuses so stale resize/complete events can never
    match a recycled task);
  * ``add_arrival_stream`` feeds arrivals lazily from a generator while
    *reserving* their event sequence numbers up front, so the heap pops
    in exactly the order full pre-injection would have produced;
  * ``track_digest=True`` folds every retired task and completed
    request into a running blake2b digest — the cross-process,
    cross-mode schedule fingerprint the sharded replay engine
    (``repro.cluster.shard``) compares against the legacy emulator.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import math
import time as _walltime
import zlib
from collections import defaultdict, deque
from typing import Any, Callable, Optional

import numpy as np

from repro.core.profiles import (Config, FunctionProfile, ProfileTable,
                                 VCPU_PRICE_PER_H, VGPU_PRICE_PER_H)
from repro.core.workflows import Workflow
from repro.gpu import (COLD, DEFAULT_SKU, DeviceModel, GpuSKU,
                       SLICES_PER_VGPU, resolve_sku)
from repro.obs import NULL_RECORDER

KEEPALIVE_MS = 600_000.0          # OpenWhisk 10-minute keep-alive
LOCAL_TRANSFER_MS = 1.0
REMOTE_TRANSFER_FIXED_MS = 20.0
REMOTE_TRANSFER_MS_PER_MB = 8.0   # ~125 MB/s remote store
RECHECK_LIMIT = 3
# free-list caps for ``retain="stream"`` (bounds pool memory; anything
# past the cap is simply left to the garbage collector)
TASK_POOL_CAP = 4096
JOB_POOL_CAP = 65536


def home_invoker(app_name: str, func: str, n_invokers: int) -> int:
    """Stable home-invoker choice for a root stage (shared with the
    autoscalers so seeded warm pools land where placement will look).
    Builtin str hash is per-process randomised, hence crc32."""
    return zlib.crc32(f"{app_name}/{func}".encode()) % n_invokers


# ---------------------------------------------------------------------------
# Jobs / instances
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AppInstance:
    app: Workflow
    uid: int
    arrival_ms: float
    slo_ms: float                     # end-to-end budget
    stage_invoker: dict = dataclasses.field(default_factory=dict)
    pending_preds: dict = dataclasses.field(default_factory=dict)
    done: bool = False
    finish_ms: float = -1.0
    plan: Any = None                  # Orion/Aquatope static plans
    # --- preemptible-fleet bookkeeping ---
    failed: bool = False              # shed mid-flight after repeated reclaims
    # stage -> fraction of exec completed at the last kill (stages with
    # ``checkpoint_mb`` resume from here instead of re-running from start)
    ckpt_frac: dict = dataclasses.field(default_factory=dict)

    @property
    def deadline_ms(self) -> float:
        return self.arrival_ms + self.slo_ms


@dataclasses.dataclass
class Job:
    inst: AppInstance
    stage: str
    ready_ms: float                   # when inputs became available


@dataclasses.dataclass
class Task:
    jobs: list[Job]
    stage: str
    func: str
    config: Config
    invoker: int
    start_ms: float
    end_ms: float
    cold: bool
    cost: float
    # --- device-model bookkeeping (fractional vGPU + swap tiers) ---
    tid: int = -1                # index into sim.tasks
    tier: str = COLD             # warm-state tier paid at start (hot/warm/cold)
    alloc_id: int = -1           # DeviceModel allocation id while running
    quota_slices: int = 0        # current compute quota (slices)
    exec_start_ms: float = 0.0   # start + residual restart penalty
    dispatch_ms: float = 0.0     # sim time the allocation was taken
    gen: int = 0                 # resize generation (stale-event guard)
    q_since: float = 0.0         # quota unchanged since (slice-ms account)
    # --- overlapped-swap accounting ---
    penalty_ms: float = 0.0      # restart penalty actually charged
    full_penalty_ms: float = 0.0  # what the additive model would charge
    # --- preemptible-fleet accounting ---
    preempted: bool = False      # killed mid-task by a spot reclamation

    @property
    def quota_vgpu(self) -> float:
        return self.quota_slices / SLICES_PER_VGPU


# ---------------------------------------------------------------------------
# Invokers
# ---------------------------------------------------------------------------
class Invoker:
    """One emulated host: a vCPU counter plus a sliceable accelerator
    (``repro.gpu.DeviceModel``) carrying the fractional-quota lattice,
    HBM accounting and two-tier keep-alive pools.  ``footprints`` maps
    function name -> model-weight MB (0 for unknown functions)."""

    def __init__(self, idx: int, vcpus: int, vgpus: int,
                 hbm_per_vgpu_mb: Optional[float] = None,
                 footprints: Optional[dict[str, float]] = None,
                 shared_weights: bool = False,
                 overlap: bool = False,
                 sku: Optional[GpuSKU] = None,
                 device_checks: bool = True):
        self.idx = idx
        self.vcpus = vcpus
        self.vgpus = vgpus
        self.free_vcpu = vcpus
        self.footprints = footprints or {}
        self.sku = sku if sku is not None else DEFAULT_SKU
        # exec times are divided by the SKU's throughput rate; the vGPU
        # billing component scales with its $/slice-hour factor.  Both
        # are 1.0 on the default SKU (bit-identical arithmetic).
        self.exec_slowdown = 1.0 / self.sku.exec_rate
        self.price_factor = self.sku.price_factor
        # spot lifecycle: draining between reclamation warning and the
        # kill, down during the post-reclaim outage
        self.down = False
        self.draining = False
        hbm = (self.sku.hbm_per_vgpu_mb
               if self.sku.hbm_per_vgpu_mb is not None else hbm_per_vgpu_mb)
        self.device = DeviceModel(vgpus, hbm_per_vgpu_mb=hbm,
                                  shared_weights=shared_weights,
                                  overlap=overlap, sku=self.sku,
                                  validate=device_checks)
        # optional sim hook observing new keep-alive expiries (the
        # event-sparse emulator's expiry watermark)
        self.note_expiry: Optional[Callable[[float], None]] = None

    @property
    def free_vgpu(self) -> float:
        """Free accelerator share in vGPU units (fractional once running
        pools have been vertically resized)."""
        return self.device.free_slices / SLICES_PER_VGPU

    def model_mb(self, func: str) -> float:
        return self.footprints.get(func, 0.0)

    def fits(self, c: Config, func: Optional[str] = None,
             now: float = 0.0) -> bool:
        if self.down or self.draining:
            return False
        return self.free_vcpu >= c.vcpu and self.device.fits(
            c.vgpu * SLICES_PER_VGPU,
            self.model_mb(func) if func else 0.0, func, now)

    def add_warm(self, func: str, expiry: float, now: float = 0.0):
        if self.down or self.draining:
            return               # nothing survives on a doomed device
        self.device.add_warm(func, expiry, self.model_mb(func), now)
        if self.note_expiry is not None:
            self.note_expiry(expiry)

    def has_warm(self, func: str, now: float) -> bool:
        return self.device.has_warm(func, now)

    def residency(self, func: str, now: float) -> str:
        """Warm-state tier a start of ``func`` would pay here (hot/warm/cold).
        ``now`` is required: querying stale pools without a GC sweep
        would report expired containers as live."""
        return self.device.residency(func, now)

    def start_penalty_ms(self, func: str, cold_ms: Optional[float],
                         now: float) -> float:
        """Predicted restart penalty of starting ``func`` on this invoker
        at ``now`` — the memory-aware placement/planning ranking term.
        Under the overlapped swap pipeline this is the *residual*
        transfer time (an in-flight prefetch shrinks it toward zero)."""
        return self.device.swap_cost_ms(func, self.model_mb(func), now,
                                        cold_ms)

    def prefetch(self, func: str, now: float) -> bool:
        """Enqueue a background PCIe copy re-promoting ``func``'s
        demoted weights (overlap mode; see ``DeviceModel.prefetch``)."""
        return self.device.prefetch(func, self.model_mb(func), now)


# ---------------------------------------------------------------------------
# Scheduler protocol
# ---------------------------------------------------------------------------
class SchedulerPolicy:
    """Interface the emulator drives.  ``plan`` returns a priority-ordered
    list of configs for the queue's *current* stage (paper: configPQ);
    ``placement`` is 'locality' (ESG/Orion/Aquatope), 'fragmentation'
    (INFless/FaST-GShare) or 'memory' (weight-locality-aware: the paper's
    locality order still leads — data transfer dominates — but the
    fallback ranks invokers by the restart penalty their warm state
    implies: hot weights > host-staged weights > cold, Torpor-style).
    With unbounded HBM no weights are ever demoted, every fallback
    candidate's penalty class collapses to has-warm/cold and 'memory'
    reproduces 'locality' bit-for-bit (the differential tests pin this).
    """
    name = "base"
    placement = "locality"
    charged_overhead_ms = 0.0

    def plan(self, sim: "ClusterSim", app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        raise NotImplementedError

    def plan_signature(self, sim: "ClusterSim", app: Workflow, stage: str,
                       jobs: list[Job], now: float):
        """Certified identity token for the candidate list ``plan`` would
        return right now, or None when the policy cannot certify one.
        The event-sparse emulator compares tokens across events to prove
        a blocked queue's retry futile without re-planning; returning
        None (the default) simply forces the full re-plan."""
        return None

    def on_arrival(self, sim: "ClusterSim", inst: AppInstance, now: float):
        pass


# ---------------------------------------------------------------------------
# The emulator
# ---------------------------------------------------------------------------
class ClusterSim:
    def __init__(self,
                 apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 profiles: dict[str, FunctionProfile],
                 scheduler: SchedulerPolicy,
                 n_invokers: int = 16,
                 vcpus: int = 16,
                 vgpus: int = 8,
                 noise_sigma: float = 0.05,
                 seed: int = 0,
                 count_overhead: bool = True,
                 prewarm: bool = True,
                 batching: bool = True,
                 gpu_sharing: bool = True,
                 initial_warm: int = 2,
                 autoscaler: Any = None,
                 admission: Optional[Callable] = None,
                 hbm_per_vgpu_mb: Optional[float] = None,
                 shared_weights: bool = False,
                 overlap: bool = False,
                 prefetch: bool = False,
                 sparse: bool = True,
                 recorder: Any = None,
                 fleet: Optional[list] = None,
                 reclaim_storms: Optional[list[tuple]] = None,
                 max_retries: int = 4,
                 retry_backoff_ms: float = 250.0,
                 retain: str = "full",
                 track_digest: bool = False,
                 device_checks: bool = True,
                 executor: Any = None):
        if retain not in ("full", "stream"):
            raise ValueError(f"retain must be 'full' or 'stream', "
                             f"got {retain!r}")
        self.apps = apps
        self.tables = tables
        self.profiles = profiles
        self.sched = scheduler
        self.shared_weights = shared_weights
        # overlapped swap pipeline: restart penalties become completion
        # times on a per-device PCIe transfer engine; ``prefetch`` adds
        # the predicted-next-stage background copies.  Both default off:
        # legacy configurations replay bit-identically.
        if prefetch and not overlap:
            raise ValueError("prefetch=True requires overlap=True "
                             "(prefetch is a transfer-engine lever)")
        self.overlap = overlap
        self.prefetch_weights = prefetch
        # event-sparse scheduling core: prewarm events unblock only the
        # queues whose placement feasibility they could have changed
        # (same function, keep-alive expiry crossed, HBM freed by a
        # demotion overshoot), and placement fallbacks walk a cached
        # capacity-sorted invoker order instead of re-scanning the fleet.
        # ``sparse=False`` restores the full-scan reference behaviour;
        # both replay bit-identically (tests/test_planner_fastpath.py) —
        # the only observable difference is that provably-futile retry
        # attempts stop being timed into ``sched_overheads_ms``.
        self.sparse = sparse
        self.sparse_skips = 0                 # provably-futile retries skipped
        self._block_sig: dict[tuple[str, str], Any] = {}
        self._min_expiry = math.inf           # earliest live keep-alive expiry
        self._cap_order: list[int] = []
        self._cap_dirty = True
        footprints = {n: getattr(p, "model_mb", 0.0)
                      for n, p in profiles.items()}
        # heterogeneous / preemptible fleet: ``fleet`` is a list of SKU
        # names (or GpuSKU objects) assigned round-robin across the
        # invokers.  None — or any spelling that resolves to the neutral
        # DEFAULT_SKU everywhere, e.g. ["a100"] * n — keeps every code
        # path arithmetically identical to the homogeneous emulator.
        skus = ([resolve_sku(s) for s in fleet] if fleet
                else [DEFAULT_SKU])
        assigned = [skus[i % len(skus)] for i in range(n_invokers)]
        self._hetero = any(s != DEFAULT_SKU for s in assigned)
        self._has_spot = any(s.spot for s in assigned)
        self.invokers = [Invoker(i, vcpus, vgpus,
                                 hbm_per_vgpu_mb=hbm_per_vgpu_mb,
                                 footprints=footprints,
                                 shared_weights=shared_weights,
                                 overlap=overlap,
                                 sku=assigned[i],
                                 device_checks=device_checks)
                         for i in range(n_invokers)]
        for inv in self.invokers:
            inv.note_expiry = self._note_expiry
        # spot-reclamation machinery (inert without a spot SKU): seeded
        # reclaim schedule, retry policy, planner-facing fleet signature
        self.seed = seed
        self.reclaim_storms = [tuple(w) for w in (reclaim_storms or [])]
        self.max_retries = max_retries
        self.retry_backoff_ms = retry_backoff_ms
        self.prefer_on_demand = False
        self._sku_sig: Any = None
        self._reclaims_seeded = False
        self._retry_counts: dict[tuple[int, str], int] = {}
        # flight recorder (repro.obs): the default null object carries
        # only ``enabled = False`` and every hook site guards on it, so
        # the disabled path does no work and replays bit-identically
        self.recorder = NULL_RECORDER if recorder is None else recorder
        # stream retention recycles Task objects at completion; the flight
        # recorder holds per-task span state past that point, so the two
        # are mutually exclusive (record per shard in full mode instead)
        if retain == "stream" and self.recorder.enabled:
            raise ValueError("retain='stream' cannot be combined with an "
                             "enabled flight recorder (recorded runs keep "
                             "per-task spans; use retain='full')")
        if self.recorder.enabled:
            self.recorder.bind_sim(self)
        # real-compute bridge (repro.serving.executor): when set, every
        # dispatched task is additionally executed for real on-device,
        # asynchronously.  None (the default) is free and replays
        # bit-identically — the emulator's simulated clock never reads
        # the executor's wall clock.
        self.executor = executor
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self.count_overhead = count_overhead
        self.batching = batching
        self.gpu_sharing = gpu_sharing

        self.now = 0.0
        self._events: list[tuple] = []
        self._seq = itertools.count()
        self.queues: dict[tuple[str, str], deque[Job]] = defaultdict(deque)
        self.recheck: dict[tuple[str, str], int] = {}
        self._blocked: set[tuple[str, str]] = set()
        # active ready set: non-empty queue keys plus their creation
        # index — the scheduling pass iterates these in creation order,
        # which is exactly the dict-insertion order the legacy full scan
        # walked, without touching the (app x stage)-many idle keys
        self._nonempty: set[tuple[str, str]] = set()
        self._qorder: dict[tuple[str, str], int] = {}
        # lazy arrival stream (None = all arrivals pre-injected)
        self._arrival_iter = None
        self._arrival_seq = 0
        self._last_arrival_t = -math.inf
        # retention mode + streaming accumulators (kept in both modes so
        # digests and counters never depend on the mode)
        self.retain = retain
        self.n_tasks = 0
        self.n_completed = 0
        self.n_shed = 0
        self.slo_hits_n = 0
        self._lat_sum = 0.0
        self._ovh_sum = 0.0
        self._ovh_n = 0
        self._horizon_ms = 0.0
        self._task_pool: list[Task] = []
        self._job_pool: list[Job] = []
        self._lat_hist = self._ovh_hist = None
        if retain == "stream":
            from repro.serving.telemetry import LatencyHistogram
            self._lat_hist = LatencyHistogram()
            self._ovh_hist = LatencyHistogram()
        # optional streaming hooks (set by Gateway/Telemetry in stream
        # mode): a deque of (app, stage, wait_ms) queue-delay samples the
        # gateway drains instead of scanning ``sim.tasks``, plus retire/
        # completion callbacks feeding telemetry online
        self.dispatch_feed: Optional[deque] = None
        self.on_task_retire: Optional[Callable[[Task], None]] = None
        self.on_request_done: Optional[Callable[[AppInstance], None]] = None
        # streaming schedule digest (see ``run_digest``)
        self._digest = (hashlib.blake2b(digest_size=16) if track_digest
                        else None)
        # warm-pool policy: the legacy prewarm/initial_warm knobs map onto
        # the default policies; pass ``autoscaler`` to swap in another
        if autoscaler is None:
            from repro.serving.autoscaler import EwmaPrewarm, NoPrewarm
            autoscaler = (EwmaPrewarm(initial_warm=initial_warm) if prewarm
                          else NoPrewarm())
        self.autoscaler = autoscaler
        self.admission = admission    # callable(sim, inst) -> bool, or None
        # futile-retry skipping is only sound when the congestion hook has
        # no side effects: a policy overriding ``on_congestion`` (vertical
        # resizing) may free capacity, so its retries must always run
        from repro.serving.autoscaler import AutoscalerPolicy
        self._congestion_noop = (type(autoscaler).on_congestion
                                 is AutoscalerPolicy.on_congestion)
        self.autoscaler.seed_pools(self)

        # metrics
        self.completed: list[AppInstance] = []
        self.shed: list[AppInstance] = []
        self.total_cost = 0.0
        self.tasks: list[Task] = []
        self.sched_overheads_ms: list[float] = []
        self.cold_starts = 0
        self.remote_transfers = 0
        self.config_misses = 0        # pre-planned config infeasible (Table 4)
        self.plan_uses = 0
        # device-model metrics
        self.running: dict[int, Task] = {}   # tid -> in-flight task
        self.resizes: list[tuple] = []       # (t, invoker, tid, old, new)
        self.slice_busy_ms = 0.0             # integral of quota over time
        # overlapped-swap accounting: penalty actually charged to task
        # starts vs what the additive model would have charged
        self.penalty_charged_ms = 0.0
        self.penalty_full_ms = 0.0
        # preemptible-fleet accounting
        self.reclaim_warnings = 0
        self.reclaims = 0
        self.recoveries = 0
        self.preemptions = 0          # running tasks killed mid-flight
        self.retries = 0              # retry/resume re-dispatches scheduled
        self.preempt_shed = 0         # instances shed after max_retries
        self.preempt_lost_ms = 0.0    # execution time lost to kills
        self.migrations = 0           # warm containers drained-and-migrated

    # ---- events ----------------------------------------------------------
    def push_event(self, t: float, kind: str, payload: Any):
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def add_arrival(self, app_name: str, t: float, slo_ms: float, uid: int):
        inst = AppInstance(self.apps[app_name], uid, t, slo_ms)
        self.push_event(t, "arrival", inst)

    def add_arrival_stream(self, arrivals, n: int) -> None:
        """Feed ``n`` arrivals lazily from an iterator of
        ``(app_name, t_ms, slo_ms, uid)`` tuples (time-sorted).

        Exactly one pending arrival event lives in the heap at a time;
        popping it pulls the next from the iterator.  The ``n`` event
        sequence numbers the pre-injection path would have handed the
        arrivals are *reserved* up front and runtime events start after
        them, so every heap comparison — and therefore the replay — is
        bit-identical to calling ``add_arrival`` ``n`` times before
        ``run()``, without materializing ``n`` ``AppInstance`` objects
        and heap entries."""
        if self._arrival_iter is not None:
            raise ValueError("an arrival stream is already attached")
        if self._has_spot:
            raise ValueError(
                "add_arrival_stream does not support spot fleets: the "
                "reclamation schedule needs the full trace horizon "
                "(pre-inject with add_arrival instead)")
        self._arrival_iter = iter(arrivals)
        base = next(self._seq)
        self._arrival_seq = base
        self._seq = itertools.count(base + n)
        self._push_next_arrival()

    def _push_next_arrival(self) -> None:
        nxt = next(self._arrival_iter, None)
        if nxt is None:
            self._arrival_iter = None
            return
        app_name, t, slo_ms, uid = nxt
        if t < self._last_arrival_t:
            raise ValueError(
                f"arrival stream must be time-sorted: got t={t} after "
                f"t={self._last_arrival_t}")
        self._last_arrival_t = t
        inst = AppInstance(self.apps[app_name], uid, t, slo_ms)
        heapq.heappush(self._events,
                       (t, self._arrival_seq, "arrival", inst))
        self._arrival_seq += 1

    # ---- queue bookkeeping ------------------------------------------------
    def _queue_push(self, key: tuple[str, str], job: Job) -> None:
        q = self.queues[key]
        if not q:
            self._nonempty.add(key)
            if key not in self._qorder:
                self._qorder[key] = len(self._qorder)
        q.append(job)

    def _new_job(self, inst: AppInstance, stage: str,
                 ready_ms: float) -> Job:
        pool = self._job_pool
        if pool:
            job = pool.pop()
            job.inst = inst
            job.stage = stage
            job.ready_ms = ready_ms
            return job
        return Job(inst, stage, ready_ms)

    # ---- main loop -------------------------------------------------------
    def run(self):
        if self._has_spot and not self._reclaims_seeded:
            self._seed_reclaims()
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            self.now = max(self.now, t)
            if kind == "arrival":
                self._on_arrival(payload)
                if self._arrival_iter is not None:
                    self._push_next_arrival()
            elif kind == "complete":
                task, gen = payload
                if gen != task.gen:
                    continue             # stale: task was resized since
                self._on_complete(task)
                self._blocked.clear()        # capacity changed: retry queues
            elif kind == "reclaim_warning":
                self._on_reclaim_warning(payload)
            elif kind == "reclaim":
                self._on_reclaim(payload)
                self._blocked.clear()        # capacity changed either way
            elif kind == "recover":
                self._on_recover(payload)
                self._blocked.clear()
            elif kind == "retry":
                self._on_retry(payload)
            elif kind == "prewarm":
                func, inv = payload
                dev = self.invokers[inv].device
                free_before = dev.free_hbm_mb
                self.invokers[inv].add_warm(func, self.now + KEEPALIVE_MS,
                                            self.now)
                if not self.sparse or dev.free_hbm_mb > free_before:
                    # a demotion overshoot (or expiry GC on this device)
                    # freed HBM: that is a capacity release, so every
                    # blocked queue could now be placeable
                    self._blocked.clear()
                else:
                    self._prewarm_unblock(func)
            elif kind == "autoscale":
                self.autoscaler.on_tick(self, payload)
                self._blocked.clear()
            self._cap_dirty = True
            if self.recorder.enabled:
                self.recorder.on_event(self, kind)
            self._schedule_pass()
        return self

    # ---- event-sparse bookkeeping ----------------------------------------
    def _note_expiry(self, expiry: float) -> None:
        if expiry < self._min_expiry:
            self._min_expiry = expiry

    def _refresh_min_expiry(self) -> None:
        now = self.now
        self._min_expiry = min(
            (c.expiry for inv in self.invokers
             for pool in inv.device.pools.values() for c in pool
             if c.expiry >= now), default=math.inf)

    def _prewarm_unblock(self, func: str) -> None:
        """Selective unblocking after a ``prewarm`` event (sparse mode).

        A warm-container add consumes HBM and touches no vCPUs or
        compute slices, so the only queues whose placement feasibility
        can have *improved* are (a) queues of the pre-warmed function
        itself (its weights just became resident) and (b) every queue if
        a keep-alive expiry was crossed since the last full retry (lazy
        GC frees capacity as a function of time, not of events).  Every
        other blocked queue is retried only if its candidate list could
        have drifted with the clock — the scheduler's ``plan_signature``
        certificate proves the common case (wide-slack budgets) did not,
        and the retry the full-scan emulator would run is then futile:
        it is accounted (recheck counter) but not executed."""
        if self.now >= self._min_expiry:
            self._blocked.clear()
            self._refresh_min_expiry()
            return
        for key in list(self._blocked):
            q = self.queues.get(key)
            if not q:
                continue        # empty queues take no part in a pass
            app = self.apps[key[0]]
            if app.func_of[key[1]] == func:
                self._blocked.discard(key)
                continue
            rec = self._block_sig.get(key)
            if rec is not None and self._congestion_noop:
                forced = self.recheck.get(key, 0) >= RECHECK_LIMIT
                sig = self.sched.plan_signature(self, app, key[1], list(q),
                                                self.now)
                if sig is not None and rec == (sig, forced):
                    # same certified candidates, non-improving capacity:
                    # mirror the futile retry's only lasting effect
                    self.recheck[key] = self.recheck.get(key, 0) + 1
                    self.sparse_skips += 1
                    if self.recorder.enabled:
                        self.recorder.on_sparse_skip(
                            self.now, key[0], key[1], sig,
                            self.recheck[key])
                    continue
            self._blocked.discard(key)

    # ---- spot reclamation -------------------------------------------------
    def _storm_mult(self, t: float) -> float:
        """Reclamation-rate multiplier at time ``t`` (storm windows are
        ``(t0_ms, t1_ms, mult)`` tuples; outside every window it is 1)."""
        for t0, t1, mult in self.reclaim_storms:
            if t0 <= t < t1:
                return max(float(mult), 1e-9)
        return 1.0

    def _seed_reclaims(self) -> None:
        """Draw each spot invoker's reclamation schedule up front from a
        dedicated seeded stream (never ``self.rng`` — its draw order is
        bit-identity-critical for dispatch noise).  Gaps are exponential
        with the SKU's mean, shrunk by the storm multiplier in effect at
        the gap's start; each reclaim announces itself ``warn_ms`` ahead.
        The horizon is bounded by the last already-queued event plus a
        tail margin, so the event loop always drains."""
        self._reclaims_seeded = True
        horizon = max((e[0] for e in self._events), default=0.0) + 60_000.0
        for inv in self.invokers:
            sku = inv.sku
            if not sku.spot or sku.reclaim_mean_s <= 0.0:
                continue
            rng = np.random.default_rng([self.seed, 7919, inv.idx])
            t = float(self.now)
            while True:
                mean_ms = sku.reclaim_mean_s * 1000.0 / self._storm_mult(t)
                t += float(rng.exponential(mean_ms))
                if t > horizon:
                    break
                self.push_event(max(t - sku.warn_ms, self.now),
                                "reclaim_warning", inv.idx)
                self.push_event(t, "reclaim", inv.idx)

    def sku_signature(self) -> Optional[tuple]:
        """Planner-facing fleet signature, folded into plan-cache keys
        and used to price SKU speed + preemption risk into both ESG_1Q
        blades.  None on a homogeneous default fleet (tables and cache
        keys stay untouched — the bit-identical replay guarantee);
        otherwise ``(exec_factor, risk_per_ms)`` over the currently-up
        invokers, recomputed lazily after every reclaim/warning/recover.

        ``exec_factor`` is the slice-weighted mean exec-time multiplier
        (1/exec_rate); ``risk_per_ms`` approximates the fleet-level
        reclamation hazard a dispatched task faces per running ms
        (spot capacity share x mean reclaim rate)."""
        if not self._hetero:
            return None
        sig = self._sku_sig
        if sig is not None:
            return sig
        up = [inv for inv in self.invokers
              if not inv.down and not inv.draining]
        if not up:
            up = list(self.invokers)
        total = sum(inv.device.total_slices for inv in up)
        eff = sum(inv.device.total_slices * inv.sku.exec_rate for inv in up)
        exec_factor = (total / eff) if eff > 0.0 else 1.0
        risk = 0.0
        if total:
            lam = sum(inv.device.total_slices /
                      (inv.sku.reclaim_mean_s * 1000.0)
                      for inv in up
                      if inv.sku.spot and inv.sku.reclaim_mean_s > 0.0)
            risk = lam / total
        sig = (round(exec_factor, 6), round(risk, 12))
        self._sku_sig = sig
        return sig

    def _on_reclaim_warning(self, inv_idx: int) -> None:
        inv = self.invokers[inv_idx]
        if inv.down or inv.draining:
            return
        inv.draining = True
        self.reclaim_warnings += 1
        self._sku_sig = None
        self._cap_dirty = True
        if self.recorder.enabled:
            self.recorder.on_reclaim_warning(self.now, inv_idx)
        # drain-and-migrate: the warm-pool policy re-homes the doomed
        # invoker's keep-alive containers before the kill lands
        self.autoscaler.on_reclaim_warning(self, inv_idx)

    def _on_reclaim(self, inv_idx: int) -> None:
        inv = self.invokers[inv_idx]
        if inv.down:
            return                   # already inside an outage
        inv.draining = False
        inv.down = True
        self.reclaims += 1
        self._sku_sig = None
        killed = sorted((t for t in self.running.values()
                         if t.invoker == inv_idx), key=lambda t: t.tid)
        for task in killed:
            self._kill_task(task, inv)
        inv.device.reclaim()
        inv.free_vcpu = inv.vcpus
        self._refresh_min_expiry()
        self.push_event(self.now + inv.sku.recover_ms, "recover", inv_idx)
        if self.recorder.enabled:
            self.recorder.on_reclaim(self.now, inv_idx, len(killed))

    def _on_recover(self, inv_idx: int) -> None:
        inv = self.invokers[inv_idx]
        if not inv.down:
            return
        inv.down = False
        inv.draining = False
        self.recoveries += 1
        self._sku_sig = None
        if self.recorder.enabled:
            self.recorder.on_recover(self.now, inv_idx)

    def _kill_task(self, task: Task, inv: Invoker) -> None:
        """Mid-task reclamation kill: stale the pending complete event,
        release compute + HBM, refund the unexecuted billing window,
        checkpoint progress for resumable stages, then schedule a retry
        with exponential backoff (or shed after ``max_retries``)."""
        now = self.now
        task.gen += 1                    # complete event goes stale
        self.running.pop(task.tid, None)
        inv.free_vcpu += task.config.vcpu
        self.slice_busy_ms += task.quota_slices * max(
            now - task.q_since, 0.0)
        inv.device.kill(task.alloc_id)
        # refund the window that will never run, billed like resize_task
        # at the current fractional-vGPU rate (SKU price factor included)
        pivot = max(now, task.exec_start_ms)
        unrun = max(task.end_ms - pivot, 0.0)
        rate = task.config.vcpu * VCPU_PRICE_PER_H + \
            task.quota_vgpu * VGPU_PRICE_PER_H * inv.price_factor
        refund = rate * unrun / 3.6e6
        task.cost -= refund
        self.total_cost -= refund
        span = task.end_ms - task.exec_start_ms
        frac = 0.0
        if span > 0.0 and now > task.exec_start_ms:
            frac = min((now - task.exec_start_ms) / span, 1.0)
        lost = max(min(now, task.end_ms) - task.exec_start_ms, 0.0)
        self.preemptions += 1
        self.preempt_lost_ms += lost
        task.end_ms = now
        task.preempted = True
        fp = self.profiles[task.func]
        resumable = fp.checkpoint_mb > 0.0 and frac > 0.0
        if self.recorder.enabled:
            self.recorder.on_preempt(self, task, lost)
        action = "resume" if resumable else "retry"
        for job in task.jobs:
            inst = job.inst
            if inst.done or inst.failed:
                continue
            if resumable:
                inst.ckpt_frac[task.stage] = max(
                    inst.ckpt_frac.get(task.stage, 0.0), frac)
            rkey = (inst.uid, task.stage)
            attempt = self._retry_counts.get(rkey, 0) + 1
            self._retry_counts[rkey] = attempt
            if attempt > self.max_retries:
                self._shed_inflight(inst, task.stage, task.invoker,
                                    attempt, lost)
                continue
            backoff = self.retry_backoff_ms * (2.0 ** (attempt - 1))
            self.retries += 1
            self.push_event(now + backoff, "retry",
                            self._new_job(inst, task.stage, now + backoff))
            if self.recorder.enabled:
                self.recorder.on_retry_decision(
                    now, inst.app.name, task.stage, inst.uid, task.invoker,
                    attempt, action, backoff, lost)
        self._retire_task(task)

    def _shed_inflight(self, inst: AppInstance, stage: str, inv_idx: int,
                       attempt: int, lost: float) -> None:
        """Give up on an instance whose stage was reclaimed more than
        ``max_retries`` times: purge its queued jobs and count it shed
        (with an audit record), so the event loop always terminates."""
        inst.failed = True
        self.preempt_shed += 1
        for skey, q in self.queues.items():
            if skey[0] != inst.app.name or not q:
                continue
            kept = [j for j in q if j.inst is not inst]
            if len(kept) != len(q):
                q.clear()
                q.extend(kept)
                if not q:
                    self._nonempty.discard(skey)
        self._shed_inst(inst)
        if self.recorder.enabled:
            self.recorder.on_retry_decision(
                self.now, inst.app.name, stage, inst.uid, inv_idx,
                attempt, "shed", 0.0, lost)

    def _on_retry(self, job: Job) -> None:
        if job.inst.done or job.inst.failed:
            return
        key = (job.inst.app.name, job.stage)
        self._queue_push(key, job)
        self._blocked.discard(key)

    # ---- handlers --------------------------------------------------------
    def _on_arrival(self, inst: AppInstance):
        if self.admission is not None and not self.admission(self, inst):
            self._shed_inst(inst)        # load-shed at the door
            return
        if self.recorder.enabled:
            self.recorder.on_admitted(inst, self.now)
        self.sched.on_arrival(self, inst, self.now)
        for s in inst.app.stages:
            inst.pending_preds[s] = len(inst.app.predecessors(s))
        for root in inst.app.roots:
            key = (inst.app.name, root)
            self._queue_push(key, self._new_job(inst, root, self.now))
            self._blocked.discard(key)

    def _shed_inst(self, inst: AppInstance) -> None:
        self.n_shed += 1
        if self.retain == "full":
            self.shed.append(inst)
        if self._digest is not None:
            self._fold(("shed", inst.uid, repr(inst.arrival_ms)))

    def _on_complete(self, task: Task):
        inv = self.invokers[task.invoker]
        inv.free_vcpu += task.config.vcpu
        self._cap_dirty = True
        # container returns to the keep-alive pool *hot*: weights stay in
        # HBM until expiry or demotion under memory pressure
        inv.device.stop(task.alloc_id, self.now + KEEPALIVE_MS)
        self._note_expiry(self.now + KEEPALIVE_MS)
        self.slice_busy_ms += task.quota_slices * max(
            self.now - task.q_since, 0.0)
        self.running.pop(task.tid, None)
        for job in task.jobs:
            inst = job.inst
            if inst.failed:
                continue             # shed mid-flight after reclamations
            if self._has_spot:
                inst.ckpt_frac.pop(task.stage, None)
                self._retry_counts.pop((inst.uid, task.stage), None)
            inst.stage_invoker[task.stage] = task.invoker
            succs = inst.app.edges.get(task.stage, ())
            if not succs and not inst.done:
                inst.done = True
                inst.finish_ms = self.now
                self._complete_inst(inst)
            for s in succs:
                inst.pending_preds[s] -= 1
                if inst.pending_preds[s] == 0:
                    skey = (inst.app.name, s)
                    self._queue_push(skey, self._new_job(inst, s, self.now))
                    self._blocked.discard(skey)
        if self.recorder.enabled:
            self.recorder.on_task_complete(self, task)
        # policy hook *after* successors are queued so the autoscaler sees
        # the true backlog (vertical policies grow idle pools here)
        self.autoscaler.on_complete(self, task)
        self._retire_task(task)

    # ---- streaming retention / digest -------------------------------------
    def _complete_inst(self, inst: AppInstance) -> None:
        self.n_completed += 1
        lat = inst.finish_ms - inst.arrival_ms
        if lat <= inst.slo_ms:
            self.slo_hits_n += 1
        if self.retain == "full":
            self.completed.append(inst)
        else:
            self._lat_sum += lat
            self._lat_hist.record(lat)
            self._horizon_ms = max(self._horizon_ms, inst.finish_ms)
        if self._digest is not None:
            self._fold(("done", inst.uid, repr(inst.arrival_ms),
                        repr(inst.finish_ms)))
        if self.on_request_done is not None:
            self.on_request_done(inst)

    def _retire_task(self, task: Task) -> None:
        """A task left the running set for good (completion or
        reclamation kill): fold it into the digest, feed the streaming
        hooks, then — in stream mode — recycle it and its jobs through
        the free-list pools instead of retaining them forever."""
        if self._digest is not None:
            self._fold_task(task)
        if self.on_task_retire is not None:
            self.on_task_retire(task)
        if self.retain == "full":
            return
        self._horizon_ms = max(self._horizon_ms, task.end_ms)
        task.gen += 1                 # stale any in-flight resize events
        jobs = task.jobs
        task.jobs = []
        pool = self._job_pool
        for job in jobs:
            if len(pool) < JOB_POOL_CAP:
                job.inst = None       # release the AppInstance
                pool.append(job)
        if len(self._task_pool) < TASK_POOL_CAP:
            self._task_pool.append(task)

    def _fold(self, payload: tuple) -> None:
        self._digest.update(repr(payload).encode())

    def _fold_task(self, task: Task) -> None:
        # everything schedule_digest-style comparisons care about, minus
        # ``gen`` (monotone across pool reuses, so mode-dependent)
        c = task.config
        self._fold(("task", task.tid, task.stage, task.func,
                    c.batch, c.vcpu, c.vgpu, task.invoker,
                    repr(task.start_ms), repr(task.end_ms),
                    repr(task.exec_start_ms), task.tier, task.cold,
                    repr(task.cost), task.quota_slices,
                    repr(task.penalty_ms), repr(task.full_penalty_ms),
                    task.preempted,
                    tuple(j.inst.uid for j in task.jobs)))

    def run_digest(self) -> str:
        """Hex digest of the streamed schedule: every retired task's
        placement/timing/cost tuple, every completion and shed, plus the
        run totals.  Identical across ``retain`` modes, arrival feeding
        modes and processes — the bit-identity fingerprint the sharded
        replay engine compares (requires ``track_digest=True``)."""
        if self._digest is None:
            raise ValueError("run_digest requires ClusterSim("
                             "track_digest=True)")
        h = self._digest.copy()
        h.update(repr(("totals", self.n_tasks, self.n_completed,
                       self.n_shed, self.slo_hits_n,
                       repr(self.total_cost), self.cold_starts,
                       self.remote_transfers, self.preemptions,
                       repr(self.slice_busy_ms),
                       repr(self.penalty_charged_ms))).encode())
        return h.hexdigest()

    # ---- scheduling pass ---------------------------------------------------
    def _schedule_pass(self):
        # active ready set: only queues currently holding jobs take part,
        # iterated in queue-creation order — exactly the dict-insertion
        # order the legacy `self.queues.items()` scan produced, without
        # the O(total queue keys)-per-event cost at day scale
        ready = self._nonempty - self._blocked
        if not ready:
            return
        qorder = self._qorder
        for key in sorted(ready, key=qorder.__getitem__):
            # round-robin over AFW queues, draining each (paper Fig 2d);
            # blocked queues wait for a capacity-changing event (the recheck
            # list retry is capacity-driven: within a pass capacity only
            # shrinks, so immediate retries are provably futile)
            while self.queues[key] and key not in self._blocked:
                if not self._try_queue(key):
                    break
            if not self.queues[key]:
                self._nonempty.discard(key)

    def _try_queue(self, key: tuple[str, str]) -> bool:
        """Dispatch from one AFW queue; returns True if a task was launched."""
        q = self.queues[key]
        if not q:
            self.recheck.pop(key, None)
            return False
        app_name, stage = key
        app = self.apps[app_name]
        jobs = list(q)

        t0 = _walltime.perf_counter()
        self.sched.charged_overhead_ms = 0.0
        candidates = self.sched.plan(self, app, stage, jobs, self.now)
        overhead_ms = (_walltime.perf_counter() - t0) * 1e3
        # schedulers may charge a (deterministic, pre-measured) overhead
        # instead of re-running an identical search per instance (Orion)
        charged = getattr(self.sched, "charged_overhead_ms", 0.0)
        if charged:
            overhead_ms = charged
        if self.retain == "full":
            self.sched_overheads_ms.append(overhead_ms)
        else:
            self._ovh_sum += overhead_ms
            self._ovh_n += 1
            self._ovh_hist.record(overhead_ms)
        if self.recorder.enabled:
            self.recorder.on_plan_timed(self)
        # scheduling overhead delays the task being scheduled (the controller
        # runs one proxy thread per queue — paper §4); it is charged to the
        # dispatched task's start below, not serialised on the global clock.
        overhead_charge = overhead_ms if self.count_overhead else 0.0

        forced = self.recheck.get(key, 0) >= RECHECK_LIMIT
        if forced:
            # stuck in recheck: force the cheapest config (ensures progress
            # without pinning huge models to a single accelerator)
            tbl = self.tables[app.func_of[stage]]
            cheapest = tbl.configs[int(np.argmin(tbl.job_costs))]
            candidates = (candidates or []) + [cheapest, Config(1, 1, 1)]

        def attempt() -> bool:
            for cfg in candidates:
                if not self.batching:
                    cfg = Config(1, cfg.vcpu, cfg.vgpu)
                if not self.gpu_sharing:
                    cfg = Config(cfg.batch, cfg.vcpu, self.invokers[0].vgpus)
                miss = cfg.batch > len(jobs)
                cfg = Config(min(cfg.batch, len(jobs)), cfg.vcpu, cfg.vgpu)
                inv = self._place(app, stage, jobs[: cfg.batch], cfg)
                if inv is not None:
                    if getattr(self.sched, "static_plan", False):
                        self.plan_uses += 1
                        self.config_misses += int(miss)
                    self._dispatch(key, jobs[: cfg.batch], cfg, inv,
                                   overhead_charge)
                    self.recheck.pop(key, None)
                    return True
            return False

        if attempt():
            return True
        # congestion hook: a vertical autoscaler may shrink the quotas of
        # running pools to make room, then the placement is retried once
        if self.autoscaler.on_congestion(self, app, stage, candidates) \
                and attempt():
            return True
        self.recheck[key] = self.recheck.get(key, 0) + 1
        self._blocked.add(key)
        if self.sparse and self._congestion_noop:
            # remember what this failed attempt planned against so later
            # prewarm events can prove a retry futile without re-planning
            sig = self.sched.plan_signature(self, app, stage, jobs, self.now)
            self._block_sig[key] = None if sig is None else (sig, forced)
        return False

    # ---- placement ---------------------------------------------------------
    def _locality_order(self, app: Workflow, stage: str,
                        jobs: list[Job]) -> list[int]:
        """Paper-§3.4 data-locality preference: the stable home invoker
        for root stages, else the predecessors' invokers by frequency."""
        preds = app.predecessors(stage)
        order: list[int] = []
        if not preds:
            order.append(home_invoker(app.name, app.func_of[stage],
                                      len(self.invokers)))
        else:
            pred_invs = [j.inst.stage_invoker.get(p)
                         for j in jobs for p in preds]
            pred_invs = [p for p in pred_invs if p is not None]
            if pred_invs:
                # kept verbatim from the pre-fast-path code: argsort's
                # default sort is unstable past 16 elements, so any
                # "equivalent" reimplementation can reorder count-tied
                # invokers on large fleets and break bit-identical replay
                vals, counts = np.unique(pred_invs, return_counts=True)
                order.extend(int(v) for v in vals[np.argsort(-counts)])
        return order

    def _capacity_order(self) -> list[int]:
        """Invoker indices, most free accelerator (then CPU) first —
        rebuilt lazily after capacity mutations so placement fallbacks
        walk one pre-sorted list instead of re-scanning the fleet."""
        if self._cap_dirty:
            invs = self.invokers
            self._cap_order = sorted(
                range(len(invs)),
                key=lambda i: (-invs[i].free_vgpu, -invs[i].free_vcpu, i))
            self._cap_dirty = False
        return self._cap_order

    def _place(self, app: Workflow, stage: str, jobs: list[Job],
               cfg: Config) -> Optional[int]:
        if self._has_spot and self.prefer_on_demand:
            # burn-rate alert firing: try the reliable partition first,
            # spill onto spot capacity only when on-demand is full
            got = self._place_any(app, stage, jobs, cfg, spot_ok=False)
            if got is not None:
                return got
        return self._place_any(app, stage, jobs, cfg)

    def _place_any(self, app: Workflow, stage: str, jobs: list[Job],
                   cfg: Config, spot_ok: bool = True) -> Optional[int]:
        func = app.func_of[stage]

        def ok(inv: Invoker) -> bool:
            return (spot_ok or not inv.sku.spot) and \
                inv.fits(cfg, func, self.now)

        if self.sched.placement == "fragmentation":
            # best-fit: minimise leftover GPU after placement (INFless/FaST)
            best, best_left = None, None
            for inv in self.invokers:
                if ok(inv):
                    left = inv.free_vgpu - cfg.vgpu
                    if best_left is None or left < best_left:
                        best, best_left = inv.idx, left
            return best
        # locality preference first (paper §3.4) — shared by the 'locality'
        # and 'memory' policies: avoiding a remote predecessor transfer is
        # worth more than any swap-in, and keeping this leg identical is
        # what lets 'memory' degrade to 'locality' bit-for-bit when HBM
        # is unbounded
        order = self._locality_order(app, stage, jobs)
        for idx in order:
            if ok(self.invokers[idx]):
                return idx
        if self.sched.placement == "memory":
            # weight-locality fallback: rank the remaining candidates by
            # the restart penalty their warm state implies (hot weights 0
            # < host-staged swap_in_ms < full cold start), breaking ties
            # exactly like the legacy warm/cold steps (most free first) —
            # the swap-in is paid once per attach, never per container,
            # when the device ledger shares read-only weights
            cold_ms = self.profiles[func].cold_ms
            rest = [i for i in self.invokers
                    if i.idx not in order and ok(i)]
            if not rest:
                return None
            return min(rest, key=lambda i: (
                i.start_penalty_ms(func, cold_ms, self.now),
                -i.free_vgpu, -i.free_vcpu, i.idx)).idx
        if self.sparse:
            # one walk over the capacity-sorted order replaces the two
            # full warm/cold scans: the first *fitting* invoker in that
            # order is exactly max((free_vgpu, free_vcpu)) over the
            # fitting set (ties resolve to the lowest index, as max()
            # did), and warm-over-cold preference is kept by remembering
            # the first fit while continuing to look for a warm one.
            # Locality-order invokers already failed fits above and are
            # skipped without re-probing.
            probed = set(order)
            first_fit = None
            for idx in self._capacity_order():
                if idx in probed:
                    continue
                inv = self.invokers[idx]
                if not ok(inv):
                    continue
                if inv.has_warm(func, self.now):
                    return idx
                if first_fit is None:
                    first_fit = idx
            return first_fit
        # other warm invokers
        warm = [i for i in self.invokers
                if i.has_warm(func, self.now) and ok(i)
                and i.idx not in order]
        if warm:
            return max(warm, key=lambda i: (i.free_vgpu, i.free_vcpu)).idx
        # cold invoker with most available resources
        cold = [i for i in self.invokers if ok(i)]
        if cold:
            return max(cold, key=lambda i: (i.free_vgpu, i.free_vcpu)).idx
        return None

    # ---- dispatch ----------------------------------------------------------
    def _dispatch(self, key: tuple[str, str], jobs: list[Job], cfg: Config,
                  inv_idx: int, overhead_ms: float = 0.0):
        app_name, stage = key
        app = self.apps[app_name]
        func = app.func_of[stage]
        inv = self.invokers[inv_idx]
        q = self.queues[key]
        for _ in jobs:
            q.popleft()
        if not q:
            self._nonempty.discard(key)

        # data transfer: remote if any predecessor output lives elsewhere
        transfer = 0.0
        for job in jobs:
            for p in app.predecessors(stage):
                src = job.inst.stage_invoker.get(p)
                if src is None:
                    continue
                if src == inv_idx:
                    transfer = max(transfer, LOCAL_TRANSFER_MS)
                else:
                    self.remote_transfers += 1
                    transfer = max(
                        transfer, REMOTE_TRANSFER_FIXED_MS +
                        REMOTE_TRANSFER_MS_PER_MB * self.profiles[func].input_mb)

        # warm-up-from-zero: the first start on a completely empty device
        # of a SKU with a bring-up latency pays it on top of the tier
        # penalty (the default SKU carries 0 and skips the probe)
        warmup_ms = 0.0
        if inv.sku.warmup_ms > 0.0 and inv.device.empty(self.now):
            warmup_ms = inv.sku.warmup_ms

        slices = cfg.vgpu * SLICES_PER_VGPU
        if self.overlap:
            # overlapped swap pipeline: the restart penalty is a
            # transfer-engine *completion time* (``alloc.ready_ms``),
            # not a scalar — execution gates on the weights landing,
            # so the swap-in hides behind data transfer, scheduling
            # overhead and any prefetch issued at the predecessor's
            # dispatch; only the residual is charged below
            alloc, tier = inv.device.start(
                func, slices, inv.model_mb(func), self.now,
                cold_ms=self.profiles[func].cold_ms)
        else:
            # the predicted restart penalty IS the billed one — hot: free;
            # warm: the Torpor-style swap-in transfer (weights were demoted
            # to host RAM), not a full cold start; cold: full cold start,
            # discounted by the weight-load component when shared weights
            # are already resident via a running peer (see
            # ``DeviceModel.swap_cost_ms``)
            penalty_ms = inv.start_penalty_ms(
                func, self.profiles[func].cold_ms, self.now)
            alloc, tier = inv.device.start(func, slices, inv.model_mb(func),
                                           self.now)
        cold = tier == COLD
        if cold:
            self.cold_starts += 1

        noise = float(np.clip(
            1.0 + self.rng.normal(0.0, self.noise_sigma), 0.5, 2.0))
        exec_ms = self.profiles[func].exec_ms(cfg) * noise
        if inv.exec_slowdown != 1.0:
            exec_ms *= inv.exec_slowdown       # SKU speed grade
        restore_ms = 0.0
        if self._has_spot:
            ck = self.profiles[func].checkpoint_mb
            if ck > 0.0:
                frac = min(j.inst.ckpt_frac.get(stage, 0.0) for j in jobs)
                if frac > 0.0:
                    # resume-from-checkpoint: skip the completed fraction
                    # of the batch's least-advanced job, pay the
                    # checkpoint restore copy instead of a full re-run
                    exec_ms *= (1.0 - frac)
                    restore_ms = inv.device._swap_ms(ck)
        start = self.now + overhead_ms + transfer
        if self.overlap:
            exec_start = max(start, alloc.ready_ms)
            charged = exec_start - start
            full = alloc.full_penalty_ms
        else:
            exec_start = start + penalty_ms
            charged = full = penalty_ms
        extra = warmup_ms + restore_ms
        if extra > 0.0:
            exec_start += extra
            charged += extra
            full += extra
        end = exec_start + exec_ms

        inv.free_vcpu -= cfg.vcpu
        self._cap_dirty = True
        rate = cfg.vcpu * VCPU_PRICE_PER_H + \
            cfg.vgpu * VGPU_PRICE_PER_H * inv.price_factor
        cost = rate * (charged + exec_ms) / 3.6e6
        self.total_cost += cost
        self.penalty_charged_ms += charged
        self.penalty_full_ms += full
        tid = self.n_tasks
        self.n_tasks += 1
        if self._task_pool:
            # free-list reuse (stream mode): every field is reassigned;
            # ``gen`` keeps counting from the previous life so stale
            # complete/resize events of that life can never match
            task = self._task_pool.pop()
            task.jobs = jobs
            task.stage = stage
            task.func = func
            task.config = cfg
            task.invoker = inv_idx
            task.start_ms = start
            task.end_ms = end
            task.cold = cold
            task.cost = cost
            task.tid = tid
            task.tier = tier
            task.alloc_id = alloc.aid
            task.quota_slices = slices
            task.exec_start_ms = exec_start
            task.dispatch_ms = self.now
            task.q_since = self.now
            task.penalty_ms = charged
            task.full_penalty_ms = full
            task.preempted = False
        else:
            task = Task(jobs, stage, func, cfg, inv_idx, start, end, cold,
                        cost, tid=tid, tier=tier, alloc_id=alloc.aid,
                        quota_slices=slices, exec_start_ms=exec_start,
                        dispatch_ms=self.now, q_since=self.now,
                        penalty_ms=charged, full_penalty_ms=full)
        if self.retain == "full":
            self.tasks.append(task)
        if self.dispatch_feed is not None:
            for job in jobs:
                self.dispatch_feed.append(
                    (app_name, stage, max(start - job.ready_ms, 0.0)))
        self.running[task.tid] = task
        self.push_event(end, "complete", (task, task.gen))
        if self.recorder.enabled:
            self.recorder.on_dispatch(self, task)
        if self.executor is not None:
            # real-compute bridge: run the dispatched batch on-device,
            # async — simulated time is never coupled to device wall time
            self.executor.submit(task)
        # warm-pool policy hook: reactive scale-up / pre-warm scheduling /
        # scale-down all live in repro.serving.autoscaler
        self.autoscaler.on_dispatch(self, func, inv_idx, cold,
                                    charged + exec_ms)
        if self.prefetch_weights:
            # predictive prefetch (Torpor): stage the successor stages'
            # weights on this invoker — locality placement probes it
            # first — so the copy overlaps this task's execution
            self.autoscaler.prefetch(self, app, stage, inv_idx)

    # ---- vertical reallocation ---------------------------------------------
    def resize_task(self, task: Task, new_slices: int) -> bool:
        """Vertically resize a *running* task's compute quota without a
        restart (HAS-GPU's lever).  The remaining execution is rescaled
        by the profile quota model, the completion event is re-scheduled
        (the old one goes stale via ``task.gen``), and the billed cost is
        adjusted to the new fractional-vGPU rate for the remaining time.
        Returns False if the task is not running, the target equals the
        current quota, or the device lacks free slices to grow."""
        if task.tid not in self.running or new_slices == task.quota_slices:
            return False
        inv = self.invokers[task.invoker]
        old = task.quota_slices
        if not inv.device.resize(task.alloc_id, new_slices):
            return False
        self._cap_dirty = True
        now = self.now
        fp = self.profiles[task.func]
        pivot = max(now, task.exec_start_ms)
        remaining = max(task.end_ms - pivot, 0.0)
        ratio = fp.exec_ms(task.config,
                           quota_vgpu=new_slices / SLICES_PER_VGPU) / \
            fp.exec_ms(task.config, quota_vgpu=old / SLICES_PER_VGPU)
        new_remaining = remaining * ratio
        # re-bill the remaining window at the new fractional-vGPU rate
        # (SKU price factor included, 1.0 on the default fleet)
        old_rate = task.config.vcpu * VCPU_PRICE_PER_H + \
            (old / SLICES_PER_VGPU) * VGPU_PRICE_PER_H * inv.price_factor
        new_rate = task.config.vcpu * VCPU_PRICE_PER_H + \
            (new_slices / SLICES_PER_VGPU) * VGPU_PRICE_PER_H \
            * inv.price_factor
        delta = (new_rate * new_remaining - old_rate * remaining) / 3.6e6
        task.cost += delta
        self.total_cost += delta
        # close the slice-time segment at the old quota
        self.slice_busy_ms += old * max(now - task.q_since, 0.0)
        task.q_since = max(now, task.q_since)
        task.end_ms = pivot + new_remaining
        task.quota_slices = new_slices
        task.gen += 1
        self.push_event(task.end_ms, "complete", (task, task.gen))
        self.resizes.append((now, task.invoker, task.tid, old, new_slices))
        if self.recorder.enabled:
            self.recorder.on_resize(self, task, old, new_slices)
        return True

    # ---- metrics -------------------------------------------------------------
    def slo_hit_rate(self) -> float:
        # counters are maintained in both retention modes (full mode
        # additionally keeps the instance list) — same arithmetic either way
        return self.slo_hits_n / self.n_completed if self.n_completed else 0.0

    def summary(self) -> dict[str, Any]:
        if self.retain == "full":
            lat = np.array([i.finish_ms - i.arrival_ms
                            for i in self.completed]) \
                if self.completed else np.array([0.0])
            ovh = np.array(self.sched_overheads_ms) \
                if self.sched_overheads_ms else np.array([0.0])
            lat_mean, lat_p95 = float(lat.mean()), float(np.percentile(lat, 95))
            ovh_mean, ovh_p95 = float(ovh.mean()), float(np.percentile(ovh, 95))
        else:
            # streaming accumulators: means are exact, percentiles come
            # from the log-bucketed histograms (O(1) memory)
            lat_mean = (self._lat_sum / self.n_completed
                        if self.n_completed else 0.0)
            lat_p95 = self._lat_hist.percentile(95)
            ovh_mean = self._ovh_sum / self._ovh_n if self._ovh_n else 0.0
            ovh_p95 = self._ovh_hist.percentile(95)
        return {
            "scheduler": self.sched.name,
            "autoscaler": getattr(self.autoscaler, "name", "?"),
            "completed": self.n_completed,
            "shed": self.n_shed,
            "slo_hit_rate": self.slo_hit_rate(),
            "total_cost": self.total_cost,
            "mean_latency_ms": lat_mean,
            "p95_latency_ms": lat_p95,
            "mean_sched_overhead_ms": ovh_mean,
            "p95_sched_overhead_ms": ovh_p95,
            "cold_starts": self.cold_starts,
            "remote_transfers": self.remote_transfers,
            "config_misses": self.config_misses,
            "plan_uses": self.plan_uses,
            "sparse_skips": self.sparse_skips,
            **self.gpu_summary(),
        }

    def gpu_summary(self) -> dict[str, Any]:
        """Device-model metrics aggregated over the invoker fleet."""
        devs = [inv.device for inv in self.invokers]
        return {
            "hot_hits": sum(d.stats.hot_hits for d in devs),
            "warm_hits": sum(d.stats.warm_hits for d in devs),
            "swap_ins": sum(d.stats.swap_ins for d in devs),
            "swap_in_ms": sum(d.stats.swap_in_ms for d in devs),
            "demotions": sum(d.stats.demotions for d in devs),
            "resizes_up": sum(d.stats.resizes_up for d in devs),
            "resizes_down": sum(d.stats.resizes_down for d in devs),
            "hbm_peak_mb": max((d.stats.hbm_peak_mb for d in devs),
                               default=0.0),
            "shared_hits": sum(d.stats.shared_hits for d in devs),
            # overlapped-swap pipeline observability
            "transfer_busy_ms": sum(d.engine.busy_ms for d in devs),
            "transfer_demand_ms": sum(d.engine.demand_ms for d in devs),
            "transfer_prefetch_ms": sum(d.engine.prefetch_ms for d in devs),
            "prefetch_issued": sum(d.stats.prefetch_issued for d in devs),
            "prefetch_hits": sum(d.stats.prefetch_hits for d in devs),
            "prefetch_wasted": sum(d.stats.prefetch_wasted for d in devs),
            "penalty_charged_ms": self.penalty_charged_ms,
            "penalty_full_ms": self.penalty_full_ms,
            "penalty_hidden_ms": self.penalty_full_ms
            - self.penalty_charged_ms,
            # preemptible-fleet observability
            "reclaim_warnings": self.reclaim_warnings,
            "reclamations": self.reclaims,
            "recoveries": self.recoveries,
            "preemptions": self.preemptions,
            "retries": self.retries,
            "preempt_shed": self.preempt_shed,
            "preempt_lost_ms": self.preempt_lost_ms,
            "migrations": self.migrations,
        }
