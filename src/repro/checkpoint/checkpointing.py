"""Sharded checkpointing with resharding restore (elastic rescale).

Format: one ``manifest.json`` (pytree structure, shapes, dtypes, step,
mesh metadata) + one ``.npy`` per leaf.  Leaves are gathered to host
numpy before writing (fine at the scale this container runs; on a real
pod each host writes its local shards — the manifest layout already keys
leaves by path so a per-shard variant is a drop-in).

Restore takes the *target* sharding tree: ``jax.device_put`` reshards,
so restoring onto a different mesh shape (elastic scale up/down) or a
different partitioning works out of the box — exercised by
``tests/test_checkpoint.py`` and ``runtime/elastic.py``.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str | pathlib.Path, step: int, tree,
         keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                     # atomic publish
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, tree_like,
            shardings=None, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (same pytree) when given."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)

    names = [n for n, _ in _flatten(tree_like)]
    flat_shardings = ([s for _, s in _flatten(shardings)]
                      if shardings is not None else [None] * len(names))
    leaves = []
    for name, shard in zip(names, flat_shardings):
        info = manifest["leaves"][name]
        arr = np.load(d / info["file"])
        if shard is not None:
            arr = jax.device_put(arr, shard)
        leaves.append(arr)
    treedef = jax.tree.structure(tree_like)
    return jax.tree.unflatten(treedef, leaves), manifest["step"]


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(int(m.group(1)) for p in ckpt_dir.iterdir()
                   if (m := re.fullmatch(r"step_(\d+)", p.name)))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
