"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests to see a
single CPU device while the dry-run sees 512 fake hosts.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with axis_types only where the release supports it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (possibly fake) devices exist."""
    return _make_mesh(shape, axes)


def mesh_axes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch (pod + data when multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
