"""Step builders: the jitted (sharded) train / prefill / serve steps.

Each builder returns (fn, in_shardings, out_shardings, input_specs,
donate_argnums) ready for ``jax.jit(...).lower(...)`` — used by both the
dry-run (AOT) and the real launchers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ModelConfig, ShapeSpec
from repro.models.model import Model, RunOptions, get_model
from repro.optim import adamw
from repro.launch import shardings as sh


def _ns(mesh, tree):
    return sh.to_shardings(tree, mesh)


def _mesh_opts(opts: RunOptions, mesh, shape: ShapeSpec,
               tp: bool = True) -> RunOptions:
    """Enable sharding constraints with the mesh's dp axes (None when the
    global batch is too small to shard, e.g. long_500k decode).  Without TP
    the batch takes the 'model' axis too (pure DP)."""
    dp = sh.dp_axes(mesh)
    if not tp:
        dp = tuple(dp) + (sh.TP,)
    extent = 1
    for a in dp:
        extent *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    dp_spec = tuple(dp) if shape.global_batch >= extent else None
    return dataclasses.replace(opts, shard_constraints=True, dp_spec=dp_spec,
                               mesh=mesh)


def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     opts: RunOptions = RunOptions(),
                     opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    tp = sh.tp_applies(cfg, shape, opts.sharding_mode)
    opts = _mesh_opts(opts, mesh, shape, tp)
    model = get_model(cfg, opts)
    multi_pod = "pod" in mesh.axis_names

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, _, metrics = adamw.update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics}

    params_specs0 = model.param_specs()
    p_raw = sh.param_pspecs(cfg)
    m_raw = sh.moment_pspecs(cfg, multi_pod)
    if not tp:
        p_raw, m_raw = sh.strip_tp(p_raw), sh.strip_tp(m_raw)
        # replicate the (small) embeddings: keeps the chunked CE fully
        # local instead of all-gathering the global batch per chunk
        for k in ("embed", "lm_head"):
            if k in p_raw:
                p_raw[k] = P(None, None)
                m_raw[k] = P(sh.FSDP, None)
    p_spec = sh.sanitize_tree(p_raw, params_specs0, mesh)
    m_spec = sh.sanitize_tree(m_raw, params_specs0, mesh)
    opt_spec = {"m": m_spec, "v": m_spec, "step": P()}
    b_spec = sh.batch_pspecs(cfg, shape, mesh,
                             dp=opts.dp_spec or sh.dp_axes(mesh))
    in_sh = (_ns(mesh, p_spec), _ns(mesh, opt_spec), _ns(mesh, b_spec))
    out_sh = (_ns(mesh, p_spec), _ns(mesh, opt_spec),
              {"loss": NamedSharding(mesh, P()),
               "grad_norm": NamedSharding(mesh, P()),
               "lr": NamedSharding(mesh, P())})

    params_specs = model.param_specs()
    opt_specs = {
        "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                          params_specs),
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                          params_specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    input_specs = (params_specs, opt_specs, model.input_specs(shape))
    return train_step, in_sh, out_sh, input_specs, (0, 1)


def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                       opts: RunOptions = RunOptions()):
    opts = _mesh_opts(opts, mesh, shape)
    model = get_model(cfg, opts)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, max_len=shape.seq_len)
        return logits, cache

    p_raw = sh.param_pspecs(cfg)
    if sh.weight_stationary_serving(cfg):
        p_raw = sh.strip_fsdp(p_raw)
    p_spec = sh.sanitize_tree(p_raw, model.param_specs(), mesh)
    b_spec = sh.batch_pspecs(cfg, shape, mesh)
    c_spec = sh.sanitize_tree(
        sh.cache_pspecs(cfg, shape, mesh),
        model.cache_specs(shape.global_batch, shape.seq_len), mesh)
    dp = sh.dp_axes(mesh)
    logits_spec = sh.sanitize_pspec(
        P(dp if shape.global_batch >= 2 else None, sh.TP),
        (shape.global_batch, cfg.vocab), mesh)
    in_sh = (_ns(mesh, p_spec), _ns(mesh, b_spec))
    out_sh = (NamedSharding(mesh, logits_spec), _ns(mesh, c_spec))
    input_specs = (model.param_specs(), model.input_specs(shape))
    return prefill_step, in_sh, out_sh, input_specs, ()


def build_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     opts: RunOptions = RunOptions()):
    """Decode: one new token against a seq_len-deep cache."""
    opts = _mesh_opts(opts, mesh, shape)
    model = get_model(cfg, opts)

    def serve_step(params, cache, tokens):
        logits, cache = model.decode(params, cache, tokens)
        return logits, cache

    p_raw = sh.param_pspecs(cfg)
    if sh.weight_stationary_serving(cfg):
        p_raw = sh.strip_fsdp(p_raw)
    p_spec = sh.sanitize_tree(p_raw, model.param_specs(), mesh)
    c_spec = sh.sanitize_tree(
        sh.cache_pspecs(cfg, shape, mesh),
        model.cache_specs(shape.global_batch, shape.seq_len), mesh)
    dp = sh.dp_axes(mesh)
    dp_extent = 1
    for a in dp:
        dp_extent *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    bdim = dp if shape.global_batch >= dp_extent else None
    tok_spec = {"tokens": sh.sanitize_pspec(
        P(bdim, None), (shape.global_batch, 1), mesh)}
    logits_spec = sh.sanitize_pspec(P(bdim, sh.TP),
                                    (shape.global_batch, cfg.vocab), mesh)
    in_sh = (_ns(mesh, p_spec), _ns(mesh, c_spec),
             NamedSharding(mesh, tok_spec["tokens"]))
    out_sh = (NamedSharding(mesh, logits_spec), _ns(mesh, c_spec))

    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    input_specs = (model.param_specs(), cache_specs, tok)
    return serve_step, in_sh, out_sh, input_specs, (1,)


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh,
               opts: RunOptions = RunOptions()):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, opts)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, opts)
    return build_serve_step(cfg, shape, mesh, opts)
