"""Optimized-HLO collective parser.

``compiled.as_text()`` is an SPMD (per-device) module.  We extract every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
attribute it to its computation, and walk the call graph from ENTRY through
``while`` bodies using XLA's ``known_trip_count`` backend_config so that
collectives inside the layer scan (and nested chunk scans) are multiplied by
their true execution counts.

Wire-byte model (per device, bidirectional ring):
  all-reduce        2 (S-1)/S x bytes(result)
  all-gather        (S-1)/S x bytes(result)
  reduce-scatter    (S-1)   x bytes(result)      (= (S-1)/S x operand)
  all-to-all        (S-1)/S x bytes(result)
  collective-permute  1.0   x bytes(result)
where S = participating group size from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9_\[\]{},\s]*?)?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
WHILE_RE = re.compile(r"=.*\bwhile\(.*body=%([\w.\-]+)")
COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)[\s(].*\{$")

WIRE_FACTOR = {
    "all-reduce": lambda s: 2.0 * (s - 1) / s,
    "all-gather": lambda s: (s - 1) / s,
    "reduce-scatter": lambda s: float(s - 1),
    "all-to-all": lambda s: (s - 1) / s,
    "collective-permute": lambda s: 1.0,
}


@dataclasses.dataclass
class Collective:
    op: str
    bytes_result: float
    group_size: int
    count: float = 1.0

    @property
    def wire_bytes(self) -> float:
        return WIRE_FACTOR[self.op](max(self.group_size, 2)) \
            * self.bytes_result * self.count


def _result_bytes(line: str) -> float:
    """Sum byte sizes of all result shapes on the line (tuples included)."""
    lhs = line.split(" = ", 1)[1] if " = " in line else line
    head = lhs.split("(", 1)[0]
    total = 0.0
    for dt, dims in SHAPE_RE.findall(head):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Returns {'total_wire_bytes', 'by_op', 'items'} for one SPMD program."""
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line.startswith(" "):
            m = COMP_RE.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is not None:
            comps[cur].append(line.strip())

    # 2. per computation: collectives + nested whiles
    colls: dict[str, list[Collective]] = defaultdict(list)
    whiles: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            if line.split(" = ")[0].strip().startswith("%") or " = " in line:
                cm = COLL_RE.search(line)
                if cm and "-done" not in line.split("(")[0]:
                    op = cm.group(2)
                    colls[name].append(Collective(
                        op, _result_bytes(line), _group_size(line, n_devices)))
                wm = WHILE_RE.search(line)
                if wm:
                    tm = TRIP_RE.search(line)
                    trip = float(tm.group(1)) if tm else 1.0
                    whiles[name].append((wm.group(1), trip))

    # 3. DFS from entry, multiplying trip counts
    out: list[Collective] = []

    def visit(comp: str, mult: float, depth: int = 0):
        if depth > 16:
            return
        for c in colls.get(comp, []):
            out.append(Collective(c.op, c.bytes_result, c.group_size, mult))
        for body, trip in whiles.get(comp, []):
            visit(body, mult * trip, depth + 1)

    if entry:
        visit(entry, 1.0)

    by_op: dict[str, float] = defaultdict(float)
    for c in out:
        by_op[c.op] += c.wire_bytes
    return {
        "total_wire_bytes": float(sum(c.wire_bytes for c in out)),
        "by_op": dict(by_op),
        "n_collectives": len(out),
        "items": [(c.op, c.bytes_result, c.group_size, c.count) for c in out],
    }
