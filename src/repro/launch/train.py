"""Training launcher CLI.

Reduced configs run for real on this host; full configs are exercised via
the dry-run (``repro.launch.dryrun``).  On a real pod this entrypoint runs
under ``jax.distributed.initialize`` with the production mesh and the same
Trainer loop (checkpoint/restart, deterministic data replay).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
      --reduced --steps 200
"""
from __future__ import annotations

import argparse

from repro.configs.registry import get_config, reduced as reduce_cfg
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps,
                                compress_grads=args.compress_grads)
    out = Trainer(cfg, data_cfg, tcfg, opt_cfg=opt_cfg).run()
    print(f"[train] done: {out['steps_run']} steps, "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
