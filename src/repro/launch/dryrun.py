import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell:  lower + compile the step on
the production mesh (16x16 single-pod, and 2x16x16 multi-pod), print
``memory_analysis()`` (proves it fits) and ``cost_analysis()`` (feeds
§Roofline), parse the optimized HLO for collective wire bytes, and persist
everything to ``benchmarks/results/dryrun/<cell>.json``.

The XLA_FLAGS line above MUST stay before any other import — jax locks the
device count at first init.

Usage:
  python -m repro.launch.dryrun --arch internlm2_20b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.registry import (ARCH_IDS, SHAPES, cell_applicable,
                                    get_config)
from repro.launch import collectives as coll
from repro.launch import hlo_analysis
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models.model import RunOptions

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: RunOptions = RunOptions(), save: bool = True,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag, "applicable": ok}
    if not ok:
        out["skip_reason"] = why
        if save:
            _save(cell, out)
        return out

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        fn, in_sh, out_sh, input_specs, donate = build_step(
            cfg, shape, mesh, opts)
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*input_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        hlo = compiled.as_text()
        cinfo = coll.parse_collectives(hlo, n_chips)
        # trip-count-aware re-analysis: cost_analysis counts while bodies
        # (the layer scan!) once — see hlo_analysis docstring
        hinfo = hlo_analysis.analyze(hlo)
        flops = float(hinfo["flops"])
        byts = float(hinfo["bytes"])
        terms = rf.roofline(cfg, shape, flops, byts,
                            cinfo["total_wire_bytes"], n_chips)
        out.update({
            "status": "ok",
            "n_chips": n_chips,
            "lower_s": t_lower,
            "compile_s": t_compile,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes_est": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "cost": {"flops": flops, "bytes_hlo_upper": byts,
                     "bytes_analytic": rf.analytic_memory_bytes(
                         cfg, shape, n_chips),
                     "xla_flops_flat": float(ca.get("flops", 0.0)),
                     "xla_bytes_flat": float(ca.get("bytes accessed", 0.0))},
            "collectives": {k: v for k, v in cinfo.items() if k != "items"},
            "collective_items": cinfo["items"][:64],
            "roofline": terms.to_dict(),
        })
        fits = out["memory"]["peak_bytes_est"] <= 16e9
        out["fits_hbm16g"] = bool(fits)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        out.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    if save:
        _save(cell, out)
    return out


def _save(cell: str, out: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    with open(RESULTS_DIR / f"{cell}.json", "w") as f:
        json.dump(out, f, indent=1, default=str)


def _fmt(out: dict) -> str:
    if not out.get("applicable", True):
        return f"SKIP ({out['skip_reason'][:60]})"
    if out.get("status") != "ok":
        return f"ERROR {out.get('error', '?')[:120]}"
    r = out["roofline"]
    mem_gb = out["memory"]["peak_bytes_est"] / 1e9
    return (f"ok compile={out['compile_s']:.1f}s mem={mem_gb:.2f}GB "
            f"comp={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
            f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant'][:4]} "
            f"useful={r['useful_ratio']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--tag", default="")
    ap.add_argument("--causal-pair-scan", action="store_true")
    ap.add_argument("--sharding", default="auto",
                    choices=["auto", "2d", "dp_only"])
    ap.add_argument("--no-seq-shard-decode", action="store_true")
    ap.add_argument("--explicit-tp", action="store_true")
    args = ap.parse_args()

    opts = RunOptions(remat=args.remat, attn_chunk=args.attn_chunk,
                      causal_pair_scan=args.causal_pair_scan,
                      sharding_mode=args.sharding,
                      seq_shard_decode=not args.no_seq_shard_decode,
                      explicit_tp_ffn=args.explicit_tp)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                out = run_cell(arch, shape, mp, opts, tag=args.tag)
                status = _fmt(out)
                print(f"{arch:26s} {shape:12s} "
                      f"{'multi ' if mp else 'single'} {status}", flush=True)
                if out.get("status") == "error":
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
