"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so a scanned
48-layer model reports ~1/48 of its real FLOPs.  This module re-derives
FLOPs and HBM traffic from the optimized HLO text, multiplying instructions
inside while bodies by XLA's ``known_trip_count`` (the layer scan, attention
chunk scans, remat bwd scans, ...), nested loops composing multiplicatively.

FLOPs:  dot ops — 2 x prod(result dims) x prod(contracting dims), read from
the instruction's operand shapes (a name->shape map is built per module).
Elementwise/fusion FLOPs are ignored (MXU-roofline convention; the VPU term
is folded into the memory bound).

Bytes:  per *top-level* instruction (fusions count once — their internals
live in registers/VMEM): sum of operand + result buffer sizes.  This
matches the spirit of XLA's bytes-accessed metric.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
OP_RE = re.compile(r"^\s*(?:\()?[\w\[\]{},\s]*?\b([\w\-]+)\(")
OPERANDS_RE = re.compile(r"\(([^)]*)\)")
TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
WHILE_BODY_RE = re.compile(r"body=%([\w.\-]+)")
CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shapes_of(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(text: str) -> float:
    total = 0.0
    for dt, shape in _shapes_of(text):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_text: str       # the "dtype[shape]..." part before the op
    op: str
    operands: list[str]
    line: str


def _parse_module(hlo: str):
    comps: dict[str, list[Instr]] = {}
    params: dict[str, dict[str, str]] = {}
    entry = None
    cur = None
    for raw in hlo.splitlines():
        if not raw.startswith(" ") and raw.strip().endswith("{"):
            header = raw.strip()
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->", header)
            if m:
                cur = m.group(1)
                comps[cur] = []
                params[cur] = {}
                if header.startswith("ENTRY"):
                    entry = cur
                for p in m.group(2).split(","):
                    p = p.strip()
                    pm = re.match(r"([\w.\-]+)\s*:\s*(.*)", p)
                    if pm:
                        params[cur][pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        line = raw.strip()
        im = INSTR_RE.match(line)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        # tuple-typed results start with '(': skip the type to find the op
        scan_from = 0
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        scan_from = i + 1
                        break
        paren = rhs.find("(", scan_from)
        if paren < 0:
            continue
        result_text = rhs[:paren] if scan_from == 0 else rhs[:scan_from]
        op_head = rhs[scan_from:paren]
        op = op_head.split()[-1] if op_head.split() else ""
        inner = rhs[paren + 1:]
        depth = 1
        args = []
        buf = ""
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append(buf.strip())
                buf = ""
            else:
                buf += ch
        if buf.strip():
            args.append(buf.strip())
        # operands may be bare (%name) or typed (f32[8,64]{1,0} %name)
        # depending on the XLA dump flavour — pull the %name either way
        operands = []
        for a in args:
            om = re.search(r"%([\w.\-]+)", a)
            if om:
                operands.append(om.group(1))
        comps[cur].append(Instr(name, result_text, op, operands, line))
    return comps, params, entry


def analyze(hlo: str) -> dict:
    """Returns {'flops', 'bytes', 'dot_flops_by_comp', ...} (per device)."""
    comps, params, entry = _parse_module(hlo)

    # name -> result text (for operand shape lookup), per computation with
    # parameters included
    shapes: dict[str, dict[str, str]] = {}
    for cname, instrs in comps.items():
        tbl = dict(params.get(cname, {}))
        for ins in instrs:
            tbl[ins.name] = ins.result_text
        shapes[cname] = tbl

    flops_by_comp: dict[str, float] = defaultdict(float)
    bytes_by_comp: dict[str, float] = defaultdict(float)
    whiles_by_comp: dict[str, list[tuple[str, float]]] = defaultdict(list)
    calls_by_comp: dict[str, list[str]] = defaultdict(list)

    # fusion parameters that are only sliced inside the fusion body charge
    # the slice bytes, not the whole operand (the stacked layer-scan buffers
    # are multi-GB; their per-iteration reads are one layer's slice)
    param_order: dict[str, list[str]] = {}
    param_sliced_bytes: dict[str, dict[int, float]] = {}
    for cname, instrs in comps.items():
        order: list[tuple[int, str]] = []
        for ins in instrs:
            if ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    order.append((int(m.group(1)), ins.name))
        order.sort()
        param_order[cname] = [n for _, n in order]
        sliced: dict[int, float] = {}
        for idx, pname in enumerate(param_order[cname]):
            users = [i for i in instrs if pname in i.operands]
            if users and all(u.op in ("dynamic-slice", "slice", "gather",
                                      "bitcast", "reshape")
                             for u in users):
                sliced[idx] = sum(_nbytes(u.result_text) for u in users)
        param_sliced_bytes[cname] = sliced

    for cname, instrs in comps.items():
        tbl = shapes[cname]
        for ins in instrs:
            if ins.op == "while":
                bm = WHILE_BODY_RE.search(ins.line)
                tm = TRIP_RE.search(ins.line)
                if bm:
                    whiles_by_comp[cname].append(
                        (bm.group(1), float(tm.group(1)) if tm else 1.0))
                continue
            if ins.op in ("call", "conditional"):
                for cm in re.finditer(r"to_apply=%([\w.\-]+)|"
                                      r"branch_computations=\{([^}]*)\}",
                                      ins.line):
                    tgt = cm.group(1)
                    if tgt:
                        calls_by_comp[cname].append(tgt)
                    elif cm.group(2):
                        calls_by_comp[cname].extend(
                            t.strip().lstrip("%")
                            for t in cm.group(2).split(","))
            # bytes: operands + result (top-level instructions only; the
            # parser never descends into fusion bodies because fusion
            # computations are only reachable via calls= which we skip)
            if ins.op in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced window, not the whole operand
                bytes_by_comp[cname] += 2.0 * _nbytes(ins.result_text)
            elif ins.op in ("dynamic-update-slice", "scatter"):
                upd = (_nbytes(tbl[ins.operands[1]])
                       if len(ins.operands) > 1 and ins.operands[1] in tbl
                       else _nbytes(ins.result_text))
                bytes_by_comp[cname] += 2.0 * upd
            elif ins.op == "fusion":
                b = _nbytes(ins.result_text)
                fm = re.search(r"calls=%([\w.\-]+)", ins.line)
                sliced = param_sliced_bytes.get(fm.group(1), {}) if fm else {}
                for i, o in enumerate(ins.operands):
                    if o not in tbl:
                        continue
                    b += sliced.get(i, _nbytes(tbl[o]))
                bytes_by_comp[cname] += b
            elif ins.op in ("dot", "convolution", "reduce",
                            "sort", "rng", "rng-bit-generator", "iota",
                            "reduce-window", "cholesky", "triangular-solve",
                            "all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"):
                b = _nbytes(ins.result_text)
                for o in ins.operands:
                    if o in tbl:
                        b += _nbytes(tbl[o])
                bytes_by_comp[cname] += b
            elif ins.op not in ("parameter", "constant", "get-tuple-element",
                                "tuple", "bitcast", "after-all", "custom-call"):
                # elementwise / layout ops: on TPU these fuse into producer
                # chains; count the result write only (the CPU backend barely
                # fuses, so operand+result counting would inflate the memory
                # term ~50x vs a real TPU executable — verified empirically)
                bytes_by_comp[cname] += _nbytes(ins.result_text)
            # flops: dots (fusions with dots inside keep the dot top-level
            # on CPU — XLA wraps them as separate instructions)
            if ins.op in ("dot", "convolution"):
                res = _shapes_of(ins.result_text)
                if not res:
                    continue
                _, rshape = res[0]
                out_elems = 1
                for d in rshape:
                    out_elems *= d
                contract = 1
                cm = CONTRACT_RE.search(ins.line)
                if cm and ins.operands:
                    lhs = ins.operands[0]
                    lhs_shapes = _shapes_of(tbl.get(lhs, ""))
                    if lhs_shapes:
                        _, lshape = lhs_shapes[0]
                        for d in cm.group(1).split(","):
                            if d != "" and int(d) < len(lshape):
                                contract *= lshape[int(d)]
                flops_by_comp[cname] += 2.0 * out_elems * contract

    # DFS from entry with trip multipliers
    totals = {"flops": 0.0, "bytes": 0.0}

    def visit(comp: str, mult: float, depth=0):
        if depth > 20:
            return
        totals["flops"] += flops_by_comp.get(comp, 0.0) * mult
        totals["bytes"] += bytes_by_comp.get(comp, 0.0) * mult
        for body, trip in whiles_by_comp.get(comp, []):
            visit(body, mult * trip, depth + 1)
        for tgt in calls_by_comp.get(comp, []):
            visit(tgt, mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "n_computations": len(comps)}
