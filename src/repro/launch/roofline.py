"""Roofline accounting from compiled dry-run artifacts (TPU v5e targets).

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` reports per-device numbers (verified: an
8-device sharded matmul reports ~global/8), so no further division by chips.
MODEL_FLOPS uses active parameters for MoE.
"""
from __future__ import annotations

import dataclasses
from typing import Any

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link (effective per-chip collective bw)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (per-device flops x chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How close the *achievable* step is to the compute roofline:
        compute_s / max-term.  1.0 = perfectly compute-bound."""
        return self.compute_s / self.bound_s if self.bound_s > 0 else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
            "roofline_fraction": self.roofline_fraction,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def analytic_memory_bytes(cfg, shape, n_chips: int) -> float:
    """Per-device HBM traffic estimate from model arithmetic.

    The HLO-parsed byte count (kept in the JSONs as ``bytes_hlo_upper``) is
    an *unfused* upper bound: the CPU backend barely fuses and charges
    nested-loop fusion operands conservatively, inflating the term 10-30x
    vs a TPU executable.  The roofline memory term therefore uses this
    transparent napkin model (kernel-resident intermediates — flash
    attention tiles, WKV pair tensors — count as VMEM, not HBM, matching
    the Pallas execution path):

    train:   params 2B read (fwd) + 2B (bwd) + grads 2B write
             + AdamW m/v read+write fp32 (16B) + param write 2B  = 24 B/param
             + activations: ~10 residual-width passes + mlp/attn projections,
             x (fwd + bwd + remat fwd) = x3
    prefill: params 2B + 1x activation pass + cache write
    decode:  params 2B + cache read/write + O(B*D) activations
    """
    p_local = cfg.n_params / n_chips
    d, f_, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    act = 2  # bf16
    if shape.kind == "train":
        b_loc = max(shape.global_batch / n_chips * 16, 1)  # dp only: B/dp
        toks = b_loc * shape.seq_len
        per_layer = (10 * d + 3 * min(f_, f_ * (cfg.top_k if cfg.n_experts
                                                else 1)) / 16 +
                     3 * cfg.n_heads * cfg.d_head / 16) * act
        act_bytes = toks * per_layer * l * 3.0
        return p_local * 24.0 + act_bytes
    if shape.kind == "prefill":
        b_loc = max(shape.global_batch / min(n_chips, 16), 1)
        toks = b_loc * shape.seq_len
        per_layer = (8 * d + 3 * (f_ if not cfg.n_experts else
                                  f_ * cfg.top_k) / 16 +
                     4 * cfg.n_kv_heads * cfg.d_head) * act
        cache = toks * 2 * cfg.n_kv_heads * cfg.d_head * act * l
        return p_local * 2.0 + toks * per_layer * l + cache / n_chips * 16
    # decode: weights + cache dominate
    cache_local = _cache_bytes(cfg, shape) / n_chips
    b = shape.global_batch
    act_bytes = b * d * l * 8 * act / min(n_chips, 16)
    return p_local * 2.0 + cache_local + act_bytes


def _cache_bytes(cfg, shape) -> float:
    l, kvh, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return b * cfg.n_heads * cfg.d_head * cfg.d_head * 4 * l
    w = s
    if cfg.window:
        w = min(cfg.window, s)
    if cfg.chunk_attn and cfg.global_every:
        per_macro = (cfg.global_every - 1) * min(cfg.chunk_attn, s) + s
        return b * per_macro * kvh * dh * 2 * 2 * (l // cfg.global_every)
    extra = 0.0
    if cfg.family == "hybrid":
        extra = b * cfg.d_model * cfg.ssm_state * 4 * l
    return b * w * kvh * dh * 2 * 2 * l + extra


def model_flops(cfg, shape) -> float:
    """6 N D (train) / 2 N D (fwd) with N = active params."""
    n = cfg.n_active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one token per sequence
    return 2.0 * n * tokens


def roofline(cfg, shape, flops_per_device: float, bytes_hlo_upper: float,
             wire_bytes_per_device: float, n_chips: int) -> RooflineTerms:
    mf = model_flops(cfg, shape)
    mem_bytes = min(analytic_memory_bytes(cfg, shape, n_chips),
                    bytes_hlo_upper if bytes_hlo_upper > 0 else float("inf"))
    return RooflineTerms(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=mem_bytes / HBM_BW,
        collective_s=wire_bytes_per_device / ICI_BW,
        flops_per_device=flops_per_device,
        bytes_per_device=mem_bytes,
        wire_bytes_per_device=wire_bytes_per_device,
        model_flops=mf,
        useful_ratio=mf / (flops_per_device * n_chips)
        if flops_per_device else 0.0,
    )
