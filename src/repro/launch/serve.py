"""Serving launcher: ESG scheduling over the model zoo.

Two modes:

  * ``--emulate`` (default): the paper's controller (ESG or a baseline)
    schedules LM-pipeline workflows onto the emulated 16-host TPU cluster,
    with per-arch latency profiles from the v5e roofline model
    (cluster/tpu_profiles).  This is the "assigned architectures as
    servable functions" configuration.

  * ``--real``: actually serves a *reduced* model on this host through
    the full control plane: scenario arrivals enter via the Gateway,
    ESG_1Q plans batches against a *measured* profile table
    (``launch/profile_kernels``), and every dispatched task is executed
    for real by the compile-cached ``serving.executor.RealExecutor``
    (Pallas prefill + scalar-prefetch decode).  ``--bench-out`` writes
    the predicted-vs-measured comparison (BENCH_realcompute.json).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.cluster.emulator import ClusterSim
from repro.cluster.tpu_profiles import zoo_tables
from repro.cluster.workload import generate
from repro.core.profiles import Config, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import Workflow

# LM pipelines over the assigned architectures (DAG stage = one model)
ZOO_APPS = {
    "draft_verify": Workflow.pipeline(
        "draft_verify", ["rwkv6_1_6b", "internlm2_20b"]),
    "vlm_caption": Workflow.pipeline(
        "vlm_caption", ["internvl2_76b", "internlm2_1_8b"]),
    "code_review": Workflow.pipeline(
        "code_review", ["starcoder2_7b", "mixtral_8x22b"]),
    "music_tagging": Workflow.pipeline(
        "music_tagging", ["musicgen_medium", "hymba_1_5b",
                          "internlm2_1_8b"]),
}


def _make_scheduler(name: str, tables):
    if name == "esg":
        return ESGScheduler(ZOO_APPS, tables, risk_sigma=0.05)
    from repro.core.baselines.aquatope import AquatopeScheduler
    from repro.core.baselines.fastgshare import FaSTGShareScheduler
    from repro.core.baselines.infless import INFlessScheduler
    from repro.core.baselines.orion import OrionScheduler
    factories = {"infless": INFlessScheduler, "fastgshare": FaSTGShareScheduler,
                 "orion": OrionScheduler, "aquatope": AquatopeScheduler}
    return factories[name](ZOO_APPS, tables)


def emulate(setting: str = "moderate-normal", n: int = 200, seed: int = 0,
            scheduler: str = "esg", scenario: str | None = None,
            autoscaler: str | None = None, slo_mult: float = 1.0,
            overlap: bool = False, prefetch: bool = False,
            trace_out: str | None = None, metrics_out: str | None = None,
            audit_out: str | None = None, calibrate: bool = False,
            health_out: str | None = None,
            log=print) -> dict:
    """Emulated serving over the model zoo.

    Legacy mode (``scenario=None``) drives the paper's uniform-interval
    ``setting`` through ``cluster.workload.generate``.  Scenario mode runs
    the online-serving stack: ``serving.traces`` arrival engine behind the
    ``serving.gateway`` admission front end, with the warm-pool policy
    named by ``autoscaler`` (ewma | finegrained | vertical | none).

    Any of ``trace_out`` / ``metrics_out`` / ``audit_out`` /
    ``health_out`` attaches the flight recorder (``repro.obs``) and
    exports the Perfetto trace / metrics time-series / planner audit
    log / health-alert stream after the run.  ``calibrate=True`` closes
    the pricing loop: an online ``ProfileCalibrator`` subscribed to the
    audit stream corrects the planner's exec estimates per (app, stage)
    as the run progresses, and ``health_out`` additionally wires the
    SLO health engine's alerts into the gateway's admission check and
    the autoscaler's congestion hooks.
    """
    from repro.serving import Gateway, get_autoscaler, get_scenario

    tables = zoo_tables()
    profiles = {a: t.fn for a, t in tables.items()}
    sched = _make_scheduler(scheduler, tables)
    scaler = get_autoscaler(autoscaler) if autoscaler else None
    recorder = None
    health = None
    if trace_out or metrics_out or audit_out or calibrate or health_out:
        from repro.obs import HealthEngine, ProfileCalibrator, Recorder
        if health_out is not None:
            health = HealthEngine()
        # calibration consumes the audit stream, so the audit log is on
        # whenever either consumer needs it
        recorder = Recorder(health=health)
        if calibrate:
            if not hasattr(sched, "calibrator"):
                raise SystemExit(f"--calibrate requires the ESG scheduler "
                                 f"(got {scheduler!r})")
            sched.calibrator = ProfileCalibrator().attach(recorder.audit)
    sim = ClusterSim(ZOO_APPS, tables, profiles, sched, seed=seed,
                     autoscaler=scaler, overlap=overlap, prefetch=prefetch,
                     recorder=recorder)
    if health is not None and scaler is not None:
        scaler.health = health

    def _export():
        if recorder is None:
            return
        written = recorder.export(trace_out, metrics_out, audit_out,
                                  health_out)
        for kind, path in written.items():
            log(f"[obs] wrote {kind} -> {path}")
        cal = getattr(sched, "calibrator", None)
        if cal is not None:
            log(f"[obs] calibration: {cal.observations} observations, "
                f"{cal.updates} published factor updates")
        if health is not None:
            hs = health.summary()
            log(f"[obs] health: {hs['alerts_total']} alert transitions, "
                f"active={hs['active'] or 'none'}")

    if scenario is None:
        generate(sim, setting, n, profiles, seed=seed + 1)
        sim.run()
        s = sim.summary()
        log(f"[serve-emulate] {s['scheduler']}: hit={s['slo_hit_rate']:.3f} "
            f"cost=${s['total_cost']:.4f} mean_lat={s['mean_latency_ms']:.0f}ms "
            f"sched_ovh={s['mean_sched_overhead_ms']:.2f}ms")
        _export()
        return s
    gw = Gateway(sim, health=health)
    sc = get_scenario(scenario, app_names=list(ZOO_APPS))
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario
    s = tel.summary()
    log(f"[serve-scenario] {scenario}/{s['scheduler']}/{s['autoscaler']}: "
        f"slo={s['slo_attainment']:.3f} $/1k={s['cost_per_1k']:.4f} "
        f"cold={s['cold_starts']} shed={s['shed']} "
        f"p95={s['latency']['p95_ms']:.0f}ms")
    _export()
    return s


def serve_real(arch: str = "internlm2_1_8b", n_requests: int = 48,
               scenario: str = "mmpp", autoscaler: str | None = None,
               slo_mult: float = 8.0, seed: int = 0,
               gen_len: int = 4, prompt_len: int = 32,
               batches: tuple = (1, 2, 4, 8), quotas: tuple = (1.0, 0.5),
               profile_path: str | None = None, reps: int = 2,
               bench_out: str | None = None, log=print) -> dict:
    """Real-compute serving through the full control plane.

    Unlike the old bypass loop, this routes every request through the
    same Gateway → autoscaler → ``ClusterSim`` dispatch path the
    emulator uses: ESG_1Q plans batches against a *measured* profile
    table, and each dispatched task is executed for real by the
    compile-cached ``serving.executor.RealExecutor`` (actual Pallas
    prefill + scalar-prefetch decode on a reduced ``arch``).

    The measured table comes from ``launch/profile_kernels`` — either
    built in-process (default) or loaded from ``profile_path``.  After
    the run, the per-cell measured wall times are compared against the
    planner's predicted stage latencies; the comparison (plus compile
    cache stats and roofline cross-checks) is the
    ``BENCH_realcompute.json`` payload (``bench_out``).
    """
    import json

    from repro.launch.profile_kernels import build_artifact
    from repro.serving import Gateway, get_autoscaler, get_scenario
    from repro.serving.executor import RealExecutor

    ex = RealExecutor(arch, batch_lattice=tuple(batches),
                      quotas=tuple(quotas), prompt_len=prompt_len,
                      gen_len=gen_len, seed=seed)
    log(f"[serve-real] warming {arch} (reduced): "
        f"{len(ex.batch_lattice)} buckets x {len(ex.quotas)} quotas ...")
    w = ex.warmup()
    log(f"[serve-real] warmup: {w['warmup_compiles']} compiles in "
        f"{w['warmup_s']:.1f}s ({w['cells']} cache cells)")

    if profile_path:
        with open(profile_path) as f:
            artifact = json.load(f)
        if artifact.get("arch") != arch:
            raise SystemExit(f"profile {profile_path} is for "
                             f"{artifact.get('arch')!r}, not {arch!r}")
    else:
        artifact = build_artifact(ex, reps=reps, log=lambda *_: None)
    table = ProfileTable.from_measured(artifact)
    log(f"[serve-real] measured profile: lattice={table.batch_lattice} "
        f"t1={table.fn.t1_ms:.1f}ms provenance={table.fn.provenance}")

    apps = {arch: Workflow.pipeline(arch, [arch])}
    tables = {arch: table}
    profiles = {arch: table.fn}
    sched = ESGScheduler(apps, tables, risk_sigma=0.05)
    scaler = get_autoscaler(autoscaler) if autoscaler else None
    # one shareable-GPU host: capacity pressure is what makes the
    # planner walk the batch lattice instead of serving everything at
    # batch 1 — the point of replaying through both paths.
    # count_overhead=False keeps simulated time fully decoupled from
    # this host's wall clock: with it on, planner wall time (inflated
    # by the executor worker's GIL share) would leak into the very
    # predictions the real measurements are compared against.
    sim = ClusterSim(apps, tables, profiles, sched, n_invokers=1,
                     vcpus=8, vgpus=1, noise_sigma=0.0, seed=seed,
                     count_overhead=False, autoscaler=scaler, executor=ex)
    gw = Gateway(sim)
    # pace arrivals to the measured service time: the stock scenario
    # rates target zoo latencies (100s of ms) and a reduced arch at a
    # few ms/batch would never queue — i.e. never leave batch 1
    pace = max(table.fn.t1_ms / 2.0, 1.0)
    try:
        sc = get_scenario(scenario, app_names=[arch],
                          mean_interval_ms=pace)
    except TypeError:   # uniform-family scenarios have no rate knob
        sc = get_scenario(scenario, app_names=[arch])
    gw.inject(sc, n_requests, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario
    s = tel.summary()
    recs = ex.drain()
    ex.shutdown()

    # predicted (planner profile) vs measured (device wall) per cell
    by_cell: dict[tuple, list] = {}
    for r in recs:
        if r.tid >= 0:
            by_cell.setdefault((r.bucket, r.quota), []).append(r.wall_ms)
    cells, err_sum, err_n = [], 0.0, 0
    for (bucket, quota), walls in sorted(by_cell.items()):
        c = Config(bucket, 1, 1)
        predicted = table.fn.exec_ms(
            c, quota_vgpu=quota if quota < 1.0 else None)
        # floor estimator, matching the profiling side: wall noise on a
        # shared host is one-sided, so the minimum is the reproducible
        # statistic for both legs of the comparison
        measured = float(np.min(walls))
        err = abs(predicted - measured) / measured if measured else 0.0
        cells.append({"batch": bucket, "quota": quota,
                      "n_executed": len(walls), "predicted_ms": predicted,
                      "measured_ms": measured, "abs_err": err})
        err_sum += err * len(walls)
        err_n += len(walls)
    mean_abs_err = err_sum / err_n if err_n else 0.0
    stats = ex.stats()

    bench = {
        "schema": "repro.realcompute_bench.v1",
        "arch": arch,
        "reduced": True,
        "scenario": scenario,
        "n_requests": n_requests,
        "seed": seed,
        "slo_mult": slo_mult,
        "backend": artifact["backend"],
        "interpret": artifact["interpret"],
        "scale_note": "reduced arch on the host backend; latencies are "
                      "machine-dependent, ratios (hit rate, abs_err, "
                      "roofline fractions) are the regression surface",
        "profile": {k: artifact[k] for k in
                    ("batch_lattice", "quota_lattice", "prompt_len",
                     "gen_len")},
        "executor": stats,
        "cells": cells,
        "mean_abs_err": mean_abs_err,
        "roofline": artifact["roofline"],
        "quota_check": artifact["quota_check"],
        "telemetry": {
            "slo_attainment": s["slo_attainment"],
            "scheduler": s["scheduler"],
            "autoscaler": s["autoscaler"],
            "cold_starts": s["cold_starts"],
            "shed": s["shed"],
            "profile_provenance": s.get("profile_provenance", {}),
        },
    }
    log(f"[serve-real] {arch}(reduced)/{scenario}: "
        f"slo={s['slo_attainment']:.3f} executed={stats['executed']} "
        f"hit_rate={stats['post_warmup_hit_rate']} "
        f"mean_abs_err={mean_abs_err:.3f}")
    if bench_out:
        with open(bench_out, "w") as f:
            json.dump(bench, f, indent=2)
        log(f"[serve-real] wrote {bench_out}")
    return bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--setting", default="moderate-normal")
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default="esg",
                    choices=["esg", "infless", "fastgshare", "orion",
                             "aquatope"])
    from repro.serving.traces import SCENARIOS
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="serving scenario; omit for the legacy uniform "
                         "setting")
    ap.add_argument("--autoscaler", default=None,
                    choices=["ewma", "finegrained", "vertical", "none"],
                    help="warm-pool policy (default: ewma); 'vertical' "
                         "adds fractional vGPU resizing of running pools")
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped swap pipeline: restart penalties "
                         "become async PCIe transfer completions")
    ap.add_argument("--prefetch", action="store_true",
                    help="predictive next-stage weight prefetch "
                         "(requires --overlap)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-request spans and write a "
                         "Perfetto-loadable Chrome-trace JSON here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="record windowed metrics and write JSON "
                         "(or CSV if PATH ends in .csv) here")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="record the planner decision audit log "
                         "and write JSONL here")
    ap.add_argument("--calibrate", action="store_true",
                    help="close the pricing loop: correct the planner's "
                         "exec estimates online from the audit stream's "
                         "predicted-vs-realized records (ESG only)")
    ap.add_argument("--health-out", default=None, metavar="PATH",
                    help="run the SLO burn-rate health engine (alerts "
                         "feed the gateway + autoscaler) and write its "
                         "alert stream as JSONL here")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="(--real) measured batch lattice")
    ap.add_argument("--quotas", type=float, nargs="+", default=[1.0, 0.5],
                    help="(--real) measured fractional-quota lattice")
    ap.add_argument("--gen-len", type=int, default=4,
                    help="(--real) decode steps per request")
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="(--real) prompt length")
    ap.add_argument("--reps", type=int, default=2,
                    help="(--real) profiling reps per lattice cell")
    ap.add_argument("--profile", default=None, metavar="PATH",
                    help="(--real) load a measured-profile artifact "
                         "instead of profiling in-process")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="(--real) write the predicted-vs-measured "
                         "benchmark JSON (BENCH_realcompute.json) here")
    args = ap.parse_args()
    if args.real:
        serve_real(arch=args.arch, n_requests=args.n if args.n else 48,
                   scenario=args.scenario or "mmpp",
                   autoscaler=args.autoscaler, slo_mult=args.slo_mult
                   if args.slo_mult != 1.0 else 8.0, seed=args.seed,
                   gen_len=args.gen_len, prompt_len=args.prompt_len,
                   batches=tuple(args.batches), quotas=tuple(args.quotas),
                   profile_path=args.profile, reps=args.reps,
                   bench_out=args.bench_out)
    else:
        emulate(args.setting, args.n, seed=args.seed,
                scheduler=args.scheduler, scenario=args.scenario,
                autoscaler=args.autoscaler, slo_mult=args.slo_mult,
                overlap=args.overlap, prefetch=args.prefetch,
                trace_out=args.trace_out, metrics_out=args.metrics_out,
                audit_out=args.audit_out, calibrate=args.calibrate,
                health_out=args.health_out)


if __name__ == "__main__":
    main()
