"""Serving launcher: ESG scheduling over the model zoo.

Two modes:

  * ``--emulate`` (default): the paper's controller (ESG or a baseline)
    schedules LM-pipeline workflows onto the emulated 16-host TPU cluster,
    with per-arch latency profiles from the v5e roofline model
    (cluster/tpu_profiles).  This is the "assigned architectures as
    servable functions" configuration.

  * ``--real``: actually serves a *reduced* model on this host: requests
    arrive on an AFW queue, ESG_1Q picks the batch size from the profile
    lattice, and real JAX prefill+decode steps run per dispatched batch.
    End-to-end driver for examples/quickstart.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.emulator import ClusterSim
from repro.cluster.tpu_profiles import ServingSpec, TPUFunctionProfile, zoo_tables
from repro.cluster.workload import generate, min_config_latency
from repro.configs.registry import ARCH_IDS, ShapeSpec, get_config, reduced
from repro.core.profiles import Config, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import Workflow
from repro.models.model import RunOptions, get_model

# LM pipelines over the assigned architectures (DAG stage = one model)
ZOO_APPS = {
    "draft_verify": Workflow.pipeline(
        "draft_verify", ["rwkv6_1_6b", "internlm2_20b"]),
    "vlm_caption": Workflow.pipeline(
        "vlm_caption", ["internvl2_76b", "internlm2_1_8b"]),
    "code_review": Workflow.pipeline(
        "code_review", ["starcoder2_7b", "mixtral_8x22b"]),
    "music_tagging": Workflow.pipeline(
        "music_tagging", ["musicgen_medium", "hymba_1_5b",
                          "internlm2_1_8b"]),
}


def _make_scheduler(name: str, tables):
    if name == "esg":
        return ESGScheduler(ZOO_APPS, tables, risk_sigma=0.05)
    from repro.core.baselines.aquatope import AquatopeScheduler
    from repro.core.baselines.fastgshare import FaSTGShareScheduler
    from repro.core.baselines.infless import INFlessScheduler
    from repro.core.baselines.orion import OrionScheduler
    factories = {"infless": INFlessScheduler, "fastgshare": FaSTGShareScheduler,
                 "orion": OrionScheduler, "aquatope": AquatopeScheduler}
    return factories[name](ZOO_APPS, tables)


def emulate(setting: str = "moderate-normal", n: int = 200, seed: int = 0,
            scheduler: str = "esg", scenario: str | None = None,
            autoscaler: str | None = None, slo_mult: float = 1.0,
            overlap: bool = False, prefetch: bool = False,
            trace_out: str | None = None, metrics_out: str | None = None,
            audit_out: str | None = None, calibrate: bool = False,
            health_out: str | None = None,
            log=print) -> dict:
    """Emulated serving over the model zoo.

    Legacy mode (``scenario=None``) drives the paper's uniform-interval
    ``setting`` through ``cluster.workload.generate``.  Scenario mode runs
    the online-serving stack: ``serving.traces`` arrival engine behind the
    ``serving.gateway`` admission front end, with the warm-pool policy
    named by ``autoscaler`` (ewma | finegrained | vertical | none).

    Any of ``trace_out`` / ``metrics_out`` / ``audit_out`` /
    ``health_out`` attaches the flight recorder (``repro.obs``) and
    exports the Perfetto trace / metrics time-series / planner audit
    log / health-alert stream after the run.  ``calibrate=True`` closes
    the pricing loop: an online ``ProfileCalibrator`` subscribed to the
    audit stream corrects the planner's exec estimates per (app, stage)
    as the run progresses, and ``health_out`` additionally wires the
    SLO health engine's alerts into the gateway's admission check and
    the autoscaler's congestion hooks.
    """
    from repro.serving import Gateway, get_autoscaler, get_scenario

    tables = zoo_tables()
    profiles = {a: t.fn for a, t in tables.items()}
    sched = _make_scheduler(scheduler, tables)
    scaler = get_autoscaler(autoscaler) if autoscaler else None
    recorder = None
    health = None
    if trace_out or metrics_out or audit_out or calibrate or health_out:
        from repro.obs import HealthEngine, ProfileCalibrator, Recorder
        if health_out is not None:
            health = HealthEngine()
        # calibration consumes the audit stream, so the audit log is on
        # whenever either consumer needs it
        recorder = Recorder(health=health)
        if calibrate:
            if not hasattr(sched, "calibrator"):
                raise SystemExit(f"--calibrate requires the ESG scheduler "
                                 f"(got {scheduler!r})")
            sched.calibrator = ProfileCalibrator().attach(recorder.audit)
    sim = ClusterSim(ZOO_APPS, tables, profiles, sched, seed=seed,
                     autoscaler=scaler, overlap=overlap, prefetch=prefetch,
                     recorder=recorder)
    if health is not None and scaler is not None:
        scaler.health = health

    def _export():
        if recorder is None:
            return
        written = recorder.export(trace_out, metrics_out, audit_out,
                                  health_out)
        for kind, path in written.items():
            log(f"[obs] wrote {kind} -> {path}")
        cal = getattr(sched, "calibrator", None)
        if cal is not None:
            log(f"[obs] calibration: {cal.observations} observations, "
                f"{cal.updates} published factor updates")
        if health is not None:
            hs = health.summary()
            log(f"[obs] health: {hs['alerts_total']} alert transitions, "
                f"active={hs['active'] or 'none'}")

    if scenario is None:
        generate(sim, setting, n, profiles, seed=seed + 1)
        sim.run()
        s = sim.summary()
        log(f"[serve-emulate] {s['scheduler']}: hit={s['slo_hit_rate']:.3f} "
            f"cost=${s['total_cost']:.4f} mean_lat={s['mean_latency_ms']:.0f}ms "
            f"sched_ovh={s['mean_sched_overhead_ms']:.2f}ms")
        _export()
        return s
    gw = Gateway(sim, health=health)
    sc = get_scenario(scenario, app_names=list(ZOO_APPS))
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario
    s = tel.summary()
    log(f"[serve-scenario] {scenario}/{s['scheduler']}/{s['autoscaler']}: "
        f"slo={s['slo_attainment']:.3f} $/1k={s['cost_per_1k']:.4f} "
        f"cold={s['cold_starts']} shed={s['shed']} "
        f"p95={s['latency']['p95_ms']:.0f}ms")
    _export()
    return s


def serve_real(arch: str = "internlm2_1_8b", n_requests: int = 48,
               slo_ms: float = 4000.0, mean_interval_ms: float = 50.0,
               gen_len: int = 8, prompt_len: int = 32, seed: int = 0,
               log=print) -> dict:
    """Serve a reduced model with ESG-batched requests (real compute)."""
    from repro.core.astar import esg_1q

    cfg = reduced(get_config(arch))
    opts = RunOptions(remat="none", attn_chunk=64,
                      param_dtype=jnp.float32, act_dtype=jnp.float32)
    model = get_model(cfg, opts)
    params = model.init(jax.random.PRNGKey(seed))

    # profile lattice: measure real batch latencies once (the "profiles")
    lat = {}
    rng = np.random.default_rng(seed)
    batches = (1, 2, 4, 8, 16)

    def run_batch_params(bs: int) -> float:
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (bs, prompt_len)), jnp.int32)
        t0 = time.perf_counter()
        logits, cache = model.prefill(params, {"tokens": toks},
                                      max_len=prompt_len + gen_len)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for _ in range(gen_len):
            logits, cache = model.decode(params, cache, nxt)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        return (time.perf_counter() - t0) * 1e3

    for bs in batches:
        run_batch_params(bs)                       # warm the jit caches
        lat[bs] = run_batch_params(bs)
    log(f"[serve-real] measured profile (ms/task): "
        + ", ".join(f"b{b}={lat[b]:.0f}" for b in batches))

    # one-stage ProfileTable over the measured lattice (1 vcpu, 1 vtpu host)
    class Measured(ProfileTable):
        pass
    from repro.core.profiles import FunctionProfile
    fp = FunctionProfile(arch, lat[1], 0.0, 0.01)
    cfgs = [Config(b, 1, 1) for b in batches]
    times = np.array([lat[b] for b in batches])
    costs = times / np.array(batches) * 1e-6
    order = np.argsort(times, kind="stable")
    table = ProfileTable(fp, [cfgs[i] for i in order], times[order],
                         costs[order])

    # arrival loop: AFW queue + ESG_1Q batching
    arrivals = np.cumsum(rng.exponential(mean_interval_ms, n_requests))
    queue: list[tuple[int, float]] = []
    done: list[tuple[float, float]] = []           # (latency, deadline_slack)
    t_start = time.perf_counter()
    i = 0
    while len(done) < n_requests:
        now = (time.perf_counter() - t_start) * 1e3
        while i < n_requests and arrivals[i] <= now:
            queue.append((i, arrivals[i]))
            i += 1
        if not queue:
            time.sleep(0.002)
            continue
        oldest = min(a for _, a in queue)
        g_slo = max(slo_ms - (now - oldest), 1.0)
        plans = esg_1q([table.restrict_batch(len(queue))], g_slo, k=3)
        bs = plans[0].configs[0].batch if plans else 1
        taken, queue = queue[:bs], queue[bs:]
        run_batch_params(len(taken))
        t_done = (time.perf_counter() - t_start) * 1e3
        for _, arr in taken:
            done.append((t_done - arr, slo_ms - (t_done - arr)))
    lats = np.array([d[0] for d in done])
    hit = float((lats <= slo_ms).mean())
    out = {"n": n_requests, "hit_rate": hit,
           "p50_ms": float(np.percentile(lats, 50)),
           "p95_ms": float(np.percentile(lats, 95))}
    log(f"[serve-real] {arch}(reduced): hit={hit:.2f} "
        f"p50={out['p50_ms']:.0f}ms p95={out['p95_ms']:.0f}ms")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--setting", default="moderate-normal")
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default="esg",
                    choices=["esg", "infless", "fastgshare", "orion",
                             "aquatope"])
    from repro.serving.traces import SCENARIOS
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="serving scenario; omit for the legacy uniform "
                         "setting")
    ap.add_argument("--autoscaler", default=None,
                    choices=["ewma", "finegrained", "vertical", "none"],
                    help="warm-pool policy (default: ewma); 'vertical' "
                         "adds fractional vGPU resizing of running pools")
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped swap pipeline: restart penalties "
                         "become async PCIe transfer completions")
    ap.add_argument("--prefetch", action="store_true",
                    help="predictive next-stage weight prefetch "
                         "(requires --overlap)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-request spans and write a "
                         "Perfetto-loadable Chrome-trace JSON here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="record windowed metrics and write JSON "
                         "(or CSV if PATH ends in .csv) here")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="record the planner decision audit log "
                         "and write JSONL here")
    ap.add_argument("--calibrate", action="store_true",
                    help="close the pricing loop: correct the planner's "
                         "exec estimates online from the audit stream's "
                         "predicted-vs-realized records (ESG only)")
    ap.add_argument("--health-out", default=None, metavar="PATH",
                    help="run the SLO burn-rate health engine (alerts "
                         "feed the gateway + autoscaler) and write its "
                         "alert stream as JSONL here")
    args = ap.parse_args()
    if args.real:
        serve_real(arch=args.arch, n_requests=args.n if args.n else 48)
    else:
        emulate(args.setting, args.n, seed=args.seed,
                scheduler=args.scheduler, scenario=args.scenario,
                autoscaler=args.autoscaler, slo_mult=args.slo_mult,
                overlap=args.overlap, prefetch=args.prefetch,
                trace_out=args.trace_out, metrics_out=args.metrics_out,
                audit_out=args.audit_out, calibrate=args.calibrate,
                health_out=args.health_out)


if __name__ == "__main__":
    main()
