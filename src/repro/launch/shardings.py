"""Sharding rules: DP (+pod) x TP (+EP/SP) for every family.

Conventions (see DESIGN §5):
  * batch shards over the dp axes ("pod","data") — unless the global batch is
    smaller than the dp extent (long_500k decode), in which case the KV/state
    sequence dim takes the parallelism instead (SP).
  * weights are 2-D sharded: one dim over "model" (TP), one over "data"
    (FSDP/ZeRO); replicated over "pod" (grad all-reduce crosses DCN).
  * MoE experts shard over "model" when divisible (EP), else the per-expert
    hidden dim takes TP.
  * optimizer moments additionally shard their FSDP dim over "pod"
    (ZeRO-1 across pods).
  * GSPMD padding handles non-divisible extents (36 heads / 16 shards etc.),
    verified in the dry-run.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ModelConfig, ShapeSpec
from repro.launch.mesh import dp_axes

TP = "model"
FSDP = "data"


def tp_applies(cfg: ModelConfig, shape: ShapeSpec, mode: str = "auto") -> bool:
    """Per-arch TP policy.  For small models (d_model < 2048) tensor
    parallelism over 16 chips leaves every matmul shard tiny and the
    per-layer TP all-reduces dominate (musicgen train: 4.2s collectives vs
    0.34s compute).  Such archs train pure-DP: batch over both mesh axes,
    weights FSDP-sharded over 'data' and replicated over 'model'."""
    if mode == "2d":
        return True
    if mode == "dp_only":
        return False
    return not (shape.kind == "train" and cfg.d_model <= 2048
                and shape.global_batch >= 256)


def strip_tp(pspecs):
    def strip(spec: P) -> P:
        return P(*[None if e == TP else e for e in spec])
    return jax.tree.map(strip, pspecs, is_leaf=lambda x: isinstance(x, P))


def strip_fsdp(pspecs):
    def strip(spec: P) -> P:
        return P(*[None if e == FSDP else e for e in spec])
    return jax.tree.map(strip, pspecs, is_leaf=lambda x: isinstance(x, P))


def weight_stationary_serving(cfg: ModelConfig) -> bool:
    """Serving wants the full TP weight slice resident per chip: FSDP
    sharding re-gathers every weight over ICI each decode step (86 ms/step
    for internlm2-20b — §Perf).  Applies when the bf16 TP slice fits
    comfortably next to the KV cache (<= 4 GB/chip)."""
    return cfg.n_params * 2 / 16 <= 4e9


def _transformer_layer_rules(cfg: ModelConfig) -> dict[str, P]:
    ep = bool(cfg.n_experts) and cfg.n_experts % 16 == 0
    rules = {
        "wq": P(None, None, FSDP, TP),
        "wk": P(None, None, FSDP, TP),
        "wv": P(None, None, FSDP, TP),
        "wo": P(None, None, TP, FSDP),
        "bq": P(None, None, TP),
        "bk": P(None, None, TP),
        "bv": P(None, None, TP),
        "w1": P(None, None, FSDP, TP),
        "w2": P(None, None, FSDP, TP),
        "w3": P(None, None, TP, FSDP),
        "b1": P(None, None, TP),
        "b3": P(None, None, None),
        "router": P(None, None, FSDP, None),
        "moe_w1": P(None, None, TP, FSDP, None) if ep
        else P(None, None, None, FSDP, TP),
        "moe_w2": P(None, None, TP, FSDP, None) if ep
        else P(None, None, None, FSDP, TP),
        "moe_w3": P(None, None, TP, None, FSDP) if ep
        else P(None, None, None, TP, FSDP),
    }
    for n in ("ln1_scale", "ln1_bias", "ln2_scale", "ln2_bias"):
        rules[n] = P(None, None, None)
    return rules


def _rwkv_layer_rules(cfg: ModelConfig) -> dict[str, P]:
    return {
        "wr": P(None, FSDP, TP), "wk": P(None, FSDP, TP),
        "wv": P(None, FSDP, TP), "wg": P(None, FSDP, TP),
        "wo": P(None, TP, FSDP),
        "wck": P(None, FSDP, TP), "wcv": P(None, TP, FSDP),
        "wcr": P(None, FSDP, TP),
        "wmix_a": P(None, FSDP, None), "wmix_b": P(None, None, None, FSDP),
        "wdec_a": P(None, FSDP, None), "wdec_b": P(None, None, FSDP),
        "u": P(None, TP, None),
        "mu_x": P(None, None), "mu_rkvwg": P(None, None, None),
        "w0": P(None, None),
        "ln1_scale": P(None, None), "ln1_bias": P(None, None),
        "ln2_scale": P(None, None), "ln2_bias": P(None, None),
        "gn_scale": P(None, None), "gn_bias": P(None, None),
        "mu_ck": P(None, None), "mu_cr": P(None, None),
    }


def _hymba_layer_rules(cfg: ModelConfig) -> dict[str, P]:
    return {
        "wq": P(None, FSDP, TP), "wk": P(None, FSDP, TP),
        "wv": P(None, FSDP, TP), "wo_attn": P(None, TP, FSDP),
        "w_in": P(None, FSDP, TP),
        "w_dt": P(None, FSDP, TP), "b_dt": P(None, None),
        "w_B": P(None, FSDP, None), "w_C": P(None, FSDP, None),
        "a_log": P(None, TP, None), "d_skip": P(None, None),
        "w_out": P(None, TP, FSDP),
        "fuse_attn_scale": P(None, None), "fuse_ssm_scale": P(None, None),
        "ln1_scale": P(None, None), "ln2_scale": P(None, None),
        "w1": P(None, FSDP, TP), "w2": P(None, FSDP, TP),
        "w3": P(None, TP, FSDP),
    }


def param_pspecs(cfg: ModelConfig) -> dict:
    if cfg.family == "ssm":
        layer = _rwkv_layer_rules(cfg)
    elif cfg.family == "hybrid":
        layer = _hymba_layer_rules(cfg)
    else:
        layer = _transformer_layer_rules(cfg)
    top = {
        "embed": P(TP, FSDP),
        "lm_head": P(TP, FSDP),
        "final_norm_scale": P(None),
        "final_norm_bias": P(None),
    }

    def build(tree, rules):
        return {k: rules[k] for k in tree}

    from repro.models.model import get_model
    specs = get_model(cfg).param_specs()
    out: dict[str, Any] = {"layers": build(specs["layers"], layer)}
    for k in specs:
        if k != "layers":
            out[k] = top[k]
    return out


def moment_pspecs(cfg: ModelConfig, multi_pod: bool) -> dict:
    """Optimizer moments: FSDP dim additionally sharded over 'pod' (ZeRO-1)."""
    base = param_pspecs(cfg)
    if not multi_pod:
        return base

    def widen(spec: P) -> P:
        return P(*[(FSDP, "pod") if e == FSDP else e for e in spec])

    return jax.tree.map(widen, base,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 dp=None) -> dict:
    dp = dp if dp is not None else dp_axes(mesh)
    dp_extent = 1
    for a in dp:
        dp_extent *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    bdim = dp if shape.global_batch >= dp_extent else None
    out = {"tokens": P(bdim, None)}
    if shape.kind == "train":
        out["labels"] = P(bdim, None)
    from repro.models.model import get_model
    specs = get_model(cfg).input_specs(shape)
    if "prefix_embeds" in specs:
        out["prefix_embeds"] = P(bdim, None, None)
    return out


def cache_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """KV caches: batch over dp (when it fits), sequence over 'model' (SP for
    decode — the softmax combine lowers to an all-reduce, flash-decoding
    style).  SSM states: heads/channels over 'model'."""
    dp = dp_axes(mesh)
    dp_extent = 1
    for a in dp:
        dp_extent *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    b = dp if shape.global_batch >= dp_extent else None
    seq = TP

    from repro.models.model import get_model
    specs = get_model(cfg).cache_specs(shape.global_batch, shape.seq_len)
    out: dict[str, P] = {}
    for k, s in specs.items():
        nd = len(s.shape)
        if k == "t":
            out[k] = P()
        elif k in ("k", "v") and cfg.family == "hybrid":
            out[k] = P(None, b, seq, None, None)            # (L,B,W,KV,DH)
        elif k in ("k", "v", "k_local", "v_local", "k_global", "v_global"):
            out[k] = P(None, None, b, seq, None, None)      # (nm,m,B,S,KV,DH)
        elif k == "wkv":
            out[k] = P(None, b, TP, None, None)             # (L,B,H,K,V)
        elif k == "ssm":
            out[k] = P(None, b, TP, None)                   # (L,B,D,N)
        elif k in ("tm", "cm"):
            out[k] = P(None, b, None)                       # (L,B,D)
        else:
            out[k] = P(*([None] * nd))
    return out


def to_shardings(tree_pspecs, mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspec(spec: P, shape: tuple, mesh) -> P:
    """Drop sharded axes whose extent is not divisible by the mesh axes —
    explicit pjit in_shardings demand divisibility (internal
    with_sharding_constraint tolerates GSPMD padding, arguments don't).
    E.g. hymba's vocab 32001 cannot take the 16-way 'model' axis."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def sanitize_tree(pspecs, shape_specs, mesh):
    """Apply sanitize_pspec leaf-wise (shape_specs: matching tree of
    ShapeDtypeStructs)."""
    return jax.tree.map(
        lambda p, s: sanitize_pspec(p, s.shape, mesh),
        pspecs, shape_specs,
        is_leaf=lambda x: isinstance(x, P))
