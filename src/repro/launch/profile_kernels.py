"""Measured-profile pipeline: time the real kernels over the lattice.

Times actual jax/Pallas execution (via ``serving.executor.RealExecutor``:
flash_attention prefill + scalar-prefetch flash_decode / WKV6 decode)
across the (batch-bucket, quota) lattice and emits a
``repro.measured_profile.v1`` JSON artifact that
``ProfileTable.from_measured`` loads in place of the zoo numbers.

Two cross-checks ride along in the artifact:

* **Roofline** — each quota-1.0 cell is compared against the analytic
  v5e lower bound from ``launch/roofline.py`` (``model_flops`` /
  ``analytic_memory_bytes``).  On the CPU interpret backend the measured
  time sits far above the TPU bound, so the fractions are *recorded*,
  not asserted; on real hardware they become a sanity gate.
* **Quota exponent** — the fractional-quota slowdown measured from the
  serialized-pass emulation is fit to the profile model's power law and
  reported next to ``QUOTA_SLOWDOWN_EXP``.

CLI::

    PYTHONPATH=src python -m repro.launch.profile_kernels \
        --arch internlm2_1_8b --out BENCH_profile.json --smoke
"""
from __future__ import annotations

import argparse
import json
import math

from repro.configs.registry import ShapeSpec
from repro.core.profiles import QUOTA_SLOWDOWN_EXP
from repro.launch.roofline import model_flops, roofline


def roofline_check(executor, bucket: int, measured_ms: float,
                   stage: str) -> dict:
    """Compare one measured quota-1.0 cell against the analytic v5e
    roofline bound for the same (reduced) config and shape."""
    cfg = executor.cfg
    seq = executor.prompt_len if stage == "prefill" else 1
    kind = "prefill" if stage == "prefill" else "decode"
    shape = ShapeSpec(f"profile_{stage}", seq_len=seq,
                      global_batch=bucket, kind=kind)
    terms = roofline(cfg, shape,
                     flops_per_device=model_flops(cfg, shape),
                     bytes_hlo_upper=0.0,   # analytic memory model only
                     wire_bytes_per_device=0.0, n_chips=1)
    bound_ms = terms.bound_s * 1e3
    if stage == "decode":                  # per decode step
        measured_ms = measured_ms / max(executor.gen_len, 1)
    return {
        "stage": stage,
        "batch": bucket,
        "bound_ms": bound_ms,
        "measured_ms": measured_ms,
        "bound_fraction": bound_ms / measured_ms if measured_ms else 0.0,
        "dominant": terms.dominant,
    }


def quota_exponent(cells: list[dict]) -> dict:
    """Fit measured quota slowdowns to ``(1/q)^alpha`` per bucket and
    report the mean exponent next to the profile model's constant."""
    base = {c["batch"]: c["e2e_ms"] for c in cells if c["quota"] == 1.0}
    exps = []
    for c in cells:
        q = c["quota"]
        if q >= 1.0 or c["batch"] not in base or base[c["batch"]] <= 0:
            continue
        slowdown = c["e2e_ms"] / base[c["batch"]]
        if slowdown > 0:
            exps.append(math.log(slowdown) / math.log(1.0 / q))
    if not exps:
        return {"model_exponent": QUOTA_SLOWDOWN_EXP,
                "measured_exponent": None, "n_points": 0}
    mean = sum(exps) / len(exps)
    return {
        "model_exponent": QUOTA_SLOWDOWN_EXP,
        "measured_exponent": mean,
        "max_abs_dev": max(abs(e - mean) for e in exps),
        "n_points": len(exps),
    }


def build_artifact(executor, reps: int = 3, cold_ms: float = 0.0,
                   input_mb: float = 0.01, log=print) -> dict:
    """Measure every (bucket, quota) lattice cell on an already-warmed
    :class:`RealExecutor` and assemble the ``repro.measured_profile.v1``
    artifact ``ProfileTable.from_measured`` consumes."""
    import jax

    if not executor._warmed:
        executor.warmup()
    cells, checks = [], []
    for bucket in executor.batch_lattice:
        for quota in executor.quotas:
            rec = executor.measure(bucket, quota, reps=reps)
            cells.append({
                "batch": bucket,
                "quota": quota,
                "prefill_ms": rec.prefill_ms,
                "decode_ms": rec.decode_ms,
                "e2e_ms": rec.wall_ms,
                "reps": reps,
            })
            log(f"  cell batch={bucket} quota={quota}: "
                f"{rec.wall_ms:.2f} ms ({rec.prefill_ms:.2f} prefill + "
                f"{rec.decode_ms:.2f} decode)")
            if quota == 1.0:
                checks.append(roofline_check(
                    executor, bucket, rec.prefill_ms, "prefill"))
                checks.append(roofline_check(
                    executor, bucket, rec.decode_ms, "decode"))
    backend = jax.default_backend()
    return {
        "schema": "repro.measured_profile.v1",
        "arch": executor.arch,
        "reduced": True,
        "backend": backend,
        "interpret": backend != "tpu",
        "prompt_len": executor.prompt_len,
        "gen_len": executor.gen_len,
        "batch_lattice": list(executor.batch_lattice),
        "quota_lattice": list(executor.quotas),
        "cells": cells,
        "roofline": checks,
        "quota_check": quota_exponent(cells),
        "cold_ms": cold_ms,
        "input_mb": input_mb,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="Measure real kernel latencies over the batch/quota "
                    "lattice and emit a measured-profile artifact")
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--quotas", type=float, nargs="+", default=[1.0, 0.5])
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny lattice (batches 1,2; quota 1.0; 1 rep)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batches, args.quotas, args.reps = [1, 2], [1.0], 1

    from repro.serving.executor import RealExecutor

    ex = RealExecutor(args.arch, batch_lattice=tuple(args.batches),
                      quotas=tuple(args.quotas),
                      prompt_len=args.prompt_len, gen_len=args.gen_len,
                      seed=args.seed)
    print(f"[profile] warming {args.arch} "
          f"({len(args.batches)} buckets x {len(ex.quotas)} quotas) ...")
    w = ex.warmup()
    print(f"[profile] warmup: {w['warmup_compiles']} compiles, "
          f"{w['warmup_s']:.1f}s, {w['cells']} cache cells")
    artifact = build_artifact(ex, reps=args.reps)
    ex.shutdown()
    qc = artifact["quota_check"]
    if qc["measured_exponent"] is not None:
        print(f"[profile] quota exponent: measured "
              f"{qc['measured_exponent']:.3f} vs model "
              f"{qc['model_exponent']} ({qc['n_points']} points)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[profile] wrote {args.out}")
    return artifact


if __name__ == "__main__":
    main()
