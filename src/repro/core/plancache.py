"""Memoized dominator-budget plan cache for the ESG planner.

ESG re-plans at every stage dispatch (the paper's optimality-guided
adaptive behaviour), but the inputs of those ESG_1Q searches repeat
heavily: the same app keeps arriving, the dominator-based SLO
distribution hands every (app, stage) the same budget *fraction*, and
uncongested queues are planned at ``w == 0`` so even the absolute budget
repeats.  This cache memoizes search results keyed on

    (workflow, remaining-stage suffix, batch bucket, penalty signature)

— the scheduler appends a fifth axis, the online calibrator's published
correction-factor tuple, whenever calibration is active (any factor
!= 1.0), so every published calibration step makes previously cached
plans unreachable rather than stale — plus the G_SLO budget — and the budget axis is quantized into exactly
three *sound* buckets, derived from the structure of ESG_1Q's output as
a function of the budget (the result is a step function of G_SLO, and
two of its steps have certifiable extents):

  * **floor**       — ``g_slo <= t_min`` (the summed per-stage minimum
    latency): the search is infeasible and returns the best-effort
    fastest path.  One precomputed result serves the whole bucket.
  * **budget-free** — ``g_slo > t_max``, where ``t_max`` is the slowest
    path among the K cheapest *unconstrained* paths (searched once with
    an infinite budget): every unconstrained winner is feasible, and
    the K cheapest feasible paths of a superset-feasible search are the
    K cheapest overall — so the unconstrained result is provably the
    answer for every budget in the bucket.  This is the common case the
    dominator split makes common: per-group quotas put same-stage
    budgets in the same (wide) slack regime run after run.
  * **exact**       — the middle regime (``t_min < g_slo <= t_max``),
    where the K-best set genuinely depends on the budget: memoized per
    exact budget value (repeat hits still come from ``w == 0`` arrivals
    sharing one SLO), never across budgets.

Quantization soundness caveat: the budget-free bucket returns the same
*path set* as a fresh search; if two distinct paths tie exactly on
(cost, time) the tie is broken by heap insertion order, which an
infinite-budget search may visit differently.  Profile-model costs are
continuous products, so exact cross-path ties do not occur in practice
(the differential tests replay every serving scenario cache-on vs
cache-off and require bit-identical schedules).

Batch caps are quantized to the profile table's batch lattice
(``ProfileTable.batch_lattice``): ``restrict_batch(n)`` returns the same
table for every ``n`` inside one lattice step, so the bucket is lossless
by construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Hashable, Optional, Sequence

from repro.core.astar import PathResult, esg_1q
from repro.core.profiles import ProfileTable


@dataclasses.dataclass
class CacheStats:
    hits_floor: int = 0
    hits_budget_free: int = 0
    hits_exact: int = 0
    misses: int = 0          # entry existed, budget fell in a new exact slot
    builds: int = 0          # prefix entry built (two searches)
    evictions: int = 0

    @property
    def hits(self) -> int:
        return self.hits_floor + self.hits_budget_free + self.hits_exact

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    def as_dict(self) -> dict[str, Any]:
        return {**dataclasses.asdict(self), "hits": self.hits,
                "lookups": self.lookups,
                "hit_rate": self.hits / self.lookups if self.lookups else 0.0}


@dataclasses.dataclass
class _Entry:
    """Per-(suffix, bucket, penalties) memo: the two certified buckets
    plus an exact-budget dict for the middle regime."""
    tables: list[ProfileTable]
    penalties: Optional[tuple[float, ...]]
    t_min: float                    # summed per-stage minimum (priced) latency
    floor: list[PathResult]         # result for every g_slo <= t_min
    budget_free: list[PathResult]   # result for every g_slo > t_max
    t_max: float                    # slowest unconstrained winner
    exact: dict[float, list[PathResult]] = dataclasses.field(
        default_factory=dict)


class PlanCache:
    """Plan memo over ``esg_1q`` searches.  ``lookup`` is a drop-in for
    running the search directly — same results, engine chosen by
    ``vectorized`` — with dict hits in the three budget regimes."""

    def __init__(self, k: int = 5, vectorized: bool = True,
                 max_entries: int = 2048, max_exact: int = 512):
        self.k = k
        self.vectorized = vectorized
        self.max_entries = max_entries
        self.max_exact = max_exact
        self._entries: dict[Hashable, _Entry] = {}
        self.stats = CacheStats()
        # budget regime the most recent ``lookup`` resolved in
        # ("floor" | "budget-free" | "exact" | "miss") — read by the
        # planner-audit recorder right after the call
        self.last_regime = ""

    # -- entry lifecycle ----------------------------------------------------
    def peek(self, key: Hashable) -> Optional[_Entry]:
        return self._entries.get(key)

    def _build(self, key: Hashable, tables: list[ProfileTable],
               penalties: Optional[Sequence[float]]) -> _Entry:
        pen = tuple(penalties) if penalties is not None else None
        # the infeasible branch ignores how far below t_min the budget is,
        # so any certainly-infeasible budget yields the floor result
        floor = esg_1q(tables, -math.inf, k=self.k, penalties_ms=penalties,
                       vectorized=self.vectorized)
        unconstrained = esg_1q(tables, math.inf, k=self.k,
                               penalties_ms=penalties,
                               vectorized=self.vectorized)
        entry = _Entry(tables=tables, penalties=pen,
                       t_min=floor[0].est_time_ms, floor=floor,
                       budget_free=unconstrained,
                       t_max=max(r.est_time_ms for r in unconstrained))
        if len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.stats.evictions += 1
        self._entries[key] = entry
        self.stats.builds += 1
        return entry

    # -- the lookup ---------------------------------------------------------
    def lookup(self, key: Hashable, g_slo_ms: float,
               tables: Callable[[], list[ProfileTable]] | list[ProfileTable],
               penalties: Optional[Sequence[float]] = None,
               stats: Optional[Any] = None) -> list[PathResult]:
        """Results of ``esg_1q(tables, g_slo_ms, k, penalties)``.

        ``tables`` may be a list or a zero-arg factory (only called on an
        entry build).  ``key`` must capture everything that determines
        the search besides the budget: the stage suffix, the batch
        bucket and the penalty signature.  ``stats`` (a
        ``repro.core.astar.SearchStats``) is threaded into the miss-path
        search only — cache hits do no search work by definition."""
        entry = self._entries.get(key)
        if entry is None:
            if callable(tables):
                tables = tables()
            entry = self._build(key, tables, penalties)
        if g_slo_ms <= entry.t_min:        # esg_1q's min_t[0] >= g_slo branch
            self.stats.hits_floor += 1
            self.last_regime = "floor"
            return entry.floor
        if g_slo_ms > entry.t_max:
            self.stats.hits_budget_free += 1
            self.last_regime = "budget-free"
            return entry.budget_free
        cached = entry.exact.get(g_slo_ms)
        if cached is not None:
            self.stats.hits_exact += 1
            self.last_regime = "exact"
            return cached
        self.stats.misses += 1
        self.last_regime = "miss"
        result = esg_1q(entry.tables, g_slo_ms, k=self.k,
                        penalties_ms=entry.penalties,
                        vectorized=self.vectorized, stats=stats)
        if len(entry.exact) >= self.max_exact:
            entry.exact.pop(next(iter(entry.exact)))
            self.stats.evictions += 1
        entry.exact[g_slo_ms] = result
        return result

    def budget_free_token(self, key: Hashable,
                          g_slo_ms: float) -> Optional[Hashable]:
        """A token identifying the plan a lookup would return, or None.

        Non-None only in the budget-free regime of an already-built
        entry, where the result is provably independent of the budget:
        two calls returning the same token are certified to produce
        identical candidate lists.  The event-sparse emulator uses this
        to prove a blocked queue's retry futile without re-searching."""
        entry = self._entries.get(key)
        if entry is None or not g_slo_ms > entry.t_max:
            return None
        return (key, "budget-free")
