"""Application workflows (DAGs of serverless DNN functions).

The paper's four evaluation applications are linear pipelines (§4.1); the
dominator machinery also supports general DAGs with splits/joins, which the
tests exercise with synthetic graphs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Workflow:
    """DAG over stage names.  ``edges[i]`` = successors of stage i.

    ``stages`` are (unique) stage ids; ``func_of[stage]`` = function name so
    one function can appear at multiple stages (AFW queues are per
    (app, stage), exactly the paper's per-app Deblur queues)."""
    name: str
    stages: tuple[str, ...]
    func_of: dict[str, str]
    edges: dict[str, tuple[str, ...]]

    @property
    def roots(self) -> list[str]:
        has_pred = {s for succ in self.edges.values() for s in succ}
        return [s for s in self.stages if s not in has_pred]

    @property
    def sinks(self) -> list[str]:
        return [s for s in self.stages if not self.edges.get(s)]

    def predecessors(self, stage: str) -> list[str]:
        return [s for s, succ in self.edges.items() if stage in succ]

    @classmethod
    def pipeline(cls, name: str, funcs: list[str]) -> "Workflow":
        stages = tuple(f"{i}:{f}" for i, f in enumerate(funcs))
        func_of = {s: f for s, f in zip(stages, funcs)}
        edges = {stages[i]: (stages[i + 1],) for i in range(len(stages) - 1)}
        edges[stages[-1]] = ()
        return cls(name, stages, func_of, edges)


# The paper's four applications (§4.1)
PAPER_APPS = {
    "image_classification": Workflow.pipeline(
        "image_classification",
        ["super_resolution", "segmentation", "classification"]),
    "depth_recognition": Workflow.pipeline(
        "depth_recognition",
        ["deblur", "super_resolution", "depth"]),
    "background_elimination": Workflow.pipeline(
        "background_elimination",
        ["super_resolution", "deblur", "background_removal"]),
    "expanded_image_classification": Workflow.pipeline(
        "expanded_image_classification",
        ["deblur", "super_resolution", "background_removal",
         "segmentation", "classification"]),
}
