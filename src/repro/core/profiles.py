"""Function profiles + the configuration lattice.

A *configuration* is (batch, vcpu, vgpu) — exactly the paper's 3-D space
(§1: the space grows from m^k to (m^k)^3 with sharable GPUs).  The default
lattice has 8 x 4 x 8 = 256 configurations per function, matching the
paper's overhead experiments ("each function has 256 configurations").

Profiles come from two sources:
  * the paper's Table 3 (six DNN image functions) via an analytical
    latency model calibrated to the measured minimum-config times;
  * the TPU model zoo, where the latency model is fed by roofline terms
    from the dry-run's ``cost_analysis`` (see repro/cluster/tpu_profiles.py).

The latency model satisfies the paper's qualitative structure:
  increasing in batch, decreasing in vcpu/vgpu, per-job time decreasing in
  batch (throughput), per-job cost decreasing in batch — producing the
  speed-cost tension the scheduler navigates.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Iterable, Optional

import numpy as np

from repro.gpu.footprints import PAPER_MODEL_MB

# Pricing (paper §4.1, following AWS EC2):
VCPU_PRICE_PER_H = 0.034
VGPU_PRICE_PER_H = 0.67

# Fractional-quota slowdown exponent: a container whose compute quota is
# throttled to ``q`` vGPUs (q may be fractional, resized while running)
# sees its GPU part scale by (vgpu/q)^QUOTA_SLOWDOWN_EXP — slightly
# sub-linear because kernel launch gaps absorb part of the throttling
# (HAS-GPU reports near-linear throughput in the SM quota).
QUOTA_SLOWDOWN_EXP = 0.9

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128)
VCPUS = (1, 2, 4, 8)
VGPUS = (1, 2, 3, 4, 5, 6, 7, 8)


@dataclasses.dataclass(frozen=True)
class Config:
    batch: int
    vcpu: int
    vgpu: int


@dataclasses.dataclass(frozen=True)
class FunctionProfile:
    """Per-function performance profile over the config lattice."""
    name: str
    t1_ms: float                 # exec time at (batch=1, 1 vCPU, 1 vGPU)
    cold_ms: float               # cold-start time
    input_mb: float              # stage input size (data-transfer model)
    cpu_frac: float = 0.2        # fraction of t1 spent on the CPU part
    model_mb: float = 0.0        # weight-checkpoint HBM footprint
    # intermediate-state checkpoint a preempted task can resume from
    # (0 => no checkpointing: a spot reclamation re-runs from scratch)
    checkpoint_mb: float = 0.0
    # where the numbers came from: "zoo" (hand-entered / analytical) or
    # "measured" (timed real-kernel execution, see launch/profile_kernels)
    # — threaded through Telemetry.summary() and the planner audit log so
    # every export names its latency ground truth
    provenance: str = "zoo"

    def quota_factor(self, c: Config, quota_vgpu: Optional[float]) -> float:
        """GPU-part slowdown when the running container's compute quota
        is ``quota_vgpu`` (fractional vGPUs) instead of the ``c.vgpu``
        it was configured for.  >1 when throttled below the config,
        <1 when granted surplus slices (vertical scale-up)."""
        if quota_vgpu is None or quota_vgpu == c.vgpu:
            return 1.0
        return (c.vgpu / max(quota_vgpu, 1e-9)) ** QUOTA_SLOWDOWN_EXP

    def exec_ms(self, c: Config,
                quota_vgpu: Optional[float] = None) -> float:
        """Deterministic latency model (noise added by the emulator).

        Multi-accelerator tasks both data-parallelise the batch
        (ceil(b/g) per unit) and tensor-parallelise each inference
        (g^-0.2 — the TPU-substrate adaptation: a pjit sub-mesh speeds up a
        single inference, unlike MIG; see DESIGN §2).  Efficiency loss from
        collectives is folded into the sub-linear exponents.

        ``quota_vgpu`` (fractional) overrides the *delivered* compute
        share when a running pool has been vertically resized away from
        its configured ``c.vgpu``."""
        t_serial = 0.05 * self.t1_ms                 # launch/framework floor
        t_cpu = self.cpu_frac * self.t1_ms
        t_gpu = (0.95 - self.cpu_frac) * self.t1_ms
        per_gpu_batch = int(np.ceil(c.batch / c.vgpu))
        cpu_part = t_cpu * (c.batch ** 0.2) / (c.vcpu ** 0.7)
        gpu_part = t_gpu * (per_gpu_batch ** 0.85) * (c.vgpu ** -0.12)
        gpu_part *= self.quota_factor(c, quota_vgpu)
        return t_serial + cpu_part + gpu_part

    def cost(self, c: Config) -> float:
        """$ for the whole task (batch of jobs) at config c."""
        rate = c.vcpu * VCPU_PRICE_PER_H + c.vgpu * VGPU_PRICE_PER_H
        return rate * self.exec_ms(c) / 3.6e6

    def job_cost(self, c: Config) -> float:
        return self.cost(c) / c.batch


@dataclasses.dataclass(frozen=True)
class MeasuredFunctionProfile(FunctionProfile):
    """Profile backed by a measured (batch, quota) latency lattice.

    ``lattice`` holds ``(batch, quota, exec_ms)`` triples timed on real
    kernel execution (``launch/profile_kernels.py``).  ``exec_ms``
    answers from the lattice instead of the analytical model: the batch
    rounds *up* to the nearest measured bucket — coherent with the
    real-compute executor, which pads dispatched batches to the same
    buckets so each (arch, stage, bucket, quota) cell compiles exactly
    once — and an unmeasured quota falls back to the measured full-quota
    cell scaled by the analytical ``quota_factor``.
    """
    lattice: tuple = ()          # ((batch, quota, exec_ms), ...)
    provenance: str = "measured"

    def __post_init__(self):
        cells = {(int(b), float(q)): float(ms) for b, q, ms in self.lattice}
        object.__setattr__(self, "_cells", cells)
        object.__setattr__(self, "_buckets",
                           tuple(sorted({b for b, _ in cells})))

    def _bucket(self, batch: int) -> int:
        for b in self._buckets:
            if batch <= b:
                return b
        return self._buckets[-1]

    def exec_ms(self, c: Config,
                quota_vgpu: Optional[float] = None) -> float:
        if not self._buckets:
            return super().exec_ms(c, quota_vgpu)
        bucket = self._bucket(c.batch)
        # waves beyond the largest measured bucket run back to back
        waves = int(np.ceil(c.batch / bucket)) if c.batch > bucket else 1
        q = (quota_vgpu / c.vgpu) if quota_vgpu is not None else 1.0
        ms = self._cells.get((bucket, round(q, 6)))
        if ms is None:
            base = self._cells.get((bucket, 1.0))
            if base is None:
                return super().exec_ms(c, quota_vgpu)
            ms = base * self.quota_factor(c, quota_vgpu)
        return ms * waves


# ---------------------------------------------------------------------------
# The six paper functions (Table 3)
# ---------------------------------------------------------------------------
_PAPER_T3 = {
    # name: (t1_ms, cold_ms, input_mb)
    "super_resolution": (86.0, 3503.0, 2.7),
    "segmentation": (293.0, 16510.0, 2.5),
    "deblur": (319.0, 22343.0, 1.1),
    "classification": (147.0, 18299.0, 0.147),
    "background_removal": (1047.0, 3729.0, 2.5),
    "depth": (828.0, 16479.0, 0.648),
}
PAPER_FUNCTIONS = {
    name: FunctionProfile(name, t1, cold, mb,
                          model_mb=PAPER_MODEL_MB[name])
    for name, (t1, cold, mb) in _PAPER_T3.items()
}


@dataclasses.dataclass
class ProfileTable:
    """Profiles for one function evaluated over the lattice, sorted by time."""
    fn: FunctionProfile
    configs: list[Config]
    times: np.ndarray            # ms, same order as configs
    job_costs: np.ndarray        # $ per job

    @classmethod
    def build(cls, fn: FunctionProfile,
              batches: Iterable[int] = BATCHES,
              vcpus: Iterable[int] = VCPUS,
              vgpus: Iterable[int] = VGPUS,
              max_batch: int | None = None) -> "ProfileTable":
        cfgs = [Config(b, c, g)
                for b, c, g in itertools.product(batches, vcpus, vgpus)
                if max_batch is None or b <= max_batch]
        times = np.array([fn.exec_ms(c) for c in cfgs])
        costs = np.array([fn.job_cost(c) for c in cfgs])
        order = np.argsort(times, kind="stable")
        return cls(fn,
                   [cfgs[i] for i in order],
                   times[order],
                   costs[order])

    @classmethod
    def from_measured(cls, artifact: dict) -> "ProfileTable":
        """Build a table from a measured-profile JSON artifact
        (``launch/profile_kernels.py`` schema ``repro.measured_profile.v1``).

        The config lattice is the measured batch lattice at (vcpu=1,
        vgpu=1) — the single-host serving shape the artifact was timed
        on; fractional quotas live on the profile's quota axis and are
        reached through ``exec_ms(c, quota_vgpu=...)``, mirroring how
        the emulator delivers vertical resizes."""
        cells = artifact["cells"]
        lattice = tuple((c["batch"], c["quota"], c["e2e_ms"])
                        for c in cells)
        full = {c["batch"]: c["e2e_ms"] for c in cells
                if c["quota"] == 1.0}
        if not full:
            raise ValueError("measured artifact has no quota=1.0 cells")
        fn = MeasuredFunctionProfile(
            name=artifact["arch"],
            t1_ms=full[min(full)],
            cold_ms=float(artifact.get("cold_ms", 0.0)),
            input_mb=float(artifact.get("input_mb", 0.01)),
            model_mb=float(artifact.get("model_mb", 0.0)),
            lattice=lattice)
        return cls.build(fn, batches=tuple(sorted(full)), vcpus=(1,),
                         vgpus=(1,))

    def restrict_batch(self, max_batch: int) -> "ProfileTable":
        keep = [i for i, c in enumerate(self.configs) if c.batch <= max_batch]
        return ProfileTable(self.fn,
                            [self.configs[i] for i in keep],
                            self.times[keep], self.job_costs[keep])

    def pareto(self) -> "ProfileTable":
        """(time, job_cost)-Pareto-optimal configs only.

        Beyond-paper optimisation: a dominated config can never appear in the
        cheapest feasible path (swap it for its dominator), so top-1 quality
        is preserved; ranks 2..K may differ (tests cover both modes)."""
        best = np.inf
        keep = []
        for i in range(len(self.configs)):      # already sorted by time
            if self.job_costs[i] < best - 1e-18:
                best = self.job_costs[i]
                keep.append(i)
        return ProfileTable(self.fn,
                            [self.configs[i] for i in keep],
                            self.times[keep], self.job_costs[keep])

    # -- cached per-config arrays (computed once; the table is immutable
    # after build, so these never go stale) ---------------------------------
    @functools.cached_property
    def rates(self) -> np.ndarray:
        """$-rate per config, aligned with ``times``/``job_costs``."""
        return np.array([c.vcpu * VCPU_PRICE_PER_H +
                         c.vgpu * VGPU_PRICE_PER_H for c in self.configs])

    @functools.cached_property
    def batch_sizes(self) -> np.ndarray:
        """Per-config batch size as floats, aligned with ``configs``."""
        return np.array([c.batch for c in self.configs], dtype=float)

    @functools.cached_property
    def batch_lattice(self) -> tuple[int, ...]:
        """Distinct batch sizes present, ascending — ``restrict_batch(n)``
        yields the same table for every ``n`` inside one lattice step, so
        callers can quantize batch caps to these buckets losslessly."""
        return tuple(sorted({c.batch for c in self.configs}))

    def priced_arrays(self, penalty_ms: float = 0.0
                      ) -> tuple[np.ndarray, np.ndarray]:
        """(times, job_costs) with a start penalty priced in — the array
        form of ``with_penalty`` (no per-config Python objects, no new
        table).  Zero penalty returns the table's own arrays."""
        if penalty_ms <= 0.0:
            return self.times, self.job_costs
        return (self.times + penalty_ms,
                self.job_costs + self.rates * penalty_ms / 3.6e6
                / self.batch_sizes)

    def scaled(self, factor: float) -> "ProfileTable":
        """Multiplicative exec-time rescale — the online calibrator's
        priced-arrays-compatible hook (``repro.obs.calibrate``).  Every
        config's latency scales by ``factor`` and so does its per-job
        cost (billed cost is $-rate x exec time, so cost honestly
        tracks the corrected runtime).  A positive factor preserves the
        time sort order and the job-cost argmin, so ESG_1Q's dual-blade
        pruning, ``pareto()`` filtering and the dominator split all
        operate on the corrected table unchanged.  Factor 1.0 returns
        ``self`` — the uncalibrated fast path stays allocation-free."""
        if factor == 1.0:
            return self
        if factor <= 0.0:
            raise ValueError(f"scale factor must be positive, got {factor}")
        return ProfileTable(self.fn, list(self.configs),
                            self.times * factor, self.job_costs * factor)

    def preempt_priced(self, exec_factor: float = 1.0,
                       risk_per_ms: float = 0.0) -> "ProfileTable":
        """Price a heterogeneous/preemptible fleet into both blades.

        ``exec_factor`` is the fleet's mean exec-time multiplier (the
        slice-weighted inverse of the SKU exec rates — >1 on a fleet
        slower than the profiled baseline).  ``risk_per_ms`` is the
        expected preemption-loss coefficient: a task running for T ms
        on spot capacity expects ~``risk_per_ms * T`` reclamations'
        worth of rework, so its effective latency inflates by
        ``(1 + risk_per_ms * T)`` — longer configs are penalised
        superlinearly, which is exactly the pressure that steers the
        planner toward shorter stages under reclamation risk.  Cost
        inflates identically (rework is billed again).  Both transforms
        are monotone in T, so the time sort order and dual-blade
        pruning survive.  Neutral arguments return ``self``."""
        if exec_factor <= 0.0 or risk_per_ms < 0.0:
            raise ValueError(
                f"bad preemption pricing ({exec_factor}, {risk_per_ms})")
        if exec_factor == 1.0 and risk_per_ms == 0.0:
            return self
        times = self.times * exec_factor
        inflate = 1.0 + risk_per_ms * times
        return ProfileTable(self.fn, list(self.configs), times * inflate,
                            self.job_costs * exec_factor * inflate)

    def with_penalty(self, penalty_ms: float) -> "ProfileTable":
        """Price a per-stage start penalty (a Torpor-style weight swap-in
        the placement is predicted to pay) into both A* blades: every
        config's latency shifts by ``penalty_ms`` (sort order preserved)
        and its per-job cost absorbs the penalty window billed at that
        config's $-rate — so dual-blade pruning compares true latencies
        and true costs, not profile-only ones."""
        if penalty_ms <= 0.0:
            return self
        times, costs = self.priced_arrays(penalty_ms)
        return ProfileTable(self.fn, list(self.configs), times, costs)

    @property
    def min_time(self) -> float:
        return float(self.times[0])

    @property
    def min_job_cost(self) -> float:
        return float(self.job_costs.min())

    @property
    def fastest_cost(self) -> float:
        """Job cost when running the fastest config (for rscFastest)."""
        return float(self.job_costs[0])

    def mean_time(self) -> float:
        return float(self.times.mean())
