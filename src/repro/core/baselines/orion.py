"""Orion [Mahgoub et al., OSDI'22] baseline, extended with vGPU (paper §4.2).

Best-first search over the joint per-stage configuration vector: the start
state is minimum config everywhere; each expansion bumps one dimension
(batch, vcpu or vgpu) of one stage; the goal is estimated P95 end-to-end
latency <= SLO; the cheapest goal state wins.  If the search exceeds the
cut-off time before reaching the goal, the state with latency closest to
the SLO is returned.

The whole-workflow plan is decided at the first stage's invocation and
never adapted (the paper's critique): later stages reuse the stored plan;
when the planned batch exceeds the queue length a *config miss* is recorded
(Table 4) and the batch is clipped.  Search runs once per (app, SLO) — the
result is deterministic — but its measured duration is charged to every
instance's first-stage latency, exactly what Fig 9 varies.
"""
from __future__ import annotations

import heapq
import itertools
import time as _walltime

import numpy as np

from repro.core.profiles import (BATCHES, VCPUS, VGPUS, Config, ProfileTable,
                                 VCPU_PRICE_PER_H, VGPU_PRICE_PER_H)
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy

P95_Z = 1.645


class OrionScheduler(SchedulerPolicy):
    name = "Orion"
    placement = "locality"
    static_plan = True

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 cutoff_ms: float = 100.0, noise_sigma: float = 0.05,
                 k: int = 1):
        self.tables = tables
        self.cutoff_ms = cutoff_ms
        self.noise_sigma = noise_sigma
        self._plans: dict[tuple[str, float], tuple[dict, float]] = {}
        self._charged_insts: set[int] = set()
        self.charged_overhead_ms = 0.0

    # ---- search -----------------------------------------------------------
    def _p95(self, app: Workflow, cfgs: dict[str, Config]) -> float:
        t = sum(self.tables[app.func_of[s]].fn.exec_ms(cfgs[s])
                for s in app.stages)
        return t * (1.0 + P95_Z * self.noise_sigma)

    def _cost(self, app: Workflow, cfgs: dict[str, Config]) -> float:
        out = 0.0
        for s in app.stages:
            c = cfgs[s]
            rate = c.vcpu * VCPU_PRICE_PER_H + c.vgpu * VGPU_PRICE_PER_H
            out += rate * self.tables[app.func_of[s]].fn.exec_ms(c) / 3.6e6 / c.batch
        return out

    def _search(self, app: Workflow, slo_ms: float) -> tuple[dict, float]:
        t0 = _walltime.perf_counter()
        dims = {"batch": BATCHES, "vcpu": VCPUS, "vgpu": VGPUS}
        start = tuple((1, 1, 1) for _ in app.stages)
        seen = {start}
        tie = itertools.count()

        def to_cfgs(state):
            return {s: Config(*state[i]) for i, s in enumerate(app.stages)}

        def score(state):
            cfgs = to_cfgs(state)
            return self._p95(app, cfgs), self._cost(app, cfgs)

        p95_0, cost_0 = score(start)
        heap = [(cost_0, next(tie), start, p95_0)]
        # Orion's "three rights": sizing targets P95 <= SLO; *bundling*
        # prefers consolidating invocations — among near-cost-tied feasible
        # states it picks the largest batch.  That preference is what makes
        # its static plans miss at runtime when queues are shorter than the
        # planned batch (Table 4).
        best_near = (abs(p95_0 - slo_ms), 0.0, start)
        feasible: list[tuple[float, tuple]] = []
        if p95_0 <= slo_ms:
            feasible.append((cost_0, start))
        while heap:
            if (_walltime.perf_counter() - t0) * 1e3 > self.cutoff_ms:
                break
            cost, _, state, p95 = heapq.heappop(heap)
            for i in range(len(app.stages)):
                for d, opts in enumerate(dims.values()):
                    vals = list(opts)
                    cur = state[i][d]
                    if cur not in vals or vals.index(cur) + 1 >= len(vals):
                        continue
                    nxt = vals[vals.index(cur) + 1]
                    ns = list(map(list, state))
                    ns[i][d] = nxt
                    ns = tuple(map(tuple, ns))
                    if ns in seen:
                        continue
                    seen.add(ns)
                    p, c = score(ns)
                    if p <= slo_ms:
                        feasible.append((c, ns))
                    if abs(p - slo_ms) < best_near[0]:
                        best_near = (abs(p - slo_ms), c, ns)
                    heapq.heappush(heap, (c, next(tie), ns, p))
        if feasible:
            c_min = min(c for c, _ in feasible)
            near_tied = [(s, c) for c, s in feasible if c <= 1.15 * c_min]
            state = max(near_tied,
                        key=lambda sc: (sum(b for b, _, _ in sc[0]), -sc[1]))[0]
        else:
            state = best_near[2]
        elapsed = (_walltime.perf_counter() - t0) * 1e3
        return to_cfgs(state), elapsed

    # ---- policy ------------------------------------------------------------
    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        slo = max(j.inst.slo_ms for j in jobs)
        key = (app.name, round(slo, 3))
        if key not in self._plans:
            self._plans[key] = self._search(app, slo)
        cfgs, search_ms = self._plans[key]
        # search latency charged once per instance, at its first stage
        self.charged_overhead_ms = 0.0
        if stage in app.roots:
            fresh = [j.inst.uid for j in jobs
                     if j.inst.uid not in self._charged_insts]
            if fresh:
                self._charged_insts.update(fresh)
                self.charged_overhead_ms = search_ms
        return [cfgs[stage]]
