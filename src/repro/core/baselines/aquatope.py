"""Aquatope [Zhou et al., ASPLOS'23] baseline, extended with vGPU (paper §4.2).

Bayesian-optimisation-style offline training: 100 bootstrap samples, then
50 rounds x 5 candidates guided by a k-NN surrogate with a UCB acquisition,
optimising  cost + penalty * P(e2e > SLO)  under the noisy latency model.
Offline training assumes saturating traffic (batches always fill), which is
exactly why its static plans miss at runtime when actual queues are shorter
than the planned batch (paper Table 4: 59-86% misses).

Deployment is static: the learned per-stage configs are used unchanged;
misses are recorded and the batch clipped.  Training happens once per
(app, SLO); scheduling overhead at runtime is negligible (paper §5.2).
"""
from __future__ import annotations

import numpy as np

from repro.core.profiles import (BATCHES, VCPUS, VGPUS, Config, ProfileTable,
                                 VCPU_PRICE_PER_H, VGPU_PRICE_PER_H)
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy

BOOTSTRAP = 100
ROUNDS = 50
PER_ROUND = 5
PENALTY = 10.0


class AquatopeScheduler(SchedulerPolicy):
    name = "Aquatope"
    placement = "locality"
    static_plan = True

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 noise_sigma: float = 0.05, seed: int = 0, k: int = 1):
        self.tables = tables
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)
        self._plans: dict[tuple[str, float], dict[str, Config]] = {}

    # ---- noisy objective ---------------------------------------------------
    def _objective(self, app: Workflow, cfgs: dict[str, Config],
                   slo_ms: float, n_eval: int = 8) -> float:
        lat = np.zeros(n_eval)
        cost = 0.0
        for s in app.stages:
            c = cfgs[s]
            t = self.tables[app.func_of[s]].fn.exec_ms(c)
            lat += t * np.clip(
                1.0 + self.rng.normal(0.0, self.noise_sigma, n_eval), 0.5, 2.0)
            rate = c.vcpu * VCPU_PRICE_PER_H + c.vgpu * VGPU_PRICE_PER_H
            cost += rate * t / 3.6e6 / c.batch
        viol = float((lat > slo_ms).mean())
        return cost * 1e4 + PENALTY * viol

    def _vec(self, cfgs, app):
        return np.array([[np.log2(cfgs[s].batch), cfgs[s].vcpu, cfgs[s].vgpu]
                         for s in app.stages]).ravel()

    def _train(self, app: Workflow, slo_ms: float) -> dict[str, Config]:
        def sample():
            return {s: Config(int(self.rng.choice(BATCHES)),
                              int(self.rng.choice(VCPUS)),
                              int(self.rng.choice(VGPUS)))
                    for s in app.stages}

        xs, ys, cfg_list = [], [], []
        for _ in range(BOOTSTRAP):
            cfgs = sample()
            xs.append(self._vec(cfgs, app))
            ys.append(self._objective(app, cfgs, slo_ms))
            cfg_list.append(cfgs)
        for _ in range(ROUNDS):
            cands = [sample() for _ in range(PER_ROUND * 4)]
            # k-NN surrogate + UCB: predicted mean - beta * nn-distance
            x_arr = np.stack(xs)
            y_arr = np.array(ys)
            scores = []
            for cfgs in cands:
                v = self._vec(cfgs, app)
                d = np.linalg.norm(x_arr - v, axis=1)
                nn = np.argsort(d)[:5]
                wgt = 1.0 / (d[nn] + 1e-6)
                mean = float((y_arr[nn] * wgt).sum() / wgt.sum())
                scores.append(mean - 0.5 * float(d[nn].min()))
            picked = np.argsort(scores)[:PER_ROUND]
            for p in picked:
                cfgs = cands[int(p)]
                xs.append(self._vec(cfgs, app))
                ys.append(self._objective(app, cfgs, slo_ms))
                cfg_list.append(cfgs)
        return cfg_list[int(np.argmin(ys))]

    # ---- policy ------------------------------------------------------------
    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        slo = max(j.inst.slo_ms for j in jobs)
        key = (app.name, round(slo, 3))
        if key not in self._plans:
            self._plans[key] = self._train(app, slo)
        return [self._plans[key][stage]]
