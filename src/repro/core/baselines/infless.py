"""INFless [Yang et al., ASPLOS'22] baseline (paper §4.2).

Per-function enumeration without inter-function relations: the app SLO is
distributed to stages proportionally to average service times (GrandSLAm
style, as the ESG paper does for it), then each stage independently picks —
among configs meeting its share — the one maximising *resource efficiency*
(throughput per $-rate).  Node selection minimises resource fragmentation
(handled by placement='fragmentation' in the emulator).
"""
from __future__ import annotations

from repro.core.profiles import Config, ProfileTable
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy
from repro.core.profiles import VCPU_PRICE_PER_H, VGPU_PRICE_PER_H


def service_time_shares(app: Workflow,
                        tables: dict[str, ProfileTable]) -> dict[str, float]:
    means = {s: tables[app.func_of[s]].mean_time() for s in app.stages}
    total = sum(means.values())
    return {s: m / total for s, m in means.items()}


class INFlessScheduler(SchedulerPolicy):
    name = "INFless"
    placement = "fragmentation"

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable], k: int = 5):
        self.tables = tables
        self.k = k
        self.shares = {n: service_time_shares(a, tables)
                       for n, a in apps.items()}

    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        share = self.shares[app.name][stage]
        slo = max(j.inst.slo_ms for j in jobs)
        stage_slo = slo * share
        tbl = self.tables[app.func_of[stage]].restrict_batch(max(len(jobs), 1))
        # among stage-SLO-feasible configs, maximise throughput — INFless's
        # resource-efficiency metric prefers saturating one invoker, which
        # over-allocates ("highest resource costs", paper §5.1/§5.2)
        scored = []
        for i, c in enumerate(tbl.configs):
            if tbl.times[i] >= stage_slo:
                continue
            thr = c.batch / tbl.times[i]
            rate = c.vcpu * VCPU_PRICE_PER_H + c.vgpu * VGPU_PRICE_PER_H
            scored.append((thr / (1.0 + 0.02 * rate), -tbl.times[i], i))
        scored.sort(reverse=True)
        if not scored:                                   # infeasible: fastest
            return [tbl.configs[0]]
        return [tbl.configs[i] for _, _, i in scored[: self.k]]
