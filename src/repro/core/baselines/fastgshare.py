"""FaST-GShare [Gu et al. 2023] baseline (paper §4.2).

Enumeration-based scheduling on throughput performance metrics, no
inter-function relations (same GrandSLAm SLO split as INFless), GPU
fragmentation-minimising node selection.
"""
from __future__ import annotations

from repro.core.profiles import Config, ProfileTable
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy
from repro.core.baselines.infless import service_time_shares


class FaSTGShareScheduler(SchedulerPolicy):
    name = "FaST-GShare"
    placement = "fragmentation"

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable], k: int = 5):
        self.tables = tables
        self.k = k
        self.shares = {n: service_time_shares(a, tables)
                       for n, a in apps.items()}

    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        share = self.shares[app.name][stage]
        slo = max(j.inst.slo_ms for j in jobs)
        stage_slo = slo * share
        tbl = self.tables[app.func_of[stage]].restrict_batch(max(len(jobs), 1))
        scored = []
        for i, c in enumerate(tbl.configs):
            if tbl.times[i] >= stage_slo:
                continue
            thr = c.batch / tbl.times[i]                 # pure throughput
            scored.append((thr, -c.vgpu, i))
        scored.sort(reverse=True)
        if not scored:
            return [tbl.configs[0]]
        return [tbl.configs[i] for _, _, i in scored[: self.k]]
