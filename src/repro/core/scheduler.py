"""The ESG scheduling policy — the paper's contribution, wired together.

Per queue-scheduling call (paper Fig 2(d)):
  1. locate the stage's schedule group + SLO quota (dominator-based
     distribution, computed once per app),
  2. G_SLO = (deadline - now) x q̂, with q̂ the group quota normalised over
     the not-yet-finished groups (the paper's (SLO - w) x q with the quota
     re-normalised so early finishes benefit later stages; see DESIGN §1),
  3. ESG_1Q (A* + dual-blade pruning) over the remaining stages of the
     group, the current stage's batch capped by the queue length,
  4. return the top-K *current-stage* configs as the configuration priority
     queue — the emulator's dispatcher walks it (ESG_Dispatch), falling back
     through candidates, then to the recheck list.

ESG re-plans at *every* stage dispatch — the paper's optimality-guided
adaptive behaviour (vs Orion/Aquatope's static whole-workflow plans).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.astar import esg_1q
from repro.core.dominator import ScheduleGroup, distribute_slo
from repro.core.profiles import Config, ProfileTable
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy


class ESGScheduler(SchedulerPolicy):
    name = "ESG"
    placement = "locality"

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 k: int = 5, group_size: int = 3,
                 pareto: bool = False, risk_sigma: float = 0.0):
        self.tables = tables
        self.k = k
        self.pareto = pareto
        # plan against P95-ish estimates when the config lattice is coarse
        # (TPU-zoo serving: chip counts step latency ~2x, so mean-based
        # plans ride the budget edge and noise tips them over)
        self.time_inflation = 1.0 + 1.645 * risk_sigma
        self.groups: dict[str, dict[str, ScheduleGroup]] = {
            name: distribute_slo(app, tables, group_size)
            for name, app in apps.items()
        }
        # per-app stage order (topological) for suffix-quota normalisation
        self._stage_pos = {
            name: {s: i for i, s in enumerate(app.stages)}
            for name, app in apps.items()
        }

    # -- quota of the remaining pipeline, for G_SLO normalisation ----------
    def _norm_quota(self, app: Workflow, group: ScheduleGroup,
                    stage: str) -> float:
        gmap = self.groups[app.name]
        pos = self._stage_pos[app.name]
        remaining_groups = {gmap[s].stages: gmap[s].slo_fraction
                            for s in app.stages if pos[s] >= pos[stage]}
        total = sum(remaining_groups.values())
        return group.slo_fraction / total if total > 0 else 1.0

    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        group = self.groups[app.name][stage]
        # stages of the group from the current one onward
        idx = group.stages.index(stage)
        stages = group.stages[idx:]
        funcs = [app.func_of[s] for s in stages]
        tables = [self.tables[f] for f in funcs]
        if self.pareto:
            tables = [t.pareto() for t in tables]
        tables[0] = tables[0].restrict_batch(max(len(jobs), 1))

        w = max(now - j.inst.arrival_ms for j in jobs)
        slo = max(j.inst.slo_ms for j in jobs)
        if w >= slo:
            # deadline already lost: the SLO miss is sunk — serve at the
            # globally cost-optimal config (paper's "ensure progress";
            # Config(1,1,1) would pin a 76B model to one chip for minutes)
            tbl = self.tables[funcs[0]].restrict_batch(max(len(jobs), 1))
            i = int(np.argmin(tbl.job_costs))
            return [tbl.configs[i]]
        remaining = max(slo - w, 1.0)
        g_slo = remaining * self._norm_quota(app, group, stage)
        # headroom for non-exec latency the profiles don't cover: data
        # transfer + dispatch/scheduling overhead per remaining stage (the
        # Controller "estimates the times with performance profiles" — §3.3;
        # transfer estimates are part of those profiles)
        margin = sum(self.tables[f].fn.input_mb * 8.0 + 25.0 for f in funcs)
        g_slo = max((g_slo - margin) / self.time_inflation, 1.0)

        results = esg_1q(tables, g_slo, k=self.k)
        out = [r.configs[0] for r in results]
        if len(out) == 1 and results[0].est_time_ms >= g_slo:
            # infeasible target: best-effort fastest path, with cheaper
            # fallbacks so the dispatcher can still place something
            out.append(Config(min(len(jobs), 8), 2, 2))
            out.append(Config(1, 1, 1))
        return out
