"""The ESG scheduling policy — the paper's contribution, wired together.

Per queue-scheduling call (paper Fig 2(d)):
  1. locate the stage's schedule group + SLO quota (dominator-based
     distribution, computed once per app),
  2. G_SLO = (deadline - now) x q̂, with q̂ the group quota normalised over
     the not-yet-finished groups (the paper's (SLO - w) x q with the quota
     re-normalised so early finishes benefit later stages; see DESIGN §1),
  3. ESG_1Q (A* + dual-blade pruning) over the remaining stages of the
     group, the current stage's batch capped by the queue length,
  4. return the top-K *current-stage* configs as the configuration priority
     queue — the emulator's dispatcher walks it (ESG_Dispatch), falling back
     through candidates, then to the recheck list.

ESG re-plans at *every* stage dispatch — the paper's optimality-guided
adaptive behaviour (vs Orion/Aquatope's static whole-workflow plans).

``placement="memory"`` (weight-locality-aware mode, off by default) does
two things: the emulator's placement ranks fallback invokers by the
restart penalty their warm state implies (see ``ClusterSim._place``),
and the planner prices the *predicted* Torpor-style swap-in penalty of
each remaining stage into the A* search (``esg_1q(penalties_ms=...)``)
so dual-blade pruning compares true latencies.  Only the swap component
is priced — when some invoker still holds the function's weights hot the
penalty is zero, and cold-start container provisioning stays out of the
plan exactly as in the legacy planner — so with unbounded HBM (where
nothing is ever demoted) memory-aware planning is bit-identical to the
default.  The baselines (Orion/Aquatope/INFless/FaST-GShare) stay
memory-blind for a fair fig6/fig7 contrast.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.astar import esg_1q
from repro.core.dominator import ScheduleGroup, distribute_slo
from repro.core.profiles import Config, ProfileTable
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy
from repro.gpu import HOT, WARM, swap_in_ms


class ESGScheduler(SchedulerPolicy):
    name = "ESG"
    placement = "locality"

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 k: int = 5, group_size: int = 3,
                 pareto: bool = False, risk_sigma: float = 0.0,
                 placement: str = "locality"):
        if placement not in ("locality", "memory"):
            raise ValueError(f"ESG placement must be 'locality' or "
                             f"'memory', got {placement!r}")
        self.placement = placement
        self.tables = tables
        self.k = k
        self.pareto = pareto
        # plan against P95-ish estimates when the config lattice is coarse
        # (TPU-zoo serving: chip counts step latency ~2x, so mean-based
        # plans ride the budget edge and noise tips them over)
        self.time_inflation = 1.0 + 1.645 * risk_sigma
        self.groups: dict[str, dict[str, ScheduleGroup]] = {
            name: distribute_slo(app, tables, group_size)
            for name, app in apps.items()
        }
        # per-app stage order (topological) for suffix-quota normalisation
        self._stage_pos = {
            name: {s: i for i, s in enumerate(app.stages)}
            for name, app in apps.items()
        }

    # -- quota of the remaining pipeline, for G_SLO normalisation ----------
    def _norm_quota(self, app: Workflow, group: ScheduleGroup,
                    stage: str) -> float:
        gmap = self.groups[app.name]
        pos = self._stage_pos[app.name]
        remaining_groups = {gmap[s].stages: gmap[s].slo_fraction
                            for s in app.stages if pos[s] >= pos[stage]}
        total = sum(remaining_groups.values())
        return group.slo_fraction / total if total > 0 else 1.0

    # -- predicted weight-swap penalty per stage (memory-aware planning) ---
    def _predicted_swap_ms(self, sim: ClusterSim, func: str) -> float:
        """Swap-in penalty the memory-aware placement is predicted to pay
        for ``func``: 0 when any invoker still holds the weights hot (the
        placement will steer there), ``swap_in_ms`` when the best warm
        state anywhere is host-staged weights, and 0 when the function is
        cold everywhere (container provisioning is not a swap cost and
        stays unpriced, as in the legacy planner — this also keeps
        unbounded-HBM runs, which never demote, bit-identical).

        Under the overlapped swap pipeline a "hot" invoker may still be
        waiting on an in-flight background copy, so the prediction is
        the best *residual* transfer time across hot invokers instead
        of a flat zero."""
        warm_somewhere = False
        best_residual = None
        for inv in sim.invokers:
            r = inv.residency(func, sim.now)
            if r == HOT:
                if not getattr(sim, "overlap", False):
                    return 0.0
                residual = inv.start_penalty_ms(func, None, sim.now)
                if residual <= 0.0:
                    return 0.0
                best_residual = (residual if best_residual is None
                                 else min(best_residual, residual))
            elif r == WARM:
                warm_somewhere = True
        if best_residual is not None:
            if warm_somewhere:
                # a host-staged copy elsewhere caps the price: placement
                # can always fall back to a fresh demand swap there
                return min(best_residual,
                           swap_in_ms(sim.invokers[0].model_mb(func)))
            return best_residual
        if warm_somewhere:
            return swap_in_ms(sim.invokers[0].model_mb(func))
        return 0.0

    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        group = self.groups[app.name][stage]
        # stages of the group from the current one onward
        idx = group.stages.index(stage)
        stages = group.stages[idx:]
        funcs = [app.func_of[s] for s in stages]
        tables = [self.tables[f] for f in funcs]
        if self.pareto:
            tables = [t.pareto() for t in tables]
        tables[0] = tables[0].restrict_batch(max(len(jobs), 1))

        w = max(now - j.inst.arrival_ms for j in jobs)
        slo = max(j.inst.slo_ms for j in jobs)
        if w >= slo:
            # deadline already lost: the SLO miss is sunk — serve at the
            # globally cost-optimal config (paper's "ensure progress";
            # Config(1,1,1) would pin a 76B model to one chip for minutes)
            tbl = self.tables[funcs[0]].restrict_batch(max(len(jobs), 1))
            i = int(np.argmin(tbl.job_costs))
            return [tbl.configs[i]]
        remaining = max(slo - w, 1.0)
        g_slo = remaining * self._norm_quota(app, group, stage)
        # headroom for non-exec latency the profiles don't cover: data
        # transfer + dispatch/scheduling overhead per remaining stage (the
        # Controller "estimates the times with performance profiles" — §3.3;
        # transfer estimates are part of those profiles)
        margin = sum(self.tables[f].fn.input_mb * 8.0 + 25.0 for f in funcs)
        g_slo = max((g_slo - margin) / self.time_inflation, 1.0)

        # memory-aware mode: price each remaining stage's predicted
        # weight-swap penalty into the search so the configPQ is ranked
        # by true (swap-inclusive) latency and cost
        penalties = None
        if self.placement == "memory" and getattr(sim, "invokers", None):
            penalties = [self._predicted_swap_ms(sim, f) for f in funcs]
            if getattr(sim, "overlap", False) and \
                    getattr(sim, "prefetch_weights", False):
                # overlapped swap pipeline with predictive prefetch:
                # stage j's swap-in is enqueued when stage j-1
                # dispatches, so at least stage j-1's fastest execution
                # hides it — price only the residual, which shrinks
                # with pipeline depth (stage 0 pays what is left *now*)
                for j in range(1, len(penalties)):
                    penalties[j] = max(
                        penalties[j] - tables[j - 1].min_time, 0.0)
            if not any(penalties):
                penalties = None
        results = esg_1q(tables, g_slo, k=self.k, penalties_ms=penalties)
        out = [r.configs[0] for r in results]
        if len(out) == 1 and results[0].est_time_ms >= g_slo:
            # infeasible target: best-effort fastest path, with cheaper
            # fallbacks so the dispatcher can still place something
            out.append(Config(min(len(jobs), 8), 2, 2))
            out.append(Config(1, 1, 1))
        return out
