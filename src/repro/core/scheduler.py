"""The ESG scheduling policy — the paper's contribution, wired together.

Per queue-scheduling call (paper Fig 2(d)):
  1. locate the stage's schedule group + SLO quota (dominator-based
     distribution, computed once per app),
  2. G_SLO = (deadline - now) x q̂, with q̂ the group quota normalised over
     the not-yet-finished groups (the paper's (SLO - w) x q with the quota
     re-normalised so early finishes benefit later stages; see DESIGN §1),
  3. ESG_1Q (A* + dual-blade pruning) over the remaining stages of the
     group, the current stage's batch capped by the queue length,
  4. return the top-K *current-stage* configs as the configuration priority
     queue — the emulator's dispatcher walks it (ESG_Dispatch), falling back
     through candidates, then to the recheck list.

ESG re-plans at *every* stage dispatch — the paper's optimality-guided
adaptive behaviour (vs Orion/Aquatope's static whole-workflow plans).
Those searches repeat heavily, so they run through a memoized
dominator-budget plan cache by default (``plan_cache=True``; see
``repro.core.plancache``) and the vectorized ESG_1Q engine
(``vectorized=True``) — both produce bit-identical plans to the legacy
per-call search, proven differentially in
``tests/test_planner_fastpath.py``.

``placement="memory"`` (weight-locality-aware mode, off by default) does
two things: the emulator's placement ranks fallback invokers by the
restart penalty their warm state implies (see ``ClusterSim._place``),
and the planner prices the *predicted* Torpor-style swap-in penalty of
each remaining stage into the A* search (``esg_1q(penalties_ms=...)``)
so dual-blade pruning compares true latencies.  With an online
calibrator attached (``calibrator=``, see ``repro.obs.calibrate``) the
suffix tables are additionally rescaled by the per-(app, stage) EWMA
correction factors learned from the audit stream, and the factor tuple
becomes an extra plan-cache key axis so no stale plan survives a
calibration step.  Only the swap component
is priced — when some invoker still holds the function's weights hot the
penalty is zero, and cold-start container provisioning stays out of the
plan exactly as in the legacy planner — so with unbounded HBM (where
nothing is ever demoted) memory-aware planning is bit-identical to the
default.  The baselines (Orion/Aquatope/INFless/FaST-GShare) stay
memory-blind for a fair fig6/fig7 contrast.
"""
from __future__ import annotations

import bisect
from typing import Optional

import numpy as np

from repro.core.astar import SearchStats, esg_1q
from repro.core.dominator import ScheduleGroup, distribute_slo
from repro.core.plancache import PlanCache
from repro.core.profiles import Config, ProfileTable
from repro.core.workflows import Workflow
from repro.cluster.emulator import ClusterSim, Job, SchedulerPolicy
from repro.gpu import HOT, WARM, swap_in_ms
from repro.obs import PlanRecord


# Expected rework per unit of reclamation hazard: a mid-task kill loses
# about half the run on average (uniform kill time), while a stage with a
# checkpoint resumes and loses only a small restore window.  These scale
# the fleet's ``risk_per_ms`` before it inflates the suffix tables.
PREEMPT_LOSS_FRAC = 0.5
CKPT_LOSS_FRAC = 0.1


class ESGScheduler(SchedulerPolicy):
    name = "ESG"
    placement = "locality"

    def __init__(self, apps: dict[str, Workflow],
                 tables: dict[str, ProfileTable],
                 k: int = 5, group_size: int = 3,
                 pareto: bool = False, risk_sigma: float = 0.0,
                 placement: str = "locality",
                 plan_cache: bool = True, vectorized: bool = True,
                 calibrator=None):
        if placement not in ("locality", "memory"):
            raise ValueError(f"ESG placement must be 'locality' or "
                             f"'memory', got {placement!r}")
        self.placement = placement
        self.tables = tables
        # online profile calibration (repro.obs.calibrate): when set,
        # every plan prices the suffix against per-stage corrected
        # tables and folds the published factor tuple into its plan-
        # cache key — None (the default) is the uncorrected legacy path
        self.calibrator = calibrator
        self._cal_version = -1
        self._scaled: dict[tuple, list[ProfileTable]] = {}
        # heterogeneous/preemptible fleets: suffix tables repriced per
        # (stage context, calibration factors, fleet signature) — the
        # signature also becomes a plan-cache key axis (see plan())
        self._spot_tables: dict[tuple, list[ProfileTable]] = {}
        self.k = k
        self.pareto = pareto
        self.vectorized = vectorized
        self.cache = PlanCache(k=k, vectorized=vectorized) \
            if plan_cache else None
        # plan against P95-ish estimates when the config lattice is coarse
        # (TPU-zoo serving: chip counts step latency ~2x, so mean-based
        # plans ride the budget edge and noise tips them over)
        self.time_inflation = 1.0 + 1.645 * risk_sigma
        self.groups: dict[str, dict[str, ScheduleGroup]] = {
            name: distribute_slo(app, tables, group_size)
            for name, app in apps.items()
        }
        # per-app stage order (topological) for suffix-quota normalisation
        self._stage_pos = {
            name: {s: i for i, s in enumerate(app.stages)}
            for name, app in apps.items()
        }
        # per-(app, stage) planning context — the group suffix, its tables
        # and the budget constants are pure functions of the constructor
        # inputs, so they are computed once instead of per dispatch
        self._ctx: dict[tuple[str, str], tuple] = {}
        self._restricted: dict[tuple[str, str, int], ProfileTable] = {}
        self._cheapest: dict[tuple[str, int], Config] = {}

    # -- quota of the remaining pipeline, for G_SLO normalisation ----------
    def _norm_quota(self, app: Workflow, group: ScheduleGroup,
                    stage: str) -> float:
        gmap = self.groups[app.name]
        pos = self._stage_pos[app.name]
        remaining_groups = {gmap[s].stages: gmap[s].slo_fraction
                            for s in app.stages if pos[s] >= pos[stage]}
        total = sum(remaining_groups.values())
        return group.slo_fraction / total if total > 0 else 1.0

    # -- predicted weight-swap penalty per stage (memory-aware planning) ---
    def _predicted_swap_ms(self, sim: ClusterSim, func: str) -> float:
        """Swap-in penalty the memory-aware placement is predicted to pay
        for ``func``: 0 when any invoker still holds the weights hot (the
        placement will steer there), ``swap_in_ms`` when the best warm
        state anywhere is host-staged weights, and 0 when the function is
        cold everywhere (container provisioning is not a swap cost and
        stays unpriced, as in the legacy planner — this also keeps
        unbounded-HBM runs, which never demote, bit-identical).

        Under the overlapped swap pipeline a "hot" invoker may still be
        waiting on an in-flight background copy, so the prediction is
        the best *residual* transfer time across hot invokers instead
        of a flat zero."""
        warm_somewhere = False
        best_residual = None
        for inv in sim.invokers:
            r = inv.residency(func, sim.now)
            if r == HOT:
                if not getattr(sim, "overlap", False):
                    return 0.0
                residual = inv.start_penalty_ms(func, None, sim.now)
                if residual <= 0.0:
                    return 0.0
                best_residual = (residual if best_residual is None
                                 else min(best_residual, residual))
            elif r == WARM:
                warm_somewhere = True
        if best_residual is not None:
            if warm_somewhere:
                # a host-staged copy elsewhere caps the price: placement
                # can always fall back to a fresh demand swap there
                return min(best_residual,
                           swap_in_ms(sim.invokers[0].model_mb(func)))
            return best_residual
        if warm_somewhere:
            return swap_in_ms(sim.invokers[0].model_mb(func))
        return 0.0

    # -- per-(app, stage) planning context ---------------------------------
    def _stage_ctx(self, app: Workflow, stage: str) -> tuple:
        key = (app.name, stage)
        ctx = self._ctx.get(key)
        if ctx is None:
            group = self.groups[app.name][stage]
            # stages of the group from the current one onward
            idx = group.stages.index(stage)
            stages = group.stages[idx:]
            # tuple: doubles as the "shape" axis of every planner memo
            # and plan-cache key below — cache entries are pure
            # functions of the profile-table contents, so apps sharing
            # a function suffix share entries (collapses an N-app
            # population of cloned workflows to a handful of shapes)
            funcs = tuple(app.func_of[s] for s in stages)
            base = [self.tables[f] for f in funcs]
            if self.pareto:
                base = [t.pareto() for t in base]
            # headroom for non-exec latency the profiles don't cover: data
            # transfer + dispatch/scheduling overhead per remaining stage
            # (the Controller "estimates the times with performance
            # profiles" — §3.3; transfer estimates are part of those)
            margin = sum(self.tables[f].fn.input_mb * 8.0 + 25.0
                         for f in funcs)
            quota = self._norm_quota(app, group, stage)
            ctx = (stages, funcs, base, margin, quota)
            self._ctx[key] = ctx
        return ctx

    # -- online calibration (repro.obs.calibrate) ---------------------------
    def _factors(self, app_name: str, stages) -> Optional[tuple]:
        """Published correction factors for the plan suffix, or None on
        the uncorrected path (no calibrator, or every factor 1.0 — the
        warmup gate keeps a cold calibrator bit-identical to none)."""
        cal = self.calibrator
        if cal is None:
            return None
        if cal.version != self._cal_version:
            # a published-factor change: drop memoized scaled tables so
            # the next plan rebuilds them against the new corrections
            self._cal_version = cal.version
            self._scaled.clear()
        if not cal.active:
            # nothing published yet: skip the per-plan factor-tuple
            # build — with accurate profiles this is every plan
            return None
        f = cal.factors(app_name, stages)
        return f if any(x != 1.0 for x in f) else None

    def _corrected(self, funcs: tuple, bucket: int,
                   tables: list[ProfileTable],
                   factors: tuple) -> list[ProfileTable]:
        key = (funcs, bucket, factors)
        got = self._scaled.get(key)
        if got is None:
            got = self._scaled[key] = [
                t.scaled(f) for t, f in zip(tables, factors)]
        return got

    # -- heterogeneous/preemptible fleet pricing ----------------------------
    @staticmethod
    def _fleet_sig(sim) -> Optional[tuple]:
        """The emulator's SKU/spot signature, or None on a homogeneous
        default fleet (and on sims that predate the fleet model)."""
        fn = getattr(sim, "sku_signature", None)
        return fn() if fn is not None else None

    def _spot_priced(self, funcs: tuple, bucket: int,
                     factors: Optional[tuple], sku_sig: tuple,
                     tables: list[ProfileTable]) -> list[ProfileTable]:
        """Suffix tables with SKU-scaled exec times and expected
        preemption loss priced into both ESG_1Q blades (memoized — the
        distinct signatures over a run are the fleet's up/down
        compositions, a handful)."""
        key = (funcs, bucket, factors, sku_sig)
        got = self._spot_tables.get(key)
        if got is None:
            exec_factor, risk = sku_sig
            got = self._spot_tables[key] = [
                t.preempt_priced(
                    exec_factor,
                    risk * (CKPT_LOSS_FRAC if t.fn.checkpoint_mb > 0.0
                            else PREEMPT_LOSS_FRAC))
                for t in tables]
        return got

    @staticmethod
    def _bucket(table: ProfileTable, n: int) -> int:
        """Quantize a batch cap to the table's lattice: restrict_batch is
        constant inside one lattice step, so the bucket is lossless."""
        lat = table.batch_lattice
        i = bisect.bisect_right(lat, n)
        return lat[i - 1] if i else 0

    def _prepared(self, funcs: tuple, base: list[ProfileTable],
                  bucket: int) -> list[ProfileTable]:
        key = (funcs[0], bucket)
        first = self._restricted.get(key)
        if first is None:
            first = base[0].restrict_batch(bucket)
            self._restricted[key] = first
        return [first] + base[1:]

    def _cheapest_config(self, func: str, n_jobs: int) -> Config:
        """Globally cost-optimal config of ``func`` at batch cap
        ``n_jobs`` (the sunk-deadline serve-at-min-cost path)."""
        bucket = self._bucket(self.tables[func], max(n_jobs, 1))
        cfg = self._cheapest.get((func, bucket))
        if cfg is None:
            tbl = self.tables[func].restrict_batch(bucket)
            cfg = tbl.configs[int(np.argmin(tbl.job_costs))]
            self._cheapest[(func, bucket)] = cfg
        return cfg

    def _penalties(self, sim: ClusterSim, funcs: list[str],
                   tables: list[ProfileTable]) -> Optional[list[float]]:
        """Memory-aware mode: predicted weight-swap penalty per remaining
        stage, residual-discounted under the overlapped swap pipeline."""
        if self.placement != "memory" or not getattr(sim, "invokers", None):
            return None
        penalties = [self._predicted_swap_ms(sim, f) for f in funcs]
        if getattr(sim, "overlap", False) and \
                getattr(sim, "prefetch_weights", False):
            # overlapped swap pipeline with predictive prefetch:
            # stage j's swap-in is enqueued when stage j-1
            # dispatches, so at least stage j-1's fastest execution
            # hides it — price only the residual, which shrinks
            # with pipeline depth (stage 0 pays what is left *now*)
            for j in range(1, len(penalties)):
                penalties[j] = max(
                    penalties[j] - tables[j - 1].min_time, 0.0)
        if not any(penalties):
            return None
        return penalties

    def plan(self, sim: ClusterSim, app: Workflow, stage: str,
             jobs: list[Job], now: float) -> list[Config]:
        # planner-decision audit (repro.obs): purely observational — the
        # stats object only exists when a recorder is attached, and no
        # decision below reads it
        rec = getattr(sim, "recorder", None)
        auditing = rec is not None and rec.enabled and rec.audit is not None
        stages, funcs, base, margin, quota = self._stage_ctx(app, stage)
        w = max(now - j.inst.arrival_ms for j in jobs)
        slo = max(j.inst.slo_ms for j in jobs)
        if w >= slo:
            # deadline already lost: the SLO miss is sunk — serve at the
            # globally cost-optimal config (paper's "ensure progress";
            # Config(1,1,1) would pin a 76B model to one chip for minutes)
            # (calibration is multiplicative per stage, so the job-cost
            # argmin — and hence this config — is factor-invariant)
            if auditing:
                rec.on_plan_result(PlanRecord(
                    t_ms=now, app=app.name, stage=stage, n_jobs=len(jobs),
                    g_slo_ms=0.0, regime="sunk", expansions=0,
                    pruned_time=0, pruned_cost=0, est_time_ms=None,
                    est_job_cost=None, slack_ms=None, n_candidates=1,
                    provenance=base[0].fn.provenance))
            return [self._cheapest_config(funcs[0], len(jobs))]
        remaining = max(slo - w, 1.0)
        g_slo = remaining * quota
        g_slo = max((g_slo - margin) / self.time_inflation, 1.0)

        bucket = self._bucket(base[0], max(len(jobs), 1))
        tables = self._prepared(funcs, base, bucket)
        # online calibration: plan against per-stage corrected tables;
        # the residual-penalty discount below then uses corrected
        # min_times too (the calibrated prediction of how much of a
        # prefetch the predecessor's execution hides)
        factors = self._factors(app.name, stages)
        if factors is not None:
            tables = self._corrected(funcs, bucket, tables, factors)
        # heterogeneous/preemptible fleet: reprice the suffix for SKU
        # speed and expected preemption loss (None on the default fleet,
        # leaving tables and cache keys untouched)
        sku_sig = self._fleet_sig(sim)
        if sku_sig is not None:
            tables = self._spot_priced(funcs, bucket, factors,
                                       sku_sig, tables)
        # memory-aware mode: price each remaining stage's predicted
        # weight-swap penalty into the search so the configPQ is ranked
        # by true (swap-inclusive) latency and cost
        penalties = self._penalties(sim, funcs, tables)
        stats = SearchStats() if auditing else None
        if self.cache is not None:
            pen_key = tuple(penalties) if penalties is not None else None
            # the factor tuple is a cache-key axis: a published
            # correction changes the key, so plans cached under the old
            # factors can never serve a calibrated lookup (stale-plan
            # invalidation by unreachability); the fleet signature is
            # another (a reclaim/recover changes the signature, making
            # plans priced for the old fleet unreachable, PR-7 style)
            key = (funcs, bucket, pen_key) if factors is None \
                else (funcs, bucket, pen_key, factors)
            if sku_sig is not None:
                key = key + ("sku", sku_sig)
            results = self.cache.lookup(
                key, g_slo, tables, penalties, stats=stats)
            regime = self.cache.last_regime
        else:
            results = esg_1q(tables, g_slo, k=self.k, penalties_ms=penalties,
                             vectorized=self.vectorized, stats=stats)
            regime = "nocache"
        out = [r.configs[0] for r in results]
        if len(out) == 1 and results[0].est_time_ms >= g_slo:
            # infeasible target: best-effort fastest path, with cheaper
            # fallbacks so the dispatcher can still place something
            out.append(Config(min(len(jobs), 8), 2, 2))
            out.append(Config(1, 1, 1))
        if auditing:
            best = results[0]
            rec.on_plan_result(PlanRecord(
                t_ms=now, app=app.name, stage=stage, n_jobs=len(jobs),
                g_slo_ms=g_slo, regime=regime,
                expansions=stats.nodes_expanded,
                pruned_time=stats.pruned_time,
                pruned_cost=stats.pruned_cost,
                est_time_ms=best.est_time_ms,
                est_job_cost=best.est_job_cost,
                slack_ms=g_slo - best.est_time_ms,
                n_candidates=len(out),
                provenance=base[0].fn.provenance))
        return out

    # -- event-sparse emulator hook ----------------------------------------
    def plan_signature(self, sim: ClusterSim, app: Workflow, stage: str,
                       jobs: list[Job], now: float):
        """Certified identity token for the candidate list ``plan`` would
        return right now, or None when no certificate is available.

        Only the plan cache's budget-free regime is certifiable (the
        result is provably independent of the exact G_SLO there); the
        sunk-deadline path, the floor/exact regimes and unbuilt cache
        entries all return None, forcing the emulator to re-plan."""
        if self.cache is None or not jobs:
            return None
        stages, funcs, base, margin, quota = self._stage_ctx(app, stage)
        w = max(now - j.inst.arrival_ms for j in jobs)
        slo = max(j.inst.slo_ms for j in jobs)
        if w >= slo:
            return None
        remaining = max(slo - w, 1.0)
        g_slo = max((remaining * quota - margin) / self.time_inflation, 1.0)
        bucket = self._bucket(base[0], max(len(jobs), 1))
        tables = self._prepared(funcs, base, bucket)
        # mirror plan() exactly: the certificate must be keyed under the
        # same factor axis, so a calibration step (new factors -> new
        # key) silently invalidates outstanding sparse-skip certificates
        factors = self._factors(app.name, stages)
        if factors is not None:
            tables = self._corrected(funcs, bucket, tables, factors)
        sku_sig = self._fleet_sig(sim)
        if sku_sig is not None:
            tables = self._spot_priced(funcs, bucket, factors,
                                       sku_sig, tables)
        penalties = self._penalties(sim, funcs, tables)
        pen_key = tuple(penalties) if penalties is not None else None
        key = (funcs, bucket, pen_key) if factors is None \
            else (funcs, bucket, pen_key, factors)
        if sku_sig is not None:
            key = key + ("sku", sku_sig)
        return self.cache.budget_free_token(key, g_slo)
