"""Dominator-based SLO distribution (paper §3.3).

Pipeline:
  1. dominator tree of the workflow DAG (Cooper-Harvey-Kennedy iterative
     algorithm — the graphs are tiny),
  2. label nodes with ANL (average normalised length) from the profiles,
  3. post-order reduction: parallel branches under a split collapse into a
     *reduced* unit whose ANL is the max over branches of the branch ANL sum,
  4. group ≤ g consecutive chain units (reduced units stay alone),
  5. distribute the end-to-end SLO proportionally to group ANLs, recursing
     into reduced units (each parallel branch inherits the unit's full quota,
     split inside the branch by ANL).

Output: for every stage, its ``ScheduleGroup`` (the stages ESG_1Q searches
over together) and the group's SLO fraction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.profiles import ProfileTable
from repro.core.workflows import Workflow


# ---------------------------------------------------------------------------
# Dominator tree
# ---------------------------------------------------------------------------
def _topo_order(wf: Workflow) -> list[str]:
    indeg = {s: 0 for s in wf.stages}
    for s, succ in wf.edges.items():
        for t in succ:
            indeg[t] += 1
    queue = [s for s in wf.stages if indeg[s] == 0]
    out = []
    while queue:
        s = queue.pop(0)
        out.append(s)
        for t in wf.edges.get(s, ()):
            indeg[t] -= 1
            if indeg[t] == 0:
                queue.append(t)
    if len(out) != len(wf.stages):
        raise ValueError(f"workflow {wf.name} has a cycle")
    return out


def dominator_tree(wf: Workflow) -> dict[str, Optional[str]]:
    """stage -> immediate dominator (idom); root maps to None."""
    order = _topo_order(wf)
    roots = wf.roots
    # virtual root if several entry stages
    virtual = len(roots) > 1
    root = "<root>" if virtual else roots[0]
    preds = {s: wf.predecessors(s) for s in wf.stages}
    if virtual:
        for r in roots:
            preds[r] = preds[r] + [root]
        order = [root] + order
    idx = {s: i for i, s in enumerate(order)}
    idom: dict[str, Optional[str]] = {root: root}

    def intersect(a, b):
        while a != b:
            while idx[a] > idx[b]:
                a = idom[a]
            while idx[b] > idx[a]:
                b = idom[b]
        return a

    changed = True
    while changed:
        changed = False
        for s in order:
            if s == root:
                continue
            ps = [p for p in preds.get(s, []) if p in idom]
            if not ps:
                continue
            new = ps[0]
            for p in ps[1:]:
                new = intersect(new, p)
            if idom.get(s) != new:
                idom[s] = new
                changed = True
    idom[root] = None
    if virtual:
        # re-root: children of the virtual root become roots
        del idom[root]
        for r in roots:
            if idom.get(r) == "<root>":
                idom[r] = None
    return idom


# ---------------------------------------------------------------------------
# ANL labels
# ---------------------------------------------------------------------------
def anl_labels(wf: Workflow, tables: dict[str, ProfileTable]) -> dict[str, float]:
    """ANL(f_i) = mean_c [ t_{f_i}(c) / sum_j t_{f_j}(c) ] (paper §3.3)."""
    mats = []
    for s in wf.stages:
        mats.append(tables[wf.func_of[s]].times)
    n = min(len(m) for m in mats)
    mat = np.stack([m[:n] for m in mats])       # (stages, configs)
    norm = mat / mat.sum(axis=0, keepdims=True)
    return {s: float(norm[i].mean()) for i, s in enumerate(wf.stages)}


# ---------------------------------------------------------------------------
# Reduction + grouping
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Unit:
    """A chain element: one stage, or a reduced parallel region."""
    stages: tuple[str, ...]            # the single stage, or all subsumed ones
    anl: float
    branches: Optional[list[list["Unit"]]] = None   # set for reduced units

    @property
    def reduced(self) -> bool:
        return self.branches is not None


def _reaches(wf: Workflow, a: str, b: str, memo: dict) -> bool:
    key = (a, b)
    if key in memo:
        return memo[key]
    stack, seen = [a], {a}
    found = False
    while stack:
        s = stack.pop()
        if s == b:
            found = True
            break
        for t in wf.edges.get(s, ()):
            if t not in seen:
                seen.add(t)
                stack.append(t)
    memo[key] = found
    return found


def reduce_chain(wf: Workflow, anl: dict[str, float]) -> list[Unit]:
    """Serialise the DAG into a chain of Units via dominator-tree reduction."""
    idom = dominator_tree(wf)
    children: dict[str, list[str]] = {s: [] for s in wf.stages}
    roots = []
    for s, d in idom.items():
        if d is None:
            roots.append(s)
        else:
            children[d].append(s)
    topo = {s: i for i, s in enumerate(_topo_order(wf))}
    memo: dict = {}

    def region(node: str) -> list[Unit]:
        chain = [Unit((node,), anl[node])]
        kids = sorted(children[node], key=lambda s: topo[s])
        i = 0
        while i < len(kids):
            # collect a maximal parallel group of mutually-unreachable kids
            group = [kids[i]]
            j = i + 1
            while j < len(kids) and all(
                    not _reaches(wf, g, kids[j], memo) and
                    not _reaches(wf, kids[j], g, memo) for g in group):
                group.append(kids[j])
                j += 1
            if len(group) == 1:
                chain.extend(region(group[0]))
            else:
                branches = [region(g) for g in group]
                sums = [sum(u.anl for u in br) for br in branches]
                stages = tuple(s for br in branches for u in br for s in u.stages)
                chain.append(Unit(stages, max(sums), branches))
            i = j
        return chain

    if len(roots) == 1:
        return region(roots[0])
    branches = [region(r) for r in sorted(roots, key=lambda s: topo[s])]
    sums = [sum(u.anl for u in br) for br in branches]
    stages = tuple(s for br in branches for u in br for s in u.stages)
    return [Unit(stages, max(sums), branches)]


@dataclasses.dataclass(frozen=True)
class ScheduleGroup:
    stages: tuple[str, ...]           # consecutive pipeline stages
    slo_fraction: float               # share of the end-to-end SLO


def distribute_slo(wf: Workflow, tables: dict[str, ProfileTable],
                   group_size: int = 3) -> dict[str, ScheduleGroup]:
    """stage -> its ScheduleGroup.  Fractions along any root->sink path
    sum to ~1 (parallel branches share their region's quota)."""
    anl = anl_labels(wf, tables)
    chain = reduce_chain(wf, anl)
    out: dict[str, ScheduleGroup] = {}

    def assign(chain: list[Unit], quota: float):
        # group <= g consecutive simple units; reduced units stay alone
        groups: list[list[Unit]] = []
        cur: list[Unit] = []
        for u in chain:
            if u.reduced:
                if cur:
                    groups.append(cur)
                    cur = []
                groups.append([u])
            else:
                cur.append(u)
                if len(cur) == group_size:
                    groups.append(cur)
                    cur = []
        if cur:
            groups.append(cur)
        total = sum(u.anl for g in groups for u in g)
        for g in groups:
            g_anl = sum(u.anl for u in g)
            g_quota = quota * (g_anl / total if total > 0 else 1 / len(groups))
            if len(g) == 1 and g[0].reduced:
                for br in g[0].branches:
                    assign(br, g_quota)      # parallel branches: full quota each
            else:
                stages = tuple(s for u in g for s in u.stages)
                sg = ScheduleGroup(stages, g_quota)
                for s in stages:
                    out[s] = sg
    assign(chain, 1.0)
    return out
