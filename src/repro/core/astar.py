"""ESG_1Q — optimality-guided configuration search (paper §3.3, Alg. 1).

Finds the K cheapest configuration *paths* (one config per remaining stage of
the schedule group) whose summed latency meets the group SLO target, via
A*-search with the paper's dual-blade pruning:

  time blade:   prune prefix p when  tLow(p) >= G_SLO, where
                tLow = time(p) + sum of per-stage minimum times not in p.
                Config lists are sorted by latency, so the first pruned
                config ends the whole expansion loop (the paper's `break`).

  cost blade:   prune when  rscLow(p) >= minRSC[K-1], where
                rscLow = cost(p) + sum of per-stage minimum costs not in p,
                and minRSC holds the K best *upper bounds* seen so far —
                each new prefix contributes rscFastest(p) = cost(p) + cost of
                completing with every remaining stage at its fastest config
                (that completion is time-feasible whenever p survived the
                time blade, so the bound is achievable).

The heuristic (suffix minimum cost) is admissible and consistent, so nodes
pop in nondecreasing f = g + h order and the first K completed paths are
exactly the K cheapest feasible ones (verified against brute force in
tests/test_astar.py).

``penalties_ms`` (one entry per stage, optional) prices a predicted
placement start penalty — e.g. the Torpor-style weight swap-in a
memory-aware placement expects to pay — into the stage's table before
the search (``ProfileTable.with_penalty``): the time blade then prunes
against the *true* latency including the swap, and the cost blade bills
the penalty window at each config's $-rate.  A zero penalty leaves the
stage's table untouched, so memory-blind callers are bit-identical.
Under the overlapped swap pipeline the caller passes the *residual*
penalty left after the transfer engine hides the copy behind the
predecessor stage's execution (see ``ESGScheduler.plan``) — deeper
pipeline suffixes therefore price smaller penalties, which is exactly
the pipeline-conscious behaviour the paper's G_SLO distribution wants.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.profiles import Config, ProfileTable

# Bounded open list (vectorized engine): when the heap outgrows this, it is
# compacted by dropping entries whose cost lower bound already exceeds the
# current K-th upper bound — exactly the nodes the pop-time stale check
# would discard anyway, so compaction never changes the result.
OPEN_LIST_CAP = 32_768


def _priced(tables: list[ProfileTable],
            penalties_ms: Optional[Sequence[float]]) -> list[ProfileTable]:
    if penalties_ms is None:
        return tables
    if len(penalties_ms) != len(tables):
        raise ValueError(
            f"penalties_ms has {len(penalties_ms)} entries "
            f"for {len(tables)} stages")
    return [t.with_penalty(p) for t, p in zip(tables, penalties_ms)]


@dataclasses.dataclass(frozen=True)
class PathResult:
    configs: tuple[Config, ...]
    est_time_ms: float
    est_job_cost: float


@dataclasses.dataclass
class SearchStats:
    nodes_expanded: int = 0
    nodes_pushed: int = 0
    pruned_time: int = 0
    pruned_cost: int = 0


def esg_1q(tables: list[ProfileTable], g_slo_ms: float, k: int = 5,
           stats: Optional[SearchStats] = None,
           penalties_ms: Optional[Sequence[float]] = None,
           vectorized: bool = True) -> list[PathResult]:
    """K cheapest SLO-feasible config paths over ``tables`` (one per stage).

    ``vectorized=True`` (default) runs the array-based engine: per-stage
    numpy pricing/blade arithmetic, index paths instead of Config tuples,
    and a bounded open list.  It returns the same results as the legacy
    per-config loop (``vectorized=False``) — the dual blades prune lazily
    at pop instead of eagerly at push, which never changes which paths
    complete first (tests/test_planner_fastpath.py runs both engines over
    randomized tables).  ``SearchStats`` counters keep the same meaning
    but not the same values across engines (the vectorized engine pushes
    nodes the sequential loop pruned in-flight and prunes them at pop)."""
    if vectorized:
        return _esg_1q_vec(tables, g_slo_ms, k, stats, penalties_ms)
    return _esg_1q_legacy(tables, g_slo_ms, k, stats, penalties_ms)


def _esg_1q_legacy(tables: list[ProfileTable], g_slo_ms: float, k: int = 5,
                   stats: Optional[SearchStats] = None,
                   penalties_ms: Optional[Sequence[float]] = None
                   ) -> list[PathResult]:
    """Reference per-config search loop (the pre-fast-path implementation)."""
    tables = _priced(tables, penalties_ms)
    n = len(tables)
    if n == 0:
        return []
    # suffix bounds (suffix i = stages i..n-1)
    min_t = np.zeros(n + 1)
    min_c = np.zeros(n + 1)
    fast_c = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        min_t[i] = min_t[i + 1] + tables[i].min_time
        min_c[i] = min_c[i + 1] + tables[i].min_job_cost
        fast_c[i] = fast_c[i + 1] + tables[i].fastest_cost

    if min_t[0] >= g_slo_ms:
        # infeasible even at the fastest configs: return the fastest path
        # (the controller treats it as a best-effort schedule)
        cfgs = tuple(t.configs[0] for t in tables)
        return [PathResult(cfgs, float(min_t[0]), float(fast_c[0]))]

    min_rsc = [float("inf")] * k
    results: list[PathResult] = []
    tie = itertools.count()
    # node: (f_cost, f_time, tie, stage_next, g_time, g_cost, path) —
    # the admissible time bound breaks cost ties toward faster paths
    # (matters when the cost curve is flat in resources, e.g. memory-bound
    # LM serving where latency ~ 1/chips and $-rate ~ chips)
    heap: list[tuple] = [(min_c[0], min_t[0], next(tie), 0, 0.0, 0.0, ())]

    def note_upper(bound: float):
        # min_rsc stays sorted, so displacing the worst entry is a pop +
        # O(log k) insort, not a full re-sort per insertion
        if bound < min_rsc[-1]:
            min_rsc.pop()
            bisect.insort(min_rsc, bound)

    note_upper(float(fast_c[0]))

    while heap and len(results) < k:
        f, _, _, i, g_time, g_cost, path = heapq.heappop(heap)
        if stats:
            stats.nodes_expanded += 1
        if i == n:
            results.append(PathResult(path, g_time, g_cost))
            continue
        if g_cost + min_c[i] > min_rsc[-1]:     # stale node (bound tightened)
            if stats:
                stats.pruned_cost += 1
            continue
        tbl = tables[i]
        for j in range(len(tbl.configs)):
            t_new = g_time + float(tbl.times[j])
            if t_new + min_t[i + 1] >= g_slo_ms:
                if stats:
                    stats.pruned_time += 1
                break                            # sorted by time: all later prune
            c_new = g_cost + float(tbl.job_costs[j])
            rsc_low = c_new + min_c[i + 1]
            if rsc_low > min_rsc[-1]:
                if stats:
                    stats.pruned_cost += 1
                continue
            note_upper(c_new + fast_c[i + 1])
            heapq.heappush(heap, (rsc_low, t_new + min_t[i + 1], next(tie),
                                  i + 1, t_new, c_new,
                                  path + (tbl.configs[j],)))
            if stats:
                stats.nodes_pushed += 1
    return results


def _esg_1q_vec(tables: list[ProfileTable], g_slo_ms: float, k: int = 5,
                stats: Optional[SearchStats] = None,
                penalties_ms: Optional[Sequence[float]] = None
                ) -> list[PathResult]:
    """Vectorized ESG_1Q engine.

    Same search, three structural changes:
      * stage tables are consumed as (times, job_costs) arrays with the
        penalty priced in via ``ProfileTable.priced_arrays`` — no Config
        objects or intermediate tables are built during the search;
      * one expansion evaluates both blades over the whole config list at
        once: the time blade is a prefix length (config lists are sorted
        by latency, so feasibility is monotone), the cost blade a boolean
        mask against the current K-th upper bound, and the K best upper
        bounds fold in via one partition instead of per-config insorts;
      * paths are tuples of config *indices* (materialized into Config
        tuples only for completed results) and the open list is bounded:
        past ``OPEN_LIST_CAP`` it is compacted by the same stale test the
        pop loop applies.

    The eager per-config bound-tightening of the sequential loop becomes
    lazy (a whole expansion prunes against the bound as of its start);
    nodes the legacy loop never pushed are pushed here and discarded by
    the pop-time stale check, which cannot change the completed-path
    order because the heap keys (cost lower bound, time lower bound) are
    computed with the same float arithmetic.
    """
    if penalties_ms is not None and len(penalties_ms) != len(tables):
        raise ValueError(
            f"penalties_ms has {len(penalties_ms)} entries "
            f"for {len(tables)} stages")
    n = len(tables)
    if n == 0:
        return []
    times: list[np.ndarray] = []
    costs: list[np.ndarray] = []
    for i, t in enumerate(tables):
        ts, cs = t.priced_arrays(
            0.0 if penalties_ms is None else penalties_ms[i])
        times.append(ts)
        costs.append(cs)
    # suffix bounds, accumulated in the same (reverse) order as the legacy
    # loop so the float sums are bitwise identical
    min_t = np.zeros(n + 1)
    min_c = np.zeros(n + 1)
    fast_c = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        min_t[i] = min_t[i + 1] + float(times[i][0])
        min_c[i] = min_c[i + 1] + float(costs[i].min())
        fast_c[i] = fast_c[i + 1] + float(costs[i][0])

    def materialize(path: tuple[int, ...]) -> tuple[Config, ...]:
        return tuple(tables[s].configs[j] for s, j in enumerate(path))

    if min_t[0] >= g_slo_ms:
        return [PathResult(tuple(t.configs[0] for t in tables),
                           float(min_t[0]), float(fast_c[0]))]

    min_rsc = np.full(k, np.inf)
    results: list[PathResult] = []
    tie = itertools.count()
    heap: list[tuple] = [(float(min_c[0]), float(min_t[0]), next(tie),
                          0, 0.0, 0.0, ())]
    min_rsc[-1] = fast_c[0]
    min_rsc.sort()
    compact_floor = OPEN_LIST_CAP

    while heap and len(results) < k:
        f, _, _, i, g_time, g_cost, path = heapq.heappop(heap)
        if stats:
            stats.nodes_expanded += 1
        if i == n:
            results.append(PathResult(materialize(path), g_time, g_cost))
            continue
        bound = min_rsc[-1]
        if g_cost + min_c[i] > bound:        # stale node (bound tightened)
            if stats:
                stats.pruned_cost += 1
            continue
        m_next = min_t[i + 1]
        t_new = g_time + times[i]
        f_time = t_new + m_next
        # time blade: sorted by latency => feasibility is a prefix
        feas = f_time < g_slo_ms
        cut = int(feas.sum())
        if cut < len(feas):
            if stats:
                stats.pruned_time += 1
            if cut == 0:
                continue
        c_new = g_cost + costs[i][:cut]
        rsc_low = c_new + min_c[i + 1]
        keep = rsc_low <= bound              # cost blade (strict > prunes)
        kept = int(keep.sum())
        if stats:
            stats.pruned_cost += cut - kept
        if not kept:
            continue
        c_keep = c_new[keep]
        # fold the survivors' achievable upper bounds (rscFastest) into
        # the K best seen so far in one partition
        merged = np.concatenate((min_rsc, c_keep + fast_c[i + 1]))
        if merged.size > k:
            merged = np.partition(merged, k - 1)[:k]
        merged.sort()
        min_rsc = merged
        nxt = i + 1
        for j, rl, ft, tn, cn in zip(
                np.flatnonzero(keep).tolist(), rsc_low[keep].tolist(),
                f_time[:cut][keep].tolist(), t_new[:cut][keep].tolist(),
                c_keep.tolist()):
            heapq.heappush(heap, (rl, ft, next(tie), nxt, tn, cn,
                                  path + (j,)))
        if stats:
            stats.nodes_pushed += kept
        if len(heap) > compact_floor:
            bound = min_rsc[-1]
            slim = [nd for nd in heap if nd[0] <= bound]
            if len(slim) < len(heap):
                heap = slim
                heapq.heapify(heap)
            # if nothing was prunable, raise the floor so compaction
            # attempts stay amortized O(1) per push
            compact_floor = max(OPEN_LIST_CAP, 2 * len(heap))
    return results


def brute_force(tables: list[ProfileTable], g_slo_ms: float,
                k: int = 5,
                penalties_ms: Optional[Sequence[float]] = None
                ) -> list[PathResult]:
    """Reference enumeration (exponential) — test oracle + Fig 9 baseline."""
    tables = _priced(tables, penalties_ms)
    paths = []
    for combo in itertools.product(*[range(len(t.configs)) for t in tables]):
        t = sum(float(tables[i].times[j]) for i, j in enumerate(combo))
        if t >= g_slo_ms:
            continue
        c = sum(float(tables[i].job_costs[j]) for i, j in enumerate(combo))
        paths.append(PathResult(
            tuple(tables[i].configs[j] for i, j in enumerate(combo)), t, c))
    paths.sort(key=lambda p: (p.est_job_cost, p.est_time_ms))
    return paths[:k]
