"""Online profile calibration: close the pricing loop over the audit stream.

ESG's dual-blade search and dominator-based SLO distribution price every
decision against ``ProfileTable`` latency estimates.  The paper assumes
those are offline-profiled and trustworthy; production profiles drift
(new kernels, contention, quantization, plain mis-measurement), and the
flight recorder already *measures* the resulting error online — one
predicted-vs-realized pair per dispatched stage in the planner audit
stream.  This module consumes that stream and feeds the error back:

  * :class:`ProfileCalibrator` subscribes to ``AuditLog`` realized
    records and maintains one **EWMA multiplicative correction factor**
    per (app, stage): the smoothed ratio of realized execution time to
    the *raw* (uncorrected) profile estimate.  The ratio is computed on
    the exec component alone (``realized_exec_ms / predicted_raw_ms``),
    so swap penalties and queueing — which the planner prices through
    separate, already-measured channels — never pollute the profile
    correction.

  * The factor is **sample-count-gated** (no correction is published
    before ``min_samples`` observations — a cold stage keeps factor 1.0
    and the planner stays bit-identical to an uncalibrated run) and
    **clamped** to ``clamp`` so one pathological record can never send
    the planner to a corner of the config lattice.

  * Publishing is **hysteretic**: the working EWMA updates on every
    record, but the *published* factor (the one the planner reads) only
    moves when the EWMA has drifted ``publish_rel_step`` away from it.
    Every publish bumps ``version`` — ``ESGScheduler`` folds the
    published factor tuple into its plan-cache keys, so a version bump
    is exactly a plan-cache invalidation for the affected stages and a
    stale cached plan can never survive a calibration step.  Hysteresis
    keeps those invalidations rare — and the defaults make "rare" mean
    *never on pure noise*: the warmup estimate is a running mean (so it
    leaves the gate carrying ``1/sqrt(min_samples)`` of the per-sample
    noise), and with ``alpha=0.1`` the steady-state EWMA wander under
    the emulator's default 5% execution noise is ~1.2%, putting the 5%
    deadband more than 4 sigma out.  An accurately profiled stage
    publishes nothing and the planner keeps its plan cache end to end;
    a genuinely mis-profiled stage still walks to its correction in a
    handful of coarse steps.  Deployments that want finer tracking (the
    calibration sweep pins 2% steps and a 5-sample warmup) buy it with
    more plan-cache invalidations.

The planner applies corrections through ``ProfileTable.scaled`` — a
priced-arrays-compatible multiplicative rescale of the stage's (times,
job_costs) — so the dual-blade search, the dominator SLO split and the
plan cache all see corrected estimates with no change to the search
machinery.  With no calibrator attached (the default everywhere), no
code path changes: the differential tests replay every serving scenario
bit-identically.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.obs.audit import AuditLog, PlanRecord

# one observed ratio outside this range is an outlier (a resize storm, a
# pathological noise draw), not a profile error — clip before the EWMA
# so a single record cannot drag the estimate far from the truth
RATIO_CLIP = (0.125, 8.0)


class ProfileCalibrator:
    """EWMA multiplicative per-(app, stage) exec-latency correction.

    ``factor(app, stage)`` is what the planner multiplies the stage's
    profile times (and, proportionally, job costs — billed cost scales
    with realized runtime) by.  It is 1.0 until ``min_samples`` records
    have been observed for the stage *and* the EWMA has moved at least
    ``publish_rel_step`` away from the last published value; it is
    always inside ``clamp``.
    """

    def __init__(self, alpha: float = 0.1, min_samples: int = 10,
                 clamp: tuple[float, float] = (0.25, 4.0),
                 publish_rel_step: float = 0.05,
                 headroom: float = 1.0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if clamp[0] <= 0 or clamp[0] > 1.0 or clamp[1] < 1.0:
            raise ValueError(f"clamp must bracket 1.0 with a positive "
                             f"floor, got {clamp}")
        if headroom < 1.0:
            raise ValueError(f"headroom must be >= 1.0, got {headroom}")
        self.alpha = alpha
        self.min_samples = min_samples
        self.clamp = clamp
        self.publish_rel_step = publish_rel_step
        # conservative margin multiplied into every published factor:
        # calibration removes the padding a mis-profiled table happened
        # to provide, so deployments facing noisy executors can keep a
        # few percent of it on purpose.  1.0 (default) = pure correction.
        self.headroom = headroom
        # per-(app, stage) [n, ewma, published] — one dict lookup per
        # observed record; ``_published`` mirrors the published slot for
        # the planner's read side and is only written on a publish
        self._state: dict[tuple[str, str], list] = {}
        self._published: dict[tuple[str, str], float] = {}
        # bumped on every published change; the scheduler folds the
        # published factors into plan-cache keys and drops its scaled-
        # table cache when the version moves
        self.version = 0
        self.updates = 0          # published factor changes
        self.observations = 0     # realized records consumed

    # ---- wiring ------------------------------------------------------------
    def attach(self, audit: AuditLog) -> "ProfileCalibrator":
        """Subscribe to an audit log's realized-record stream."""
        audit.subscribe(self.observe)
        return self

    # ---- the stream consumer ----------------------------------------------
    def observe(self, rec: PlanRecord) -> None:
        raw = rec.predicted_raw_ms
        realized = rec.realized_exec_ms
        if raw is None or realized is None or raw <= 0.0 or realized < 0.0:
            return
        self.observations += 1
        ratio = realized / raw
        lo, hi = RATIO_CLIP
        ratio = lo if ratio < lo else hi if ratio > hi else ratio
        key = (rec.app, rec.stage)
        st = self._state.get(key)
        if st is None:
            st = self._state[key] = [1, ratio, 1.0]
            n, ewma = 1, ratio
        else:
            n = st[0] = st[0] + 1
            prev = st[1]
            if n <= self.min_samples:
                # warmup: running mean, so the estimate leaving the gate
                # carries 1/sqrt(min_samples) of the per-sample noise —
                # an EWMA seeded on the first ratio alone keeps most of
                # one draw's variance and publishes right at warmup
                ewma = prev + (ratio - prev) / n
            else:
                ewma = (1.0 - self.alpha) * prev + self.alpha * ratio
            st[1] = ewma
        if n < self.min_samples:
            return
        lo, hi = self.clamp
        cand = ewma * self.headroom
        cand = lo if cand < lo else hi if cand > hi else cand
        pub = st[2]
        if abs(cand - pub) < self.publish_rel_step * pub:
            return
        st[2] = cand
        self._published[key] = cand
        self.version += 1
        self.updates += 1

    # ---- planner-side queries ----------------------------------------------
    @property
    def active(self) -> bool:
        """True once any correction has been published.  False for a
        cold or warmup-gated calibrator — the planner skips factor
        lookups entirely and stays on its uncorrected fast path."""
        return bool(self._published)

    def factor(self, app: str, stage: str) -> float:
        """Published multiplicative correction for (app, stage); 1.0
        during warmup and for never-observed stages."""
        return self._published.get((app, stage), 1.0)

    def factors(self, app: str, stages) -> tuple[float, ...]:
        """Published factors for a stage suffix, in order."""
        return tuple(self._published.get((app, s), 1.0) for s in stages)

    def samples(self, app: str, stage: str) -> int:
        st = self._state.get((app, stage))
        return st[0] if st else 0

    # ---- export ------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        """Structured state: published factors, working EWMAs, counts."""
        per_stage = {}
        for app, stage in sorted(set(self._state) | set(self._published)):
            st = self._state.get((app, stage))
            per_stage[f"{app}/{stage}"] = {
                "factor": self._published.get((app, stage), 1.0),
                "ewma": st[1] if st else None,
                "n": st[0] if st else 0,
            }
        return {
            "version": self.version,
            "updates": self.updates,
            "observations": self.observations,
            "alpha": self.alpha,
            "min_samples": self.min_samples,
            "clamp": list(self.clamp),
            "headroom": self.headroom,
            "per_stage": per_stage,
        }


def make_calibrator(recorder, scheduler,
                    **kw) -> Optional[ProfileCalibrator]:
    """Wire a calibrator between a recorder's audit stream and a
    scheduler that accepts one (``ESGScheduler``).  Returns the
    calibrator, or None when the recorder has no audit log or the
    scheduler has no ``calibrator`` attribute to accept it."""
    audit = getattr(recorder, "audit", None)
    if audit is None or not hasattr(scheduler, "calibrator"):
        return None
    cal = ProfileCalibrator(**kw).attach(audit)
    scheduler.calibrator = cal
    return cal
