"""Streaming metrics bus: windowed time-series sampled on simulated time.

Three series kinds, all bucketed into fixed windows of simulated
milliseconds (default 1 s):

  * **counter** — per-window sum of increments (tasks dispatched, plans
    run, sheds, PCIe demand/prefetch milliseconds, ...);
  * **gauge**   — last value observed in the window (queue depth, slice
    utilization, HBM occupancy, running tasks, ...);
  * **hist**    — per-window (count, sum, min, max) summary of observed
    values (queue waits, exec times, ...).

The bus is fed *online* from emulator/gateway/device hooks through the
flight recorder (``repro.obs.Recorder``) — no post-hoc scan of the run
— which is what a live dashboard, the ROADMAP's sharded-replay RSS
tracking and a Gym-style observation feed all need.  ``to_json`` /
``to_csv`` export the whole bus for dashboards;
``benchmarks/obs_overhead.py`` consumes it in CI.
"""
from __future__ import annotations

import csv
import json
import math
from typing import Any

COUNTER = "counter"
GAUGE = "gauge"
HIST = "hist"


class MetricsBus:
    def __init__(self, window_ms: float = 1000.0):
        if window_ms <= 0:
            raise ValueError(f"window_ms must be positive, got {window_ms}")
        self.window_ms = float(window_ms)
        # name -> (kind, {window_index -> value | [n, sum, min, max]})
        self.series: dict[str, tuple[str, dict[int, Any]]] = {}

    # ---- recording ---------------------------------------------------------
    def _win(self, t_ms: float) -> int:
        return int(t_ms // self.window_ms)

    def _data(self, name: str, kind: str) -> dict[int, Any]:
        got = self.series.get(name)
        if got is None:
            got = self.series[name] = (kind, {})
        elif got[0] != kind:
            raise ValueError(f"series {name!r} is a {got[0]}, not a {kind}")
        return got[1]

    def inc(self, name: str, t_ms: float, v: float = 1.0):
        d = self._data(name, COUNTER)
        w = self._win(t_ms)
        d[w] = d.get(w, 0.0) + v

    def gauge(self, name: str, t_ms: float, v: float):
        self._data(name, GAUGE)[self._win(t_ms)] = v

    def observe(self, name: str, t_ms: float, v: float):
        d = self._data(name, HIST)
        w = self._win(t_ms)
        cell = d.get(w)
        if cell is None:
            d[w] = [1, v, v, v]
        else:
            cell[0] += 1
            cell[1] += v
            cell[2] = min(cell[2], v)
            cell[3] = max(cell[3], v)

    # ---- queries -----------------------------------------------------------
    def total(self, name: str) -> float:
        """Sum of a counter across all windows (0.0 for unknown names)."""
        got = self.series.get(name)
        if got is None:
            return 0.0
        kind, d = got
        if kind != COUNTER:
            raise ValueError(f"series {name!r} is a {kind}, not a counter")
        return sum(d.values())

    def points(self, name: str) -> list[tuple[float, Any]]:
        """(window_start_ms, value) pairs in time order."""
        kind, d = self.series[name]
        return [(w * self.window_ms, d[w]) for w in sorted(d)]

    # ---- export ------------------------------------------------------------
    def as_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"window_ms": self.window_ms, "series": {}}
        for name in sorted(self.series):
            kind, _ = self.series[name]
            out["series"][name] = {
                "kind": kind,
                "points": [[t, v] if kind != HIST else [t, *v]
                           for t, v in self.points(name)],
            }
        return out

    def to_json(self, path: str) -> dict[str, Any]:
        doc = self.as_dict()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        return doc

    def to_csv(self, path: str) -> None:
        """Long-format CSV: one row per (series, window).  Hist windows
        fill count/sum/min/max, scalar kinds fill ``value``."""
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["series", "kind", "window_start_ms", "value",
                        "count", "sum", "min", "max"])
            for name in sorted(self.series):
                kind, _ = self.series[name]
                for t, v in self.points(name):
                    if kind == HIST:
                        w.writerow([name, kind, t, "", *v])
                    else:
                        w.writerow([name, kind, t, v, "", "", "", ""])

    def export(self, path: str):
        """Extension-dispatched export (.csv -> CSV, else JSON)."""
        if str(path).endswith(".csv"):
            return self.to_csv(path)
        return self.to_json(path)

    def rate_per_s(self, name: str) -> float:
        """Mean per-second rate of a counter over its observed span."""
        got = self.series.get(name)
        if not got or not got[1]:
            return 0.0
        kind, d = got
        span_ms = (max(d) - min(d) + 1) * self.window_ms
        return self.total(name) / span_ms * 1e3 if span_ms > 0 else math.inf
