"""SLO burn-rate health engine: online alerting over the metrics stream.

The flight recorder measures; this module *judges*.  A
:class:`HealthEngine` is fed the same online signals the recorder
samples into the metrics bus — request completions vs their SLOs, shed
decisions, per-window queue depth, cold starts, wasted prefetches, and
the audit stream's predicted-vs-realized records — and turns them into
structured, exportable :class:`AlertRecord` transitions that serving
components consume as early-warning signals:

  * **slo_burn_rate** (per app) — multi-window burn-rate alerting in
    the Google-SRE style.  Each app has an *error budget*: with an
    attainment target of ``slo_target`` (say 0.99), a fraction
    ``1 - slo_target`` of requests may miss their SLO.  The burn rate
    is the observed miss rate divided by that budget — burn 1.0 spends
    the budget exactly; burn 10 exhausts it 10x too fast.  An alert
    fires only when **both** a short and a long window burn above
    ``burn_threshold``: the long window keeps one transient blip from
    paging, the short window makes the alert *clear* quickly once the
    system recovers.  Shed requests count as misses — shedding protects
    the pool, not the SLO ledger.

  * **calibration_drift** (per app) — fast-vs-slow EWMA of the absolute
    predicted-vs-realized relative error from the planner audit stream.
    When the fast estimate pulls away from the slow baseline the
    profiles have *drifted* (as opposed to being merely wrong — a
    constant error calibrates away; drift means the world is changing
    faster than the calibrator's gate).

  * **queue_buildup** (cluster) — per-window queue-depth snapshots
    against an absolute depth threshold for ``sustain`` consecutive
    windows; clears on the first calm window.

  * **cold_start_spike** / **prefetch_waste_surge** (cluster) — a
    per-window count more than ``spike_mult`` x a trailing EWMA baseline
    (and above an absolute floor, so quiet runs cannot "spike" from 0 to
    2): keep-alive or the prefetch predictor has stopped matching the
    arrival pattern.

Consumers poll :meth:`firing` / :meth:`early_warning`; the gateway
inflates its predicted-queueing term under a firing burn-rate alert
(shedding doomed work *earlier* while the budget burns), and the
vertical autoscaler suppresses opportunistic quota grows so idle slices
stay free for the queued work the alert predicts.  Both hooks default
to ``health=None`` and change nothing when absent — the differential
replay tests stay bit-identical.

The engine runs on simulated time, uses no RNG, and is pure bookkeeping
— attaching it never changes a schedule unless a consumer is explicitly
wired to act on its alerts.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

from repro.obs.audit import AuditLog, PlanRecord

FIRING = "firing"
CLEARED = "cleared"

# alert kinds (the taxonomy documented in the README)
SLO_BURN = "slo_burn_rate"
CAL_DRIFT = "calibration_drift"
QUEUE_BUILDUP = "queue_buildup"
COLD_SPIKE = "cold_start_spike"
PREFETCH_WASTE = "prefetch_waste_surge"

ALERT_KINDS = (SLO_BURN, CAL_DRIFT, QUEUE_BUILDUP, COLD_SPIKE,
               PREFETCH_WASTE)


@dataclasses.dataclass
class AlertRecord:
    """One alert state transition (firing or cleared)."""
    t_ms: float
    kind: str                    # one of ALERT_KINDS
    app: Optional[str]           # None for cluster-scoped kinds
    state: str                   # firing | cleared
    value: float                 # the measurement that crossed
    threshold: float             # what it crossed
    detail: str = ""


class _Windowed:
    """Rolling (total, bad) counts over a fixed trailing span of
    simulated time, bucketed so old samples age out exactly."""

    def __init__(self, span_ms: float, bucket_ms: float):
        self.span = span_ms
        self.bucket = bucket_ms
        self._cells: dict[int, list[float]] = {}   # bucket -> [total, bad]
        self._total = 0.0
        self._bad = 0.0

    def add(self, t_ms: float, bad: bool):
        b = int(t_ms // self.bucket)
        cell = self._cells.get(b)
        if cell is None:
            self._cells[b] = [1.0, 1.0 if bad else 0.0]
        else:
            cell[0] += 1.0
            if bad:
                cell[1] += 1.0
        self._total += 1.0
        if bad:
            self._bad += 1.0

    def rates(self, now_ms: float) -> tuple[float, float]:
        """(total, bad_fraction) over the trailing span; prunes.

        O(1) amortized: totals are maintained on ``add`` and cells are
        expired from the front of the (insertion- and therefore time-
        ordered, since feeds run on monotone simulated time) dict."""
        lo = int((now_ms - self.span) // self.bucket)
        cells = self._cells
        while cells:
            b = next(iter(cells))
            if b >= lo:
                break
            total, bad = cells.pop(b)
            self._total -= total
            self._bad -= bad
        total = self._total
        return total, (self._bad / total if total else 0.0)


class HealthEngine:
    """Multi-window SLO burn-rate tracking + drift/anomaly detectors.

    ``slo_targets`` maps app name -> attainment target (fraction of
    requests that must meet their SLO); unmapped apps use
    ``default_target``.  All feeds take the current simulated time —
    the engine has no clock of its own.
    """

    def __init__(self,
                 slo_targets: Optional[dict[str, float]] = None,
                 default_target: float = 0.99,
                 short_ms: float = 10_000.0,
                 long_ms: float = 60_000.0,
                 burn_threshold: float = 2.0,
                 min_requests: int = 10,
                 drift_fast_alpha: float = 0.3,
                 drift_slow_alpha: float = 0.03,
                 drift_threshold: float = 0.15,
                 drift_min_samples: int = 10,
                 queue_depth_limit: int = 64,
                 queue_sustain: int = 3,
                 spike_mult: float = 4.0,
                 spike_floor: float = 8.0):
        self.slo_targets = dict(slo_targets or {})
        self.default_target = default_target
        self.burn_threshold = burn_threshold
        self.min_requests = min_requests
        self.drift_fast_alpha = drift_fast_alpha
        self.drift_slow_alpha = drift_slow_alpha
        self.drift_threshold = drift_threshold
        self.drift_min_samples = drift_min_samples
        self.queue_depth_limit = queue_depth_limit
        self.queue_sustain = queue_sustain
        self.spike_mult = spike_mult
        self.spike_floor = spike_floor
        bucket = max(short_ms / 10.0, 1.0)
        self._mk_short = lambda: _Windowed(short_ms, bucket)
        self._mk_long = lambda: _Windowed(long_ms, bucket)
        self._short: dict[str, _Windowed] = {}
        self._long: dict[str, _Windowed] = {}
        self._budget: dict[str, float] = {}    # per-app error budget
        # per-app [fast, slow, n] |relative error| EWMAs + sample count
        self._drift: dict[str, list] = {}
        self._q_high = 0                       # consecutive deep windows
        self._spike_base: dict[str, float] = {}  # kind -> EWMA baseline
        # (kind, app) -> the AlertRecord currently firing
        self._active: dict[tuple[str, Optional[str]], AlertRecord] = {}
        self.alerts: list[AlertRecord] = []    # full transition history

    # ------------------------------------------------------------------
    # wiring
    # ------------------------------------------------------------------
    def attach_audit(self, audit: AuditLog) -> "HealthEngine":
        """Subscribe the calibration-drift detector to an audit log."""
        audit.subscribe(self.observe_calibration)
        return self

    # ------------------------------------------------------------------
    # alert bookkeeping
    # ------------------------------------------------------------------
    def _transition(self, now: float, kind: str, app: Optional[str],
                    fire: bool, value: float, threshold: float,
                    detail=""):
        """``detail`` may be a zero-arg callable: the engine is fed on
        every request/record/window but transitions are rare, so detail
        strings are only rendered when a record is actually emitted."""
        key = (kind, app)
        active = self._active.get(key)
        if fire == (active is not None):
            return
        if callable(detail):
            detail = detail()
        if fire:
            rec = AlertRecord(now, kind, app, FIRING, value, threshold,
                              detail)
            self._active[key] = rec
            self.alerts.append(rec)
        else:
            del self._active[key]
            self.alerts.append(AlertRecord(now, kind, app, CLEARED, value,
                                           threshold, detail))

    # ------------------------------------------------------------------
    # feeds (called by the Recorder hooks / audit subscription)
    # ------------------------------------------------------------------
    def on_request(self, app: str, now: float, ok: bool):
        """One finished (or shed) request: ``ok`` is SLO attainment."""
        short = self._short.get(app)
        if short is None:
            short = self._short[app] = self._mk_short()
            self._long[app] = self._mk_long()
            budget = 1.0 - self.slo_targets.get(app, self.default_target)
            if budget <= 0.0:
                budget = 1e-9                   # a 100% target burns instantly
            self._budget[app] = budget
        long = self._long[app]
        bad = not ok
        short.add(now, bad)
        long.add(now, bad)
        budget = self._budget[app]
        n_s, miss_s = short.rates(now)
        burn_s = miss_s / budget
        thr = self.burn_threshold
        if (SLO_BURN, app) not in self._active:
            # fire only on evidence in BOTH windows; the long window is
            # not even consulted until the short one burns — on a
            # healthy stream this is the whole evaluation
            if n_s < self.min_requests or burn_s < thr:
                return
            n_l, miss_l = long.rates(now)
            burn_l = miss_l / budget
            if burn_l < thr:
                return
            self._transition(
                now, SLO_BURN, app, True, max(burn_s, burn_l), thr,
                lambda: f"burn short={burn_s:.2f} long={burn_l:.2f} "
                        f"(n={n_s:.0f}/{n_l:.0f}, budget={budget:.4f})")
        elif burn_s < thr:
            # clear as soon as the short window recovers
            self._transition(
                now, SLO_BURN, app, False, burn_s, thr,
                lambda: f"burn short={burn_s:.2f} "
                        f"(n={n_s:.0f}, budget={budget:.4f})")

    def on_shed(self, app: str, now: float):
        """A shed request spends error budget like an SLO miss."""
        self.on_request(app, now, ok=False)

    def observe_calibration(self, rec: PlanRecord) -> None:
        """Audit-stream subscriber: fast-vs-slow |relative error| drift."""
        if rec.predicted_ms is None or rec.realized_ms is None \
                or rec.predicted_ms <= 0:
            return
        err = abs(rec.realized_ms - rec.predicted_ms) / rec.predicted_ms
        st = self._drift.get(rec.app)
        if st is None:
            st = self._drift[rec.app] = [err, err, 0]
        fa, sa = self.drift_fast_alpha, self.drift_slow_alpha
        fast = st[0] = (1.0 - fa) * st[0] + fa * err
        slow = st[1] = (1.0 - sa) * st[1] + sa * err
        n = st[2] = st[2] + 1
        if n < self.drift_min_samples:
            return
        gap = fast - slow
        fire = gap >= self.drift_threshold
        if fire or (CAL_DRIFT, rec.app) in self._active:
            self._transition(
                rec.t_ms, CAL_DRIFT, rec.app, fire,
                gap, self.drift_threshold,
                lambda: f"|err| ewma fast={fast:.3f} slow={slow:.3f} "
                        f"(n={n})")

    def on_window(self, now: float, queue_depth: float,
                  cold_starts: float, prefetch_wasted: float):
        """Per-metrics-window cluster snapshot (fed by the recorder)."""
        # queue buildup: sustained absolute depth
        if queue_depth >= self.queue_depth_limit:
            self._q_high += 1
        else:
            self._q_high = 0
        fire = self._q_high >= self.queue_sustain
        if fire or (QUEUE_BUILDUP, None) in self._active:
            self._transition(
                now, QUEUE_BUILDUP, None, fire,
                queue_depth, float(self.queue_depth_limit),
                lambda: f"depth {queue_depth:.0f} for "
                        f"{self._q_high} window(s)")
        # spike detectors: current window vs trailing EWMA baseline
        for kind, v in ((COLD_SPIKE, cold_starts),
                        (PREFETCH_WASTE, prefetch_wasted)):
            base = self._spike_base.get(kind, 0.0)
            limit = max(self.spike_mult * base, self.spike_floor)
            fire = v >= limit
            if fire or (kind, None) in self._active:
                self._transition(now, kind, None, fire, v, limit,
                                 lambda v=v, base=base:
                                     f"window={v:.0f} baseline={base:.2f}")
            self._spike_base[kind] = 0.8 * base + 0.2 * v

    # ------------------------------------------------------------------
    # consumer queries
    # ------------------------------------------------------------------
    def firing(self, kind: Optional[str] = None,
               app: Optional[str] = None) -> list[AlertRecord]:
        """Currently-active alerts, optionally filtered by kind/app."""
        return [a for a in self._active.values()
                if (kind is None or a.kind == kind)
                and (app is None or a.app == app)]

    def early_warning(self, app: Optional[str] = None) -> bool:
        """True when the app (or the cluster) should act defensively:
        its own burn-rate/drift alert is firing, or any cluster-scoped
        alert is."""
        for a in self._active.values():
            if a.app is None or app is None or a.app == app:
                return True
        return False

    def burn_rate(self, app: str, now: float) -> tuple[float, float]:
        """(short, long) burn rates for an app right now."""
        budget = 1.0 - self.slo_targets.get(app, self.default_target)
        if budget <= 0.0:
            budget = 1e-9
        short = self._short.get(app)
        if short is None:
            return 0.0, 0.0
        _, miss_s = short.rates(now)
        _, miss_l = self._long[app].rates(now)
        return miss_s / budget, miss_l / budget

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        counts: dict[str, int] = {}
        for a in self.alerts:
            counts[f"{a.kind}:{a.state}"] = \
                counts.get(f"{a.kind}:{a.state}", 0) + 1
        return {
            "alerts_total": len(self.alerts),
            "active": sorted(f"{a.kind}"
                             + (f"[{a.app}]" if a.app else "")
                             for a in self._active.values()),
            "transitions": counts,
        }

    def export_jsonl(self, path: str) -> int:
        """One JSON object per alert transition, in emission order."""
        n = 0
        with open(path, "w") as f:
            for a in self.alerts:
                f.write(json.dumps({"type": "alert",
                                    **dataclasses.asdict(a)},
                                   sort_keys=True) + "\n")
                n += 1
        return n
