"""Flight recorder: span tracing + streaming metrics + planner audit.

The whole observability layer hangs off one :class:`Recorder` facade the
emulator, gateway, scheduler and device model call into through narrow
``on_*`` hooks.  Three components fan out behind it:

  * :class:`~repro.obs.tracer.SpanTracer` — per-request span traces,
    exported as Chrome-trace/Perfetto JSON;
  * :class:`~repro.obs.metrics.MetricsBus` — windowed gauge/counter/hist
    time-series sampled online on simulated time;
  * :class:`~repro.obs.audit.AuditLog` — one structured record per
    ``plan()`` call and per sparse-skip decision, with predicted-vs-
    realized calibration back-filled at task completion.

The default is :data:`NULL_RECORDER`, a null object whose ``enabled``
flag is False: every instrumentation site guards with ``if
rec.enabled:`` so the disabled path allocates nothing, consumes no RNG,
and replays bit-identical to an uninstrumented build (the differential
tests in ``tests/test_observability.py`` pin all six serving scenarios).
Recording never feeds back into scheduling by itself — an enabled
recorder changes no decision, cost or SLO outcome.  Feedback is opt-in
and explicit: a :class:`~repro.obs.calibrate.ProfileCalibrator`
subscribed to the audit stream and handed to ``ESGScheduler``, and/or a
:class:`~repro.obs.health.HealthEngine` (``Recorder(health=...)``)
whose alerts the gateway and autoscaler may consume.  With neither
attached, recorded runs replay bit-identically.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.obs.audit import AuditLog, PlanRecord, RetryRecord, SkipRecord
from repro.obs.calibrate import ProfileCalibrator
from repro.obs.health import AlertRecord, HealthEngine
from repro.obs.metrics import COUNTER, GAUGE, HIST, MetricsBus
from repro.obs.tracer import SpanTracer

__all__ = ["Recorder", "NullRecorder", "NULL_RECORDER", "SpanTracer",
           "MetricsBus", "AuditLog", "PlanRecord", "SkipRecord",
           "RetryRecord", "ProfileCalibrator", "HealthEngine",
           "AlertRecord"]


class NullRecorder:
    """Disabled recorder: one shared instance, no state, no overhead.

    Every hook site checks ``enabled`` before doing *any* work, so the
    null object needs no methods at all — it is a flag, not a stub."""
    enabled = False

    def __repr__(self):
        return "NullRecorder()"


NULL_RECORDER = NullRecorder()


class Recorder:
    """Enabled flight recorder wired through ``ClusterSim(recorder=...)``.

    Any of the three components can be switched off at construction
    (e.g. metrics-only sampling for a dashboard feed); the hooks skip
    absent components.
    """
    enabled = True

    def __init__(self, trace: bool = True, metrics: bool = True,
                 audit: bool = True, window_ms: float = 1000.0,
                 health: Optional[HealthEngine] = None):
        self.tracer: Optional[SpanTracer] = SpanTracer() if trace else None
        self.metrics: Optional[MetricsBus] = \
            MetricsBus(window_ms=window_ms) if metrics else None
        self.audit: Optional[AuditLog] = AuditLog() if audit else None
        # the health engine rides the streaming side of the bus: its
        # per-window feeds (queue depth, cold-start and prefetch-waste
        # counts) come out of the same snapshot the metrics gauges use
        if health is not None and self.metrics is None:
            raise ValueError("HealthEngine requires metrics=True (it is "
                             "fed from the metrics windows)")
        self.health: Optional[HealthEngine] = health
        if health is not None and self.audit is not None:
            health.attach_audit(self.audit)
        self._pf_wasted_seen = 0
        # delta trackers for cumulative emulator/engine counters sampled
        # per event into windowed counter series
        self._xfer_seen = (0.0, 0.0)     # (demand_ms, prefetch_ms)
        self._sheds_seen = 0
        # gauge sampling is throttled to one snapshot per metrics window
        # (the cluster-wide sums are O(invokers) — cheap once a second of
        # sim time, hot if taken on every event)
        self._last_win = -1
        # hot-path handles: the per-event/per-task hooks run inside the
        # emulator's inner loop, so they update the bus's window dicts
        # directly instead of going through inc()/observe() each time
        # (same cells, same math — just no per-call dispatch)
        self._evt_data: dict[str, dict] = {}
        # bind_sim fills these so the per-window snapshot walks plain
        # lists instead of attribute chains over the invoker fleet
        self._devices: list = []
        self._total_slices = 0
        if self.metrics:
            m = self.metrics
            self._wms = m.window_ms
            self._m_tasks = m._data("tasks", COUNTER)
            self._m_jobs = m._data("jobs", COUNTER)
            self._m_cold = m._data("cold_starts", COUNTER)
            self._m_plans = m._data("plans", COUNTER)
            self._m_qwait = m._data("queue_wait_ms", HIST)
            self._m_exec = m._data("exec_ms", HIST)
            gd = GAUGE
            self._g_depth = m._data("queue_depth", gd)
            self._g_running = m._data("running_tasks", gd)
            self._g_slices = m._data("slices_used", gd)
            self._g_util = m._data("slice_util", gd)
            self._g_hbm = m._data("hbm_used_mb", gd)
            self._m_xfer_d = m._data("xfer_demand_ms", COUNTER)
            self._m_xfer_p = m._data("xfer_prefetch_ms", COUNTER)

    # ------------------------------------------------------------------
    # wiring
    # ------------------------------------------------------------------
    def bind_sim(self, sim) -> "Recorder":
        """Attach to a ClusterSim: point every invoker's device + engine
        back at this recorder so transfer/demotion events land on the
        right device track."""
        for inv in sim.invokers:
            inv.device.recorder = self
            inv.device.device_id = inv.idx
            inv.device.engine.recorder = self
            inv.device.engine.device_id = inv.idx
        self._devices = [inv.device for inv in sim.invokers]
        self._total_slices = sum(d.total_slices for d in self._devices)
        return self

    # ------------------------------------------------------------------
    # gateway
    # ------------------------------------------------------------------
    def on_injected(self, app: str, now: float):
        if self.metrics:
            self.metrics.inc("injected", now)

    def on_admitted(self, inst, now: float):
        if self.tracer:
            self.tracer.begin_request(inst.uid, inst.app.name, now)
        if self.metrics:
            self.metrics.inc("admitted", now)

    def on_shed(self, inst, now: float, budget_ms: float, need_ms: float):
        if self.tracer:
            self.tracer.shed_request(inst.uid, inst.app.name, now,
                                     budget_ms, need_ms)
        if self.metrics:
            self.metrics.inc("shed", now)
        if self.health:
            self.health.on_shed(inst.app.name, now)

    # ------------------------------------------------------------------
    # emulator lifecycle
    # ------------------------------------------------------------------
    def on_dispatch(self, sim, task):
        now = sim.now
        if self.metrics:
            w = int(now // self._wms)
            d = self._m_tasks
            d[w] = d.get(w, 0.0) + 1.0
            d = self._m_jobs
            d[w] = d.get(w, 0.0) + len(task.jobs)
            if task.cold:
                d = self._m_cold
                d[w] = d.get(w, 0.0) + 1.0
            dq = self._m_qwait
            start = task.start_ms
            for job in task.jobs:
                v = start - job.ready_ms
                if v < 0.0:
                    v = 0.0
                cell = dq.get(w)
                if cell is None:
                    dq[w] = [1, v, v, v]
                else:
                    cell[0] += 1
                    cell[1] += v
                    if v < cell[2]:
                        cell[2] = v
                    if v > cell[3]:
                        cell[3] = v
        if self.audit:
            # predicted from the *controller's* view (the planner's
            # ProfileTable, which may diverge from the emulator's ground
            # truth under injected skew or drift), split into the raw
            # profile estimate and the planner's working prediction
            # (raw x the calibrator's published correction + penalty) —
            # identical when no calibrator is attached
            app_name = task.jobs[0].inst.app.name
            raw = sim.tables[task.func].fn.exec_ms(task.config)
            cal = getattr(sim.sched, "calibrator", None)
            f = cal.factor(app_name, task.stage) \
                if cal is not None and cal.active else 1.0
            self.audit.on_dispatch(
                app_name, task.stage, task.tid, task.config,
                predicted_ms=raw * f + task.penalty_ms,
                predicted_raw_ms=raw)

    def on_task_complete(self, sim, task):
        now = sim.now
        if self.metrics:
            w = int(now // self._wms)
            v = now - task.exec_start_ms
            de = self._m_exec
            cell = de.get(w)
            if cell is None:
                de[w] = [1, v, v, v]
            else:
                cell[0] += 1
                cell[1] += v
                if v < cell[2]:
                    cell[2] = v
                if v > cell[3]:
                    cell[3] = v
        if self.audit:
            self.audit.on_complete(task.tid, now - task.start_ms,
                                   realized_exec_ms=now - task.exec_start_ms)
        if self.health:
            for job in task.jobs:
                inst = job.inst
                if inst.done and inst.finish_ms == now:
                    ok = inst.finish_ms - inst.arrival_ms <= inst.slo_ms
                    self.health.on_request(inst.app.name, now, ok)
        if self.tracer:
            args = {"stage": task.stage, "func": task.func,
                    "config": task.config, "tier": task.tier,
                    "invoker": task.invoker,
                    "quota_slices": task.quota_slices,
                    "penalty_ms": task.penalty_ms,
                    "hidden_ms": task.full_penalty_ms - task.penalty_ms,
                    "cold": task.cold}
            for job in task.jobs:
                self.tracer.stage_spans(
                    job.inst.uid, task.stage, job.ready_ms, task.start_ms,
                    task.exec_start_ms, now, args)
                inst = job.inst
                if inst.done and inst.finish_ms == now:
                    self.tracer.end_request(inst.uid, now, inst.slo_ms)

    def on_resize(self, sim, task, old_slices: int, new_slices: int):
        now = sim.now
        if self.metrics:
            self.metrics.inc("resizes", now)
        if self.tracer:
            for job in task.jobs:
                self.tracer.resize_instant(job.inst.uid, now, task.invoker,
                                           old_slices, new_slices)

    def on_plan_result(self, rec: PlanRecord):
        if self.audit:
            self.audit.on_plan(rec)

    def on_sparse_skip(self, now: float, app: str, stage: str,
                       certificate: Any, recheck: int):
        if self.audit:
            self.audit.on_skip(now, app, stage, certificate, recheck)
        if self.metrics:
            self.metrics.inc("sparse_skips", now)

    def on_prefetch_issued(self, now: float, n: int):
        if self.metrics and n:
            self.metrics.inc("prefetch_enqueued", now, n)

    def on_retire(self, now: float):
        if self.metrics:
            self.metrics.inc("retires", now)

    # ------------------------------------------------------------------
    # preemptible fleet (spot reclamations)
    # ------------------------------------------------------------------
    def on_reclaim_warning(self, now: float, inv_idx: int):
        if self.metrics:
            self.metrics.inc("reclaim_warnings", now)
        if self.tracer:
            self.tracer.reclaim_instant(inv_idx, now, "reclaim_warning")

    def on_reclaim(self, now: float, inv_idx: int, n_killed: int):
        if self.metrics:
            self.metrics.inc("reclamations", now)
        if self.tracer:
            self.tracer.reclaim_instant(inv_idx, now, "reclaim",
                                        {"killed_tasks": n_killed})

    def on_recover(self, now: float, inv_idx: int):
        if self.metrics:
            self.metrics.inc("recoveries", now)
        if self.tracer:
            self.tracer.reclaim_instant(inv_idx, now, "recover")

    def on_preempt(self, sim, task, lost_ms: float):
        """A running task was killed mid-execution by a reclamation."""
        now = sim.now
        if self.metrics:
            self.metrics.inc("preemptions", now)
            if lost_ms > 0.0:
                self.metrics.inc("preempt_lost_ms", now, lost_ms)
        if self.audit:
            # the partial run must never back-fill calibration
            self.audit.on_preempted(task.tid)
        if self.tracer:
            args = {"stage": task.stage, "func": task.func,
                    "invoker": task.invoker, "config": task.config,
                    "lost_ms": lost_ms}
            for job in task.jobs:
                self.tracer.preempt_span(job.inst.uid, task.stage,
                                         task.start_ms, now, args)

    def on_retry_decision(self, now: float, app: str, stage: str, uid: int,
                          invoker: int, attempt: int, action: str,
                          backoff_ms: float, lost_ms: float):
        if self.audit:
            self.audit.on_retry(now, app, stage, uid, invoker, attempt,
                                action, backoff_ms, lost_ms)
        if self.metrics:
            self.metrics.inc(
                "preempt_shed" if action == "shed" else "retries", now)

    def on_migrate(self, now: float, inv_idx: int, moved: int):
        if self.metrics and moved:
            self.metrics.inc("migrations", now, moved)
        if self.tracer:
            self.tracer.reclaim_instant(inv_idx, now, "migrate",
                                        {"moved": moved})

    # ------------------------------------------------------------------
    # device / transfer engine
    # ------------------------------------------------------------------
    def on_transfer(self, device_id: int, transfer, issued_as: str):
        if self.tracer:
            self.tracer.note_transfer(device_id, transfer, issued_as)

    def on_promote(self, device_id: int, func: str, now: float):
        if self.tracer:
            self.tracer.promote_instant(device_id, func, now)

    def on_demotion(self, device_id: int, func: str, now: float):
        if self.tracer:
            self.tracer.demotion_instant(device_id, func, now)
        if self.metrics:
            self.metrics.inc("demotions", now)

    # ------------------------------------------------------------------
    # per-event sampling (the streaming side of the bus)
    # ------------------------------------------------------------------
    def on_event(self, sim, kind: str):
        m = self.metrics
        if m is None:
            return
        now = sim.now
        d = self._evt_data.get(kind)
        if d is None:
            d = self._evt_data[kind] = m._data("events." + kind, COUNTER)
        win = int(now // self._wms)
        d[win] = d.get(win, 0.0) + 1.0
        # cluster-wide gauges: first event of each window snapshots them
        if win == self._last_win:
            return
        prev_win = self._last_win
        self._last_win = win
        used = 0
        hbm = demand = pref = 0.0
        for dev in self._devices:
            used += dev.used_slices
            hbm += dev.hbm_used_mb
            eng = dev.engine
            demand += eng.demand_ms
            pref += eng.prefetch_ms
        total = self._total_slices
        depth = sum(len(q) for q in sim.queues.values())
        self._g_depth[win] = depth
        self._g_running[win] = len(sim.running)
        self._g_slices[win] = used
        self._g_util[win] = used / total if total else 0.0
        self._g_hbm[win] = hbm
        # transfer-link busy split: cumulative engine counters turned
        # into per-window deltas
        d0, p0 = self._xfer_seen
        if demand > d0:
            dd = self._m_xfer_d
            dd[win] = dd.get(win, 0.0) + (demand - d0)
        if pref > p0:
            dp = self._m_xfer_p
            dp[win] = dp.get(win, 0.0) + (pref - p0)
        self._xfer_seen = (demand, pref)
        if self.health is not None:
            # anomaly feeds: the just-closed window's cold-start count,
            # the wasted-prefetch delta since the last snapshot, and the
            # instantaneous queue depth
            wasted = sum(dev.stats.prefetch_wasted
                         for dev in self._devices)
            self.health.on_window(now, depth,
                                  self._m_cold.get(prev_win, 0.0),
                                  wasted - self._pf_wasted_seen)
            self._pf_wasted_seen = wasted

    def on_plan_timed(self, sim):
        if self.metrics:
            d = self._m_plans
            w = int(sim.now // self._wms)
            d[w] = d.get(w, 0.0) + 1.0

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def calibration(self) -> dict[str, Any]:
        return self.audit.calibration() if self.audit else {}

    def export(self, trace_path: Optional[str] = None,
               metrics_path: Optional[str] = None,
               audit_path: Optional[str] = None,
               health_path: Optional[str] = None) -> dict[str, Any]:
        out: dict[str, Any] = {}
        if trace_path and self.tracer:
            out["trace"] = trace_path
            self.tracer.export_chrome_trace(trace_path)
        if metrics_path and self.metrics:
            out["metrics"] = metrics_path
            self.metrics.export(metrics_path)
        if audit_path and self.audit:
            out["audit"] = audit_path
            self.audit.export_jsonl(audit_path)
        if health_path and self.health:
            out["health"] = health_path
            self.health.export_jsonl(health_path)
        return out
