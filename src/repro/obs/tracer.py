"""Per-request span tracing with a Chrome-trace/Perfetto exporter.

Every admitted request becomes one *trace*: a Chrome-trace "process"
(pid) whose track carries the request's end-to-end span plus one span
per lifecycle phase of every stage execution it took part in —

  * ``queue``  — job ready -> task start (per-stage queue wait),
  * ``xfer``   — task start -> exec start (the restart penalty window:
                 weight swap-in / cold provisioning, annotated with the
                 hot/warm/cold start class and, under the overlapped
                 swap pipeline, whether a prefetch hid part of it),
  * ``exec``   — exec start -> task end (annotated with the dispatched
                 config, the fractional slice quota and every vertical
                 resize applied while running),

with ``admit``/``shed`` instants from the gateway.  Each emulated
device gets its own process whose tracks carry the PCIe transfer
engine's copies (cat ``pcie``: demand vs prefetch, promotions) and HBM
demotion instants — exactly the two places FaaSTube-style hidden
transfer time can accumulate.

Spans are recorded as plain tuples during the run and materialised into
Chrome-trace JSON only at export, where partially-overlapping spans
(parallel DAG branches, concurrent copies) are assigned to
non-overlapping lanes (tids) so the file loads cleanly in
``ui.perfetto.dev`` / ``chrome://tracing``.

Timestamps are *simulated* milliseconds, written as the microsecond
``ts``/``dur`` fields the format requires.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Optional

# pid layout: requests get 10000+uid, devices 100+idx — disjoint for any
# realistic fleet, and stable across runs for diffable golden traces.
REQUEST_PID_BASE = 10_000
DEVICE_PID_BASE = 100

_US = 1e3   # ms -> us


@dataclasses.dataclass
class _Span:
    name: str
    cat: str
    t0_ms: float
    t1_ms: float
    pid: int
    args: Optional[dict] = None


@dataclasses.dataclass
class _Instant:
    name: str
    cat: str
    t_ms: float
    pid: int
    args: Optional[dict] = None


class SpanTracer:
    """Collects spans/instants; exports Chrome-trace JSON."""

    def __init__(self):
        self._spans: list[_Span] = []
        self._instants: list[_Instant] = []
        self._procs: dict[int, str] = {}
        # request span bookkeeping: uid -> (app, arrival_ms)
        self._open_requests: dict[int, tuple[str, float]] = {}
        # transfers are resolved lazily at export (a queued prefetch's
        # completion time is only known once the engine drains it)
        self._transfers: list[tuple[int, Any, str]] = []
        # stage lifecycles land as raw tuples on the hot path and are
        # expanded into queue/xfer/exec spans only at export
        self._stages: list[tuple] = []

    # ---- request lifecycle -------------------------------------------------
    @staticmethod
    def request_pid(uid: int) -> int:
        return REQUEST_PID_BASE + uid

    def begin_request(self, uid: int, app: str, t_ms: float):
        pid = self.request_pid(uid)
        self._procs[pid] = f"req {app}#{uid}"
        self._open_requests[uid] = (app, t_ms)
        self._instants.append(_Instant("admit", "gateway", t_ms, pid))

    def end_request(self, uid: int, t_ms: float, slo_ms: float):
        got = self._open_requests.pop(uid, None)
        if got is None:
            return               # already ended (multi-sink DAG completion)
        app, arr = got
        lat = t_ms - arr
        self._spans.append(_Span(
            f"{app}#{uid}", "request", arr, t_ms, self.request_pid(uid),
            {"latency_ms": lat, "slo_ms": slo_ms,
             "slo_hit": bool(lat <= slo_ms)}))

    def shed_request(self, uid: int, app: str, t_ms: float,
                     budget_ms: float, need_ms: float):
        pid = self.request_pid(uid)
        self._procs[pid] = f"req {app}#{uid} (shed)"
        self._instants.append(_Instant(
            "shed", "gateway", t_ms, pid,
            {"budget_ms": budget_ms, "need_ms": need_ms}))

    # ---- stage lifecycle ---------------------------------------------------
    def stage_spans(self, uid: int, stage: str, ready_ms: float,
                    start_ms: float, exec_start_ms: float, end_ms: float,
                    args: dict):
        """One request's share of a finished task, all three phases
        (recorded raw; expanded at export)."""
        self._stages.append((uid, stage, ready_ms, start_ms, exec_start_ms,
                             end_ms, args))

    def _expand_stages(self):
        for uid, stage, ready_ms, start_ms, exec_start_ms, end_ms, args \
                in self._stages:
            pid = self.request_pid(uid)
            if start_ms > ready_ms:
                yield _Span(f"queue:{stage}", "queue", ready_ms, start_ms,
                            pid)
            if exec_start_ms > start_ms:
                yield _Span(
                    f"{args.get('tier', '?')}-start:{stage}", "xfer",
                    start_ms, exec_start_ms, pid,
                    {k: args[k] for k in ("tier", "invoker", "penalty_ms",
                                          "hidden_ms") if k in args})
            yield _Span(f"exec:{stage}", "exec", exec_start_ms, end_ms,
                        pid, args)

    def preempt_span(self, uid: int, stage: str, t0_ms: float, t1_ms: float,
                     args: dict):
        """A stage execution killed by a spot reclamation: the span covers
        task start -> kill, so the lost work is visible on the request's
        track right where the retry's queue span begins."""
        self._spans.append(_Span(f"preempt:{stage}", "preempt", t0_ms,
                                 t1_ms, self.request_pid(uid), args))

    def reclaim_instant(self, device: int, t_ms: float, name: str,
                        args: Optional[dict] = None):
        """Reclamation lifecycle marker (warning / reclaim / recover) on
        the device's own track."""
        self._instants.append(_Instant(
            name, "reclaim", t_ms, self.device_pid(device), args))

    def resize_instant(self, uid: int, t_ms: float, invoker: int,
                       old_slices: int, new_slices: int):
        self._instants.append(_Instant(
            "resize", "resize", t_ms, self.request_pid(uid),
            {"invoker": invoker, "from": old_slices, "to": new_slices}))

    # ---- device tracks -----------------------------------------------------
    def device_pid(self, idx: int) -> int:
        pid = DEVICE_PID_BASE + idx
        if pid not in self._procs:
            self._procs[pid] = f"device {idx}"
        return pid

    def note_transfer(self, device: int, transfer, issued_as: str):
        self.device_pid(device)
        self._transfers.append((device, transfer, issued_as))

    def promote_instant(self, device: int, func: str, t_ms: float):
        self._instants.append(_Instant(
            f"promote:{func}", "pcie", t_ms, self.device_pid(device)))

    def demotion_instant(self, device: int, func: str, t_ms: float):
        self._instants.append(_Instant(
            f"demote:{func}", "hbm", t_ms, self.device_pid(device)))

    # ---- export ------------------------------------------------------------
    def _resolve_transfers(self):
        """Turn noted engine transfers into spans/instants (done copies
        get a span over their link lifetime, cancelled/still-pending
        copies an instant at enqueue)."""
        for device, tr, issued_as in self._transfers:
            pid = self.device_pid(device)
            if math.isfinite(tr.done_ms):
                promoted = issued_as != tr.kind
                yield _Span(
                    f"{tr.kind}:{tr.func}", "pcie", tr.enq_ms, tr.done_ms,
                    pid, {"issued_as": issued_as, "promoted": promoted,
                          "copy_ms": tr.total_ms})
            else:
                state = "cancelled" if tr.remaining_ms <= 0 else "pending"
                self._instants.append(_Instant(
                    f"{state}:{tr.func}", "pcie", tr.enq_ms, pid,
                    {"issued_as": issued_as}))

    @staticmethod
    def _assign_lanes(spans: list[_Span]) -> list[tuple[_Span, int]]:
        """Greedy interval partitioning: spans that overlap in time get
        distinct lanes (tids), so Perfetto never sees a slice that ends
        after a later-starting sibling began.  ``request``-cat spans
        contain everything on their pid and stay on lane 0 (contained
        slices nest correctly on the same track)."""
        out: list[tuple[_Span, int]] = []
        lanes: list[float] = []          # lane -> busy-until
        for s in sorted(spans, key=lambda s: (s.cat != "request",
                                              s.t0_ms, -s.t1_ms)):
            if s.cat == "request":
                out.append((s, 0))
                continue
            for i, busy in enumerate(lanes):
                if busy <= s.t0_ms + 1e-9:
                    lanes[i] = s.t1_ms
                    out.append((s, i))
                    break
            else:
                lanes.append(s.t1_ms)
                out.append((s, len(lanes) - 1))
        return out

    def events(self) -> list[dict]:
        """Chrome-trace event dicts, deterministic order."""
        spans = list(self._spans)
        spans.extend(self._expand_stages())
        spans.extend(self._resolve_transfers())
        by_pid: dict[int, list[_Span]] = {}
        for s in spans:
            by_pid.setdefault(s.pid, []).append(s)
        ev: list[dict] = []
        for pid in sorted(self._procs):
            ev.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": self._procs[pid]}})
        for pid in sorted(by_pid):
            for s, lane in self._assign_lanes(by_pid[pid]):
                e = {"ph": "X", "name": s.name, "cat": s.cat,
                     "ts": s.t0_ms * _US,
                     "dur": max(s.t1_ms - s.t0_ms, 0.0) * _US,
                     "pid": s.pid, "tid": lane}
                if s.args:
                    e["args"] = s.args
                ev.append(e)
        for i in sorted(range(len(self._instants)),
                        key=lambda i: (self._instants[i].pid,
                                       self._instants[i].t_ms, i)):
            s = self._instants[i]
            e = {"ph": "i", "name": s.name, "cat": s.cat, "ts": s.t_ms * _US,
                 "pid": s.pid, "tid": 0, "s": "t"}
            if s.args:
                e["args"] = s.args
            ev.append(e)
        return ev

    def export_chrome_trace(self, path: str) -> dict:
        # default=str: span args may hold rich values (Config objects)
        # recorded as-is on the hot path and stringified only here
        doc = {"displayTimeUnit": "ms", "traceEvents": self.events()}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        return doc
