"""Planner decision audit log.

One structured :class:`PlanRecord` per ``ESGScheduler.plan`` call —
which plan-cache budget regime served it (floor / budget-free / exact /
miss), how much work the A* search did (expansions, dual-blade prune
counts — zero on a cache hit), the chosen path's predicted latency/cost
against its G_SLO budget, and, back-filled when the dispatched task
completes, the realized stage latency next to the predicted one.  Plus
one :class:`SkipRecord` per event-sparse ``sparse_skips`` decision,
naming the plan-signature certificate that proved the retry futile, and
one :class:`RetryRecord` per retry decision taken after a spot
reclamation killed a running task (retry / resume-from-checkpoint /
shed, with attempt count, backoff and lost execution time).

This is the layer that makes a mispriced plan *visible*: the
``calibration()`` block aggregates per-stage predicted-vs-realized
error quantiles (surfaced through ``Telemetry.summary()``), and the
JSONL export lets a single bad decision be traced from its budget and
regime to the task it produced.

The audit log is also a *stream*: ``subscribe(fn)`` registers a
callback invoked once per plan record the moment its realized latency
back-fills at task completion.  That is the hook the online profile
calibrator (``repro.obs.calibrate``) and the SLO health engine's
calibration-drift detector (``repro.obs.health``) consume — they see
each predicted-vs-realized pair in simulated-time order, online, with
no post-hoc scan.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Any, Callable, Optional

# quantiles computed from a single sample are that sample, not a
# distribution — below this count the per-stage calibration block
# reports them as None so downstream consumers cannot mistake one
# noisy observation for a p90
MIN_QUANTILE_SAMPLES = 2

# per-(app, stage) cap on retained calibration error samples: beyond
# this the reservoir thins itself (keep-every-other, stride doubles), so
# a million-invocation run holds O(cap) floats per stage instead of
# O(invocations) while quantiles stay exact over a systematic 1-in-2^k
# subsample of the stream
CAL_RESERVOIR_CAP = 4096


class _ErrAcc:
    """Streaming predicted-vs-realized error accumulator for one
    (app, stage): exact count/sums, plus a deterministic
    systematic-thinning reservoir for quantiles.  No RNG — the kept
    subsample is every ``stride``-th observation, so replays reproduce
    it bit-for-bit."""
    __slots__ = ("n", "sum_err", "sum_abs", "samples", "stride", "_skip")

    def __init__(self):
        self.n = 0
        self.sum_err = 0.0
        self.sum_abs = 0.0
        self.samples: list[float] = []
        self.stride = 1
        self._skip = 0

    def add(self, err: float) -> None:
        self.n += 1
        self.sum_err += err
        self.sum_abs += abs(err)
        if self._skip:
            self._skip -= 1
            return
        self._skip = self.stride - 1
        self.samples.append(err)
        if len(self.samples) >= CAL_RESERVOIR_CAP:
            del self.samples[1::2]     # keep even ranks, double the step
            self.stride *= 2


@dataclasses.dataclass
class PlanRecord:
    """One ``plan()`` call and the dispatch it led to (if any)."""
    t_ms: float
    app: str
    stage: str
    n_jobs: int
    g_slo_ms: float                  # budget handed to ESG_1Q (0 when sunk)
    regime: str                      # floor|budget-free|exact|miss|nocache|sunk
    expansions: int                  # A* nodes expanded (0 on cache hits)
    pruned_time: int                 # time-blade prunes
    pruned_cost: int                 # cost-blade prunes
    est_time_ms: Optional[float]     # chosen path's predicted suffix latency
    est_job_cost: Optional[float]
    slack_ms: Optional[float]        # g_slo - est_time of the chosen path
    n_candidates: int
    # --- back-filled at dispatch / completion ---
    task_tid: Optional[int] = None
    config: Optional[Any] = None     # the dispatched Config (JSON: nested)
    predicted_ms: Optional[float] = None   # this stage, dispatched config
    realized_ms: Optional[float] = None    # start -> end, noise + resizes
    # raw (uncorrected) profile estimate of the exec component alone and
    # the realized exec span (exec_start -> end) — the multiplicative
    # signal the online calibrator learns from: realized_exec_ms /
    # predicted_raw_ms is the profile's error free of swap penalties and
    # of whatever correction the planner already applied
    predicted_raw_ms: Optional[float] = None
    realized_exec_ms: Optional[float] = None
    # where the stage's profile numbers came from: "zoo" (analytic
    # roofline tables) or "measured" (real-kernel timing artifact) —
    # lets audit consumers weight calibration trust accordingly
    provenance: Optional[str] = None


@dataclasses.dataclass
class SkipRecord:
    """One provably-futile retry skipped by the event-sparse emulator."""
    t_ms: float
    app: str
    stage: str
    certificate: str                 # the plan-signature token that proved it
    recheck: int                     # recheck counter at skip time


@dataclasses.dataclass
class RetryRecord:
    """One retry decision after a spot reclamation killed a running task.

    ``action`` is what the emulator decided for this job: ``retry``
    (re-run from scratch after ``backoff_ms``), ``resume`` (restart from
    the stage's checkpoint) or ``shed`` (retry budget exhausted, the
    request failed).  ``lost_ms`` is the execution time destroyed by the
    kill, attributed to every job of the killed task."""
    t_ms: float
    app: str
    stage: str
    uid: int                         # request the retried job belongs to
    invoker: int                     # reclaimed invoker
    attempt: int                     # 1-based attempt count for this stage
    action: str                      # retry|resume|shed
    backoff_ms: float                # delay before the re-queue (0 for shed)
    lost_ms: float                   # exec time destroyed by the kill


class AuditLog:
    def __init__(self):
        self.plans: list[PlanRecord] = []
        self.skips: list[SkipRecord] = []
        self.retries: list[RetryRecord] = []
        # most recent un-dispatched record per (app, stage): the emulator
        # calls plan() then dispatches at most one task from its result
        self._pending: dict[tuple[str, str], PlanRecord] = {}
        self._by_tid: dict[int, PlanRecord] = {}
        # realized-record stream: called once per record when its
        # realized latency back-fills (see module docstring)
        self._subscribers: list[Callable[[PlanRecord], None]] = []
        # streaming calibration state, fed at back-fill time so
        # calibration() never has to rescan (and retain) every record
        self._cal: dict[str, _ErrAcc] = {}

    def subscribe(self, fn: Callable[[PlanRecord], None]) -> None:
        """Register ``fn`` to receive each plan record the moment its
        realized latency is back-filled at task completion."""
        self._subscribers.append(fn)

    # ---- recording ---------------------------------------------------------
    def on_plan(self, rec: PlanRecord) -> PlanRecord:
        self.plans.append(rec)
        self._pending[(rec.app, rec.stage)] = rec
        return rec

    def on_dispatch(self, app: str, stage: str, tid: int, config: Any,
                    predicted_ms: float,
                    predicted_raw_ms: Optional[float] = None):
        rec = self._pending.pop((app, stage), None)
        if rec is None:
            return
        rec.task_tid = tid
        rec.config = config
        rec.predicted_ms = predicted_ms
        rec.predicted_raw_ms = predicted_raw_ms
        self._by_tid[tid] = rec

    def on_complete(self, tid: int, realized_ms: float,
                    realized_exec_ms: Optional[float] = None):
        rec = self._by_tid.pop(tid, None)
        if rec is None:
            return
        rec.realized_ms = realized_ms
        rec.realized_exec_ms = realized_exec_ms
        if rec.predicted_ms is not None and rec.predicted_ms > 0:
            err = (realized_ms - rec.predicted_ms) / rec.predicted_ms
            acc = self._cal.get(f"{rec.app}/{rec.stage}")
            if acc is None:
                acc = self._cal[f"{rec.app}/{rec.stage}"] = _ErrAcc()
            acc.add(err)
        for fn in self._subscribers:
            fn(rec)

    def on_skip(self, t_ms: float, app: str, stage: str, certificate: Any,
                recheck: int):
        self.skips.append(SkipRecord(t_ms, app, stage, str(certificate),
                                     recheck))

    def on_preempted(self, tid: int):
        """A running task was killed by a reclamation: drop its pending
        back-fill so the partial run never reaches the calibration stream
        (a kill is not a latency observation)."""
        self._by_tid.pop(tid, None)

    def on_retry(self, t_ms: float, app: str, stage: str, uid: int,
                 invoker: int, attempt: int, action: str,
                 backoff_ms: float, lost_ms: float) -> RetryRecord:
        rec = RetryRecord(t_ms, app, stage, uid, invoker, attempt, action,
                          backoff_ms, lost_ms)
        self.retries.append(rec)
        return rec

    # ---- analysis ----------------------------------------------------------
    @staticmethod
    def _quantile(xs: list[float], q: float) -> float:
        """Nearest-rank quantile without numpy (xs non-empty, sorted)."""
        i = min(int(q * (len(xs) - 1) + 0.5), len(xs) - 1)
        return xs[i]

    def calibration(self) -> dict[str, Any]:
        """Predicted-vs-realized per-stage latency error quantiles.

        Relative error is (realized - predicted) / predicted: positive
        means the plan was optimistic (exec noise, resizes, contention),
        negative pessimistic.  Per-(app, stage) plus an overall block.
        Every per-stage block carries its sample count ``n`` next to the
        quantiles, and below ``MIN_QUANTILE_SAMPLES`` the quantiles are
        reported as None — a "p90" of one sample is that sample, and
        consumers (the calibrator's warmup gate, dashboards) must be
        able to tell the difference.

        Counts and means come from exact streaming accumulators fed at
        back-fill time; quantiles come from each stage's bounded
        thinning reservoir (``CAL_RESERVOIR_CAP``), so this holds O(1)
        floats per stage regardless of trace length.
        """
        accs = self._cal
        if not accs and self.plans:
            # fallback for records whose realized_ms was set directly
            # instead of through on_complete (external tooling): one
            # bounded scan into throwaway accumulators
            accs = {}
            for rec in self.plans:
                if rec.predicted_ms is None or rec.realized_ms is None \
                        or rec.predicted_ms <= 0:
                    continue
                key = f"{rec.app}/{rec.stage}"
                acc = accs.get(key)
                if acc is None:
                    acc = accs[key] = _ErrAcc()
                acc.add((rec.realized_ms - rec.predicted_ms)
                        / rec.predicted_ms)
        out: dict[str, Any] = {}
        all_errs: list[float] = []
        n_total = 0
        sum_err = sum_abs = 0.0
        for key in sorted(accs):
            acc = accs[key]
            errs = sorted(acc.samples)
            all_errs.extend(errs)
            n_total += acc.n
            sum_err += acc.sum_err
            sum_abs += acc.sum_abs
            quantiled = acc.n >= MIN_QUANTILE_SAMPLES
            out[key] = {
                "n": acc.n,
                "mean_err": acc.sum_err / acc.n,
                "mean_abs_err": acc.sum_abs / acc.n,
                "p50_err": self._quantile(errs, 0.50) if quantiled else None,
                "p90_abs_err": self._quantile(sorted(abs(e) for e in errs),
                                              0.90) if quantiled else None,
            }
        all_errs.sort()
        return {
            "n": n_total,
            "mean_err": (sum_err / n_total) if n_total else 0.0,
            "mean_abs_err": (sum_abs / n_total) if n_total else 0.0,
            "p50_err": self._quantile(all_errs, 0.50) if all_errs else 0.0,
            "p90_abs_err": self._quantile(
                sorted(abs(e) for e in all_errs), 0.90) if all_errs else 0.0,
            "per_stage": out,
        }

    def regimes(self) -> dict[str, int]:
        counts: dict[str, int] = defaultdict(int)
        for rec in self.plans:
            counts[rec.regime] += 1
        return dict(counts)

    # ---- export ------------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """One JSON object per line: plan, then skip, then retry records."""
        n = 0
        with open(path, "w") as f:
            for rec in self.plans:
                f.write(json.dumps({"type": "plan",
                                    **dataclasses.asdict(rec)},
                                   sort_keys=True, default=str) + "\n")
                n += 1
            for skip in self.skips:
                f.write(json.dumps({"type": "skip",
                                    **dataclasses.asdict(skip)},
                                   sort_keys=True, default=str) + "\n")
                n += 1
            for retry in self.retries:
                f.write(json.dumps({"type": "retry",
                                    **dataclasses.asdict(retry)},
                                   sort_keys=True, default=str) + "\n")
                n += 1
        return n
