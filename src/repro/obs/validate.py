"""Chrome-trace / metrics-export schema validation.

Used three ways: by the test suite's golden-fixture checks, by CI (the
obs smoke step runs ``python -m repro.obs.validate trace.json
metrics.json``) and manually on any exported artifact.  The trace check
enforces the Chrome-trace contract Perfetto actually relies on — every
event carries ``ph``/``ts``/``pid``/``tid``, every complete slice ("X")
carries ``dur`` — plus the flight-recorder-specific requirement that at
least one complete span exists for each request lifecycle phase
(request envelope, queue wait, exec; ``xfer`` appears only when some
start paid a restart penalty, so it is opt-in via ``required``).
"""
from __future__ import annotations

import json
import sys
from typing import Any, Iterable

REQUIRED_PHASES = ("request", "queue", "exec")


def validate_trace(doc: dict[str, Any],
                   required: Iterable[str] = REQUIRED_PHASES) -> dict[str, int]:
    """Validate a Chrome-trace document; returns per-category X-span
    counts.  Raises ``ValueError`` with a precise message on the first
    violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome-trace document: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    counts: dict[str, int] = {}
    for i, e in enumerate(events):
        for field in ("ph", "pid", "tid"):
            if field not in e:
                raise ValueError(f"event {i} missing {field!r}: {e}")
        ph = e["ph"]
        if ph not in ("X", "i", "M"):
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if ph != "M" and "ts" not in e:
            raise ValueError(f"event {i} ({ph}) missing ts")
        if ph == "X":
            if "dur" not in e:
                raise ValueError(f"event {i} (complete span) missing dur")
            if e["dur"] < 0:
                raise ValueError(f"event {i} has negative dur {e['dur']}")
            counts[e.get("cat", "?")] = counts.get(e.get("cat", "?"), 0) + 1
    missing = [c for c in required if counts.get(c, 0) < 1]
    if missing:
        raise ValueError(
            f"no complete span for lifecycle phase(s) {missing}; "
            f"have {counts}")
    return counts


def validate_nesting(doc: dict[str, Any]) -> None:
    """Check stage spans sit inside their request envelope: on every
    request pid, each queue/xfer/exec slice's interval must be contained
    in the union of that pid's request-cat slices."""
    from repro.obs.tracer import REQUEST_PID_BASE
    envelope: dict[int, list[tuple[float, float]]] = {}
    inner: dict[int, list[tuple[float, float, str]]] = {}
    for e in doc["traceEvents"]:
        if e["ph"] != "X" or e["pid"] < REQUEST_PID_BASE:
            continue
        t0, t1 = e["ts"], e["ts"] + e["dur"]
        if e.get("cat") == "request":
            envelope.setdefault(e["pid"], []).append((t0, t1))
        else:
            inner.setdefault(e["pid"], []).append((t0, t1, e["name"]))
    eps = 1e-6
    for pid, spans in inner.items():
        envs = envelope.get(pid, [])
        for t0, t1, name in spans:
            if not any(a - eps <= t0 and t1 <= b + eps for a, b in envs):
                raise ValueError(
                    f"span {name!r} [{t0}, {t1}] on pid {pid} escapes its "
                    f"request envelope {envs}")


def validate_metrics(doc: dict[str, Any]) -> int:
    """Validate a MetricsBus JSON export; returns the series count."""
    if "window_ms" not in doc or "series" not in doc:
        raise ValueError("not a metrics export: missing window_ms/series")
    for name, s in doc["series"].items():
        if s.get("kind") not in ("counter", "gauge", "hist"):
            raise ValueError(f"series {name!r} has bad kind {s.get('kind')!r}")
        if not isinstance(s.get("points"), list):
            raise ValueError(f"series {name!r} missing points list")
    return len(doc["series"])


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate TRACE.json "
              "[METRICS.json] [AUDIT.jsonl]", file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        trace = json.load(f)
    counts = validate_trace(trace)
    validate_nesting(trace)
    print(f"[obs-validate] trace OK: "
          + ", ".join(f"{c}={n}" for c, n in sorted(counts.items())))
    if len(argv) > 1:
        with open(argv[1]) as f:
            n = validate_metrics(json.load(f))
        print(f"[obs-validate] metrics OK: {n} series")
    if len(argv) > 2:
        with open(argv[2]) as f:
            records = [json.loads(line) for line in f if line.strip()]
        if any("type" not in r for r in records):
            raise ValueError("audit record missing type field")
        print(f"[obs-validate] audit OK: {len(records)} records")
    return 0


if __name__ == "__main__":
    sys.exit(main())
