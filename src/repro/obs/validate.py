"""Schema validation for every flight-recorder export format.

Used three ways: by the test suite's golden-fixture checks, by CI (the
obs smoke step runs ``python -m repro.obs.validate`` over the exported
artifacts) and manually on any export.  Four formats are covered, and
``main`` dispatches on file extension + content instead of positional
roles, so any mix of artifacts can be passed in any order:

  * **Chrome trace** (``.json`` with ``traceEvents``) — the contract
    Perfetto actually relies on: every event carries
    ``ph``/``ts``/``pid``/``tid``, every complete slice ("X") carries
    ``dur``, and at least one complete span exists for each request
    lifecycle phase (request envelope, queue wait, exec; ``xfer``
    appears only when some start paid a restart penalty, so it is
    opt-in via ``required``);
  * **metrics bus** (``.json`` with ``series``, or the long-format
    ``.csv``) — known series kinds, well-formed points (scalar kinds
    carry one value, hist windows carry count/sum/min/max with
    min <= max <= sum consistency), strictly increasing window starts;
  * **planner audit** (``.jsonl`` of plan/skip/retry records) —
    required fields per record type, numeric sanity, realized >= 0,
    retry actions in retry|resume|shed with attempt >= 1;
  * **health alerts** (``.jsonl`` of alert records) — known alert
    kinds, firing/cleared states alternating per (kind, app) stream.

Every error names the offending file and record (``file: record i:``
or ``file: line i:``) so a CI failure points at the exact artifact.
"""
from __future__ import annotations

import csv
import json
import sys
from typing import Any, Iterable

REQUIRED_PHASES = ("request", "queue", "exec")

_METRIC_KINDS = ("counter", "gauge", "hist")
_AUDIT_PLAN_FIELDS = ("t_ms", "app", "stage", "n_jobs", "g_slo_ms",
                      "regime", "expansions")
_AUDIT_SKIP_FIELDS = ("t_ms", "app", "stage", "certificate", "recheck")
_AUDIT_RETRY_FIELDS = ("t_ms", "app", "stage", "uid", "invoker", "attempt",
                       "action", "backoff_ms", "lost_ms")
_RETRY_ACTIONS = ("retry", "resume", "shed")
_ALERT_FIELDS = ("t_ms", "kind", "app", "state", "value", "threshold")


def validate_trace(doc: dict[str, Any],
                   required: Iterable[str] = REQUIRED_PHASES) -> dict[str, int]:
    """Validate a Chrome-trace document; returns per-category X-span
    counts.  Raises ``ValueError`` with a precise message on the first
    violation."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome-trace document: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    counts: dict[str, int] = {}
    for i, e in enumerate(events):
        for field in ("ph", "pid", "tid"):
            if field not in e:
                raise ValueError(f"event {i} missing {field!r}: {e}")
        ph = e["ph"]
        if ph not in ("X", "i", "M"):
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if ph != "M" and "ts" not in e:
            raise ValueError(f"event {i} ({ph}) missing ts")
        if ph == "X":
            if "dur" not in e:
                raise ValueError(f"event {i} (complete span) missing dur")
            if e["dur"] < 0:
                raise ValueError(f"event {i} has negative dur {e['dur']}")
            counts[e.get("cat", "?")] = counts.get(e.get("cat", "?"), 0) + 1
    missing = [c for c in required if counts.get(c, 0) < 1]
    if missing:
        raise ValueError(
            f"no complete span for lifecycle phase(s) {missing}; "
            f"have {counts}")
    return counts


def validate_nesting(doc: dict[str, Any]) -> None:
    """Check stage spans sit inside their request envelope: on every
    request pid, each queue/xfer/exec slice's interval must be contained
    in the union of that pid's request-cat slices."""
    from repro.obs.tracer import REQUEST_PID_BASE
    envelope: dict[int, list[tuple[float, float]]] = {}
    inner: dict[int, list[tuple[float, float, str]]] = {}
    for e in doc["traceEvents"]:
        if e["ph"] != "X" or e["pid"] < REQUEST_PID_BASE:
            continue
        t0, t1 = e["ts"], e["ts"] + e["dur"]
        if e.get("cat") == "request":
            envelope.setdefault(e["pid"], []).append((t0, t1))
        else:
            inner.setdefault(e["pid"], []).append((t0, t1, e["name"]))
    eps = 1e-6
    for pid, spans in inner.items():
        envs = envelope.get(pid, [])
        for t0, t1, name in spans:
            if not any(a - eps <= t0 and t1 <= b + eps for a, b in envs):
                raise ValueError(
                    f"span {name!r} [{t0}, {t1}] on pid {pid} escapes its "
                    f"request envelope {envs}")


def _num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_metrics(doc: dict[str, Any], path: str = "metrics") -> int:
    """Validate a MetricsBus JSON export; returns the series count.
    Errors name the file, series and point index."""
    if "window_ms" not in doc or "series" not in doc:
        raise ValueError(f"{path}: not a metrics export: "
                         f"missing window_ms/series")
    if not _num(doc["window_ms"]) or doc["window_ms"] <= 0:
        raise ValueError(f"{path}: window_ms must be a positive number, "
                         f"got {doc['window_ms']!r}")
    for name, s in doc["series"].items():
        kind = s.get("kind")
        if kind not in _METRIC_KINDS:
            raise ValueError(f"{path}: series {name!r} has bad kind "
                             f"{kind!r}")
        pts = s.get("points")
        if not isinstance(pts, list):
            raise ValueError(f"{path}: series {name!r} missing points list")
        width = 5 if kind == "hist" else 2
        prev_t = None
        for i, p in enumerate(pts):
            if not isinstance(p, list) or len(p) != width \
                    or not all(_num(x) for x in p):
                raise ValueError(
                    f"{path}: series {name!r} point {i}: expected "
                    f"{width} numbers, got {p!r}")
            if prev_t is not None and p[0] <= prev_t:
                raise ValueError(
                    f"{path}: series {name!r} point {i}: window start "
                    f"{p[0]} not after previous {prev_t}")
            prev_t = p[0]
            if kind == "hist":
                _, n, total, lo, hi = p
                if n < 1 or lo > hi:
                    raise ValueError(
                        f"{path}: series {name!r} point {i}: inconsistent "
                        f"hist window {p!r}")
    return len(doc["series"])


def validate_metrics_csv(path: str) -> int:
    """Validate a MetricsBus long-format CSV export; returns the row
    count.  Errors name the file and 1-based line number."""
    header = ["series", "kind", "window_start_ms", "value",
              "count", "sum", "min", "max"]
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    if not rows or rows[0] != header:
        raise ValueError(f"{path}: line 1: bad header {rows[0] if rows else []!r}, "
                         f"expected {header!r}")
    for i, row in enumerate(rows[1:], start=2):
        if len(row) != len(header):
            raise ValueError(f"{path}: line {i}: expected "
                             f"{len(header)} columns, got {len(row)}")
        name, kind, t, value, n, total, lo, hi = row
        if kind not in _METRIC_KINDS:
            raise ValueError(f"{path}: line {i}: series {name!r} has bad "
                             f"kind {kind!r}")
        try:
            float(t)
        except ValueError:
            raise ValueError(f"{path}: line {i}: bad window_start_ms "
                             f"{t!r}") from None
        filled, blank = ((n, total, lo, hi), (value,)) if kind == "hist" \
            else ((value,), (n, total, lo, hi))
        if any(c == "" for c in filled) or any(c != "" for c in blank):
            raise ValueError(
                f"{path}: line {i}: {kind} row must fill "
                f"{'count/sum/min/max' if kind == 'hist' else 'value'} "
                f"and leave the rest empty, got {row!r}")
        try:
            [float(c) for c in filled]
        except ValueError:
            raise ValueError(f"{path}: line {i}: non-numeric cell in "
                             f"{filled!r}") from None
    return len(rows) - 1


def _load_jsonl(path: str) -> list[dict[str, Any]]:
    records = []
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}: line {i}: not JSON: {e}") from None
    return records


def validate_audit(records: list[dict[str, Any]],
                   path: str = "audit") -> dict[str, int]:
    """Validate planner-audit JSONL records; returns per-type counts.
    Errors name the file and 0-based record index."""
    counts = {"plan": 0, "skip": 0, "retry": 0}
    fields_by_type = {"plan": _AUDIT_PLAN_FIELDS,
                      "skip": _AUDIT_SKIP_FIELDS,
                      "retry": _AUDIT_RETRY_FIELDS}
    for i, r in enumerate(records):
        t = r.get("type")
        if t not in counts:
            raise ValueError(f"{path}: record {i}: bad type {t!r} "
                             f"(want plan|skip|retry)")
        counts[t] += 1
        missing = [k for k in fields_by_type[t] if k not in r]
        if missing:
            raise ValueError(f"{path}: record {i}: {t} record missing "
                             f"{missing}")
        if not _num(r["t_ms"]) or r["t_ms"] < 0:
            raise ValueError(f"{path}: record {i}: bad t_ms {r['t_ms']!r}")
        if t == "plan":
            for k in ("realized_ms", "realized_exec_ms", "predicted_ms",
                      "predicted_raw_ms"):
                v = r.get(k)
                if v is not None and (not _num(v) or v < 0):
                    raise ValueError(f"{path}: record {i}: bad {k} {v!r}")
        elif t == "retry":
            if r["action"] not in _RETRY_ACTIONS:
                raise ValueError(
                    f"{path}: record {i}: bad retry action "
                    f"{r['action']!r} (want one of {_RETRY_ACTIONS})")
            if not isinstance(r["attempt"], int) or \
                    isinstance(r["attempt"], bool) or r["attempt"] < 1:
                raise ValueError(f"{path}: record {i}: bad attempt "
                                 f"{r['attempt']!r} (want int >= 1)")
            for k in ("backoff_ms", "lost_ms"):
                if not _num(r[k]) or r[k] < 0:
                    raise ValueError(f"{path}: record {i}: bad {k} "
                                     f"{r[k]!r}")
    return counts


def validate_health(records: list[dict[str, Any]],
                    path: str = "health") -> dict[str, int]:
    """Validate health-alert JSONL records; returns per-kind counts.
    Checks each (kind, app) stream alternates firing/cleared starting
    with firing.  Errors name the file and 0-based record index."""
    from repro.obs.health import ALERT_KINDS, CLEARED, FIRING
    counts: dict[str, int] = {}
    state: dict[tuple[str, Any], str] = {}
    for i, r in enumerate(records):
        if r.get("type") != "alert":
            raise ValueError(f"{path}: record {i}: bad type "
                             f"{r.get('type')!r} (want alert)")
        missing = [k for k in _ALERT_FIELDS if k not in r]
        if missing:
            raise ValueError(f"{path}: record {i}: missing {missing}")
        if r["kind"] not in ALERT_KINDS:
            raise ValueError(f"{path}: record {i}: unknown alert kind "
                             f"{r['kind']!r}")
        if r["state"] not in (FIRING, CLEARED):
            raise ValueError(f"{path}: record {i}: bad state "
                             f"{r['state']!r}")
        for k in ("t_ms", "value", "threshold"):
            if not _num(r[k]):
                raise ValueError(f"{path}: record {i}: bad {k} {r[k]!r}")
        key = (r["kind"], r["app"])
        prev = state.get(key, CLEARED)
        if r["state"] == prev:
            raise ValueError(
                f"{path}: record {i}: {r['kind']}[{r['app']}] is "
                f"{r['state']!r} twice in a row (streams must alternate)")
        state[key] = r["state"]
        counts[r["kind"]] = counts.get(r["kind"], 0) + 1
    return counts


def _dispatch(path: str) -> str:
    """Validate one artifact, sniffing its format; returns a summary."""
    if path.endswith(".csv"):
        n = validate_metrics_csv(path)
        return f"metrics-csv OK: {n} rows"
    if path.endswith(".jsonl"):
        records = _load_jsonl(path)
        types = {r.get("type") for r in records}
        if types <= {"alert"}:
            counts = validate_health(records, path)
            return "health OK: " + (", ".join(
                f"{k}={n}" for k, n in sorted(counts.items()))
                or "0 alerts")
        counts = validate_audit(records, path)
        return (f"audit OK: {counts['plan']} plans, {counts['skip']} "
                f"skips, {counts['retry']} retries")
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and "traceEvents" in doc:
        counts = validate_trace(doc)
        validate_nesting(doc)
        return "trace OK: " + ", ".join(
            f"{c}={n}" for c, n in sorted(counts.items()))
    if isinstance(doc, dict) and "series" in doc:
        n = validate_metrics(doc, path)
        return f"metrics OK: {n} series"
    raise ValueError(f"{path}: unrecognized artifact (want a Chrome "
                     f"trace, a metrics export, or a .jsonl/.csv)")


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate ARTIFACT... "
              "(trace/metrics .json, metrics .csv, audit/health .jsonl)",
              file=sys.stderr)
        return 2
    for path in argv:
        print(f"[obs-validate] {path}: {_dispatch(path)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
