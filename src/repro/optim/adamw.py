"""AdamW with cosine schedule, global-norm clipping and optional int8
gradient compression (error feedback) for the data-parallel all-reduce.

Pure-functional: ``init`` builds the (fp32) moment state; ``update`` returns
new (params, state).  The compression path quantises gradients to int8 with
a per-tensor scale *before* they cross the DP axis and keeps the residual
locally (error feedback), the standard bandwidth/quality trade
[1-bit Adam, arXiv:2102.02888-style].
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False      # int8 + error feedback across DP


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    return state


def init_error_feedback(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(g: Array) -> tuple[Array, Array]:
    """Per-tensor symmetric int8 quantisation."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_residual(g: Array, err: Array) -> tuple[Array, Array]:
    """Quantise (g + err); return (dequantised g_hat, new residual)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = compress_int8(g32)
    g_hat = decompress_int8(q, scale)
    return g_hat, g32 - g_hat


def update(cfg: AdamWConfig, params, grads, state,
           error_feedback: Optional[dict] = None):
    """Returns (params', state', error_feedback', metrics)."""
    if cfg.compress_grads:
        assert error_feedback is not None
        pairs = jax.tree.map(compress_residual, grads, error_feedback)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        error_feedback = jax.tree.map(lambda p: p[1], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))

    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(g32)))
    scale_clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale_clip, g32)

    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], g32)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], g32)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, error_feedback, metrics
