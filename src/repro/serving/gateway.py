"""Admission-control gateway: open-loop injection + load shedding.

The gateway fronts the cluster emulator the way an OpenWhisk controller's
edge fronts invokers: a scenario's request trace is injected open-loop
(arrivals do not wait for completions), and each request passes an
admission check *at its arrival time in simulated time*.  Requests that
are already doomed — their remaining SLO budget cannot cover even the
fastest possible execution plus the predicted queueing — are shed at the
door instead of wasting GPU time on a guaranteed miss (the
Torpor/FaaSwap observation that queueing doomed work poisons the pool).

The queueing predictor is a **per-stage queueing-delay EWMA**: realized
queue waits (task start minus job ready, observed as tasks dispatch) are
folded into one EWMA per (app, stage), and an arrival's predicted delay
is the critical-path sum of its stages' EWMAs.  This replaces the old
fleet-averaged backlog estimate, which smeared one hot stage's queue
over every invoker.  Every shed decision is logged with its budget and
prediction so telemetry can score *shed precision* after the run (true
sheds — requests that would indeed have missed — vs false sheds).

Admitted requests flow into the emulator's per-(app, stage) AFW queues
unchanged; the scheduler under test never sees shed traffic.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.cluster.workload import critical_path, min_config_latency
from repro.serving.telemetry import Telemetry
from repro.serving.traces import Scenario


class Gateway:
    """Admission-control front end over a ``ClusterSim``.

    ``shed_doomed=False`` turns the gateway into a pure injector (every
    arrival admitted) — the ablation baseline.  ``backlog_aware=False``
    drops the queueing-delay term from the admission check (the doomed
    test then uses the empty-cluster fastest path only).
    """

    def __init__(self, sim, telemetry: Optional[Telemetry] = None,
                 shed_doomed: bool = True, backlog_aware: bool = True,
                 qdelay_alpha: float = 0.3, health=None,
                 health_headroom: float = 1.5):
        self.sim = sim
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.shed_doomed = shed_doomed
        self.backlog_aware = backlog_aware
        self.qdelay_alpha = qdelay_alpha
        # SLO health engine (repro.obs.health): while an alert relevant
        # to the arriving app is firing, the predicted-queueing term is
        # inflated by ``health_headroom`` — the EWMA lags exactly when
        # the burn-rate/queue-buildup detectors say conditions are
        # deteriorating, so admission turns pessimistic early instead
        # of queueing doomed work through the whole burn.  None (the
        # default) changes nothing.
        self.health = health
        self.health_headroom = health_headroom
        # per-(app, stage) EWMA of realized queueing delay
        self._qdelay: dict[tuple[str, str], float] = {}
        self._tasks_seen = 0
        # fastest possible end-to-end time per app: critical path with every
        # stage at its profile-lattice minimum latency
        self._fastest_ms = {
            name: critical_path(
                app, lambda s, a=app: float(sim.tables[a.func_of[s]].min_time))
            for name, app in sim.apps.items()
        }
        self.telemetry.fastest_ms = dict(self._fastest_ms)
        sim.admission = self._admit
        if getattr(sim, "retain", "full") == "stream":
            # a streaming sim keeps no task list to scan, so dispatches
            # reach the EWMAs through a feed the sim appends to at
            # dispatch time — only created when this gateway will
            # actually drain it (otherwise it would grow unboundedly)
            self.telemetry.attach_stream(sim)
            if self.backlog_aware and self.shed_doomed:
                from collections import deque
                sim.dispatch_feed = deque()

    # ---- queueing-delay model ----------------------------------------------
    def _ingest_dispatches(self) -> None:
        """Fold queue waits of tasks dispatched since the last admission
        decision into the per-stage EWMAs (``sim.tasks`` is appended in
        nondecreasing simulated time, so this is an online pass)."""
        feed = self.sim.dispatch_feed
        if feed is not None:
            # stream mode: the sim pushed (app, stage, wait) per job at
            # dispatch, in exactly the order the task-list scan below
            # would visit them — the EWMA folds are bit-identical
            a = self.qdelay_alpha
            qd = self._qdelay
            while feed:
                app, stage, wait = feed.popleft()
                key = (app, stage)
                prev = qd.get(key)
                qd[key] = wait if prev is None \
                    else (1.0 - a) * prev + a * wait
            return
        tasks = self.sim.tasks
        a = self.qdelay_alpha
        while self._tasks_seen < len(tasks):
            t = tasks[self._tasks_seen]
            self._tasks_seen += 1
            key = (t.jobs[0].inst.app.name, t.stage)
            for j in t.jobs:
                wait = max(t.start_ms - j.ready_ms, 0.0)
                prev = self._qdelay.get(key)
                self._qdelay[key] = wait if prev is None \
                    else (1.0 - a) * prev + a * wait

    def predicted_queueing_ms(self, app) -> float:
        """Critical-path sum of the per-stage queueing-delay EWMAs."""
        if not self.backlog_aware:
            return 0.0
        self._ingest_dispatches()
        return critical_path(
            app, lambda s: self._qdelay.get((app.name, s), 0.0))

    # ---- admission ---------------------------------------------------------
    def _admit(self, sim, inst) -> bool:
        self.telemetry.on_injected(inst.app.name)
        rec = getattr(sim, "recorder", None)
        recording = rec is not None and rec.enabled
        if recording:
            rec.on_injected(inst.app.name, sim.now)
        if self.health is not None and getattr(sim, "_has_spot", False):
            # While any burn-rate/queue-buildup alert is firing, steer new
            # placements off spot capacity: reclamation rework is the last
            # thing a burning SLO needs.  Clears itself when alerts clear.
            sim.prefer_on_demand = bool(self.health.early_warning())
        if self.shed_doomed:
            budget = inst.deadline_ms - sim.now
            fastest = self._fastest_ms[inst.app.name]
            queueing = self.predicted_queueing_ms(inst.app)
            if self.health is not None \
                    and self.health.early_warning(inst.app.name):
                queueing *= self.health_headroom
            need = fastest + queueing
            if need > budget:
                self.telemetry.on_shed(inst.app.name, t_ms=sim.now,
                                       budget_ms=budget, need_ms=need,
                                       fastest_ms=fastest)
                if recording:
                    rec.on_shed(inst, sim.now, budget, need)
                return False
        self.telemetry.on_admitted(inst.app.name)
        return True

    # ---- injection ---------------------------------------------------------
    def inject(self, scenario: Scenario, n: int, seed: int = 0,
               slo_mult: float = 1.0,
               app_names: Optional[Sequence[str]] = None,
               stream: bool = False) -> dict[str, float]:
        """Open-loop injection of ``n`` scenario arrivals.

        SLOs follow the paper's rule: ``slo_mult`` x the app's
        minimum-configuration end-to-end latency L.  Returns the SLO map.

        ``stream=True`` feeds arrivals lazily through
        ``sim.add_arrival_stream`` (one pending heap entry at a time,
        bit-identical replay) instead of materializing ``n``
        ``AppInstance`` objects up front — the day-scale path.
        """
        sim = self.sim
        app_names = list(app_names or sim.apps)
        slos = {a: slo_mult * min_config_latency(sim.apps[a], sim.profiles)
                for a in app_names}
        if stream:
            sim.add_arrival_stream(
                ((arr.app, arr.t_ms, slos[arr.app], arr.uid)
                 for arr in scenario.arrivals(app_names, n, seed)), n)
        else:
            for arr in scenario.arrivals(app_names, n, seed):
                sim.add_arrival(arr.app, arr.t_ms, slos[arr.app], arr.uid)
        return slos

    # ---- results -----------------------------------------------------------
    def run(self) -> Telemetry:
        """Drive the emulator to quiescence and collect telemetry."""
        self.sim.run()
        self.telemetry.collect(self.sim)
        return self.telemetry
