"""Admission-control gateway: open-loop injection + load shedding.

The gateway fronts the cluster emulator the way an OpenWhisk controller's
edge fronts invokers: a scenario's request trace is injected open-loop
(arrivals do not wait for completions), and each request passes an
admission check *at its arrival time in simulated time*.  Requests that
are already doomed — their remaining SLO budget cannot cover even the
fastest possible execution plus the current backlog — are shed at the
door instead of wasting GPU time on a guaranteed miss (the
Torpor/FaaSwap observation that queueing doomed work poisons the pool).

Admitted requests flow into the emulator's per-(app, stage) AFW queues
unchanged; the scheduler under test never sees shed traffic.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.cluster.workload import critical_path, min_config_latency
from repro.serving.telemetry import Telemetry
from repro.serving.traces import Scenario


class Gateway:
    """Admission-control front end over a ``ClusterSim``.

    ``shed_doomed=False`` turns the gateway into a pure injector (every
    arrival admitted) — the ablation baseline.
    """

    def __init__(self, sim, telemetry: Optional[Telemetry] = None,
                 shed_doomed: bool = True, backlog_aware: bool = True):
        self.sim = sim
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.shed_doomed = shed_doomed
        self.backlog_aware = backlog_aware
        # fastest possible end-to-end time per app: critical path with every
        # stage at its profile-lattice minimum latency
        self._fastest_ms = {
            name: critical_path(
                app, lambda s, a=app: float(sim.tables[a.func_of[s]].min_time))
            for name, app in sim.apps.items()
        }
        sim.admission = self._admit

    # ---- admission ---------------------------------------------------------
    def _backlog_ms(self, app) -> float:
        """Crude backlog estimate: queued jobs of this app, costed at each
        stage's fastest time, spread over the invoker fleet."""
        if not self.backlog_aware:
            return 0.0
        total = 0.0
        for stage in app.stages:
            q = self.sim.queues.get((app.name, stage))
            if q:
                total += len(q) * float(
                    self.sim.tables[app.func_of[stage]].min_time)
        return total / max(len(self.sim.invokers), 1)

    def _admit(self, sim, inst) -> bool:
        self.telemetry.on_injected(inst.app.name)
        if self.shed_doomed:
            budget = inst.deadline_ms - sim.now
            need = self._fastest_ms[inst.app.name] + self._backlog_ms(inst.app)
            if need > budget:
                self.telemetry.on_shed(inst.app.name)
                return False
        self.telemetry.on_admitted(inst.app.name)
        return True

    # ---- injection ---------------------------------------------------------
    def inject(self, scenario: Scenario, n: int, seed: int = 0,
               slo_mult: float = 1.0,
               app_names: Optional[Sequence[str]] = None) -> dict[str, float]:
        """Open-loop injection of ``n`` scenario arrivals.

        SLOs follow the paper's rule: ``slo_mult`` x the app's
        minimum-configuration end-to-end latency L.  Returns the SLO map.
        """
        sim = self.sim
        app_names = list(app_names or sim.apps)
        slos = {a: slo_mult * min_config_latency(sim.apps[a], sim.profiles)
                for a in app_names}
        for arr in scenario.arrivals(app_names, n, seed):
            sim.add_arrival(arr.app, arr.t_ms, slos[arr.app], arr.uid)
        return slos

    # ---- results -----------------------------------------------------------
    def run(self) -> Telemetry:
        """Drive the emulator to quiescence and collect telemetry."""
        self.sim.run()
        self.telemetry.collect(self.sim)
        return self.telemetry
