"""Online serving runtime around the cluster emulator.

Modules:
  * ``traces``     — trace-driven scenario engine (diurnal, MMPP bursts,
                     flash crowds, heavy-tailed Azure-like arrivals, mixes);
  * ``autoscaler`` — pluggable warm-pool / vGPU autoscaler policies
                     (EWMA pre-warm, HAS-GPU-style fine-grained, none);
  * ``gateway``    — admission-control front end (open-loop injection,
                     per-app AFW queues, load shedding of doomed requests);
  * ``telemetry``  — per-stage latency histograms, SLO attainment, cost,
                     utilization, cold-start and shed counters.
"""
from repro.serving.autoscaler import (AUTOSCALERS, AutoscalerPolicy,
                                      EwmaPrewarm, FineGrained, NoPrewarm,
                                      VerticalFineGrained, get_autoscaler)
from repro.serving.gateway import Gateway
from repro.serving.telemetry import LatencyHistogram, Telemetry, format_table
from repro.serving.traces import (SCENARIOS, Arrival, Scenario,
                                  TraceReplayScenario, get_scenario)

__all__ = [
    "AUTOSCALERS", "AutoscalerPolicy", "EwmaPrewarm", "FineGrained",
    "NoPrewarm", "VerticalFineGrained", "get_autoscaler", "Gateway",
    "LatencyHistogram", "Telemetry", "format_table", "SCENARIOS", "Arrival",
    "Scenario", "TraceReplayScenario", "get_scenario",
]
