"""Pluggable warm-pool / vGPU autoscaler policies.

The cluster emulator used to hard-code its pre-warming behaviour (EWMA
inter-arrival prediction + reactive warm-on-cold + static initial pools).
That logic now lives here behind ``AutoscalerPolicy`` so serving runs can
swap policies without touching the event loop:

  * ``EwmaPrewarm``  — the paper-§4 default, bit-compatible with the old
    emulator behaviour (initial pools, reactive scale-up on a cold start,
    EWMA-timed pre-warm events).
  * ``FineGrained``  — HAS-GPU-style fine-grained scaling: per-function
    arrival-rate and service-time estimates drive a Little's-law target
    pool size; surplus containers are retired early (scale-down), deficits
    are pre-warmed immediately.
  * ``VerticalFineGrained`` — ``FineGrained`` plus HAS-GPU's *vertical*
    lever: fractional vGPU quotas of *running* pools are resized in
    place — grown into idle slices when no work is queued, shrunk (down
    to a floor) to admit queued work that would otherwise block.
  * ``NoPrewarm``    — cold-start-always baseline (no pools, no events).

Policies interact with the emulator through five hooks:
  ``seed_pools(sim)``                       once, after invokers exist;
  ``on_dispatch(sim, func, inv, cold, ms)`` after every task dispatch;
  ``on_complete(sim, task)``                after a task finishes (its
                                            successors already queued);
  ``on_congestion(sim, app, stage, cfgs)``  when no candidate config
                                            placed — return True after
                                            freeing capacity to retry;
  ``on_tick(sim, payload)``                 on ``autoscale`` timer events
                                            the policy scheduled itself.
Pre-warms are requested by pushing the emulator's generic ``prewarm``
event; scale-down manipulates invoker device pools directly; vertical
resizes go through ``sim.resize_task``.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.profiles import Config
from repro.gpu import SLICES_PER_VGPU

AUTOSCALERS: dict[str, type] = {}


def _register(cls):
    AUTOSCALERS[cls.name] = cls
    return cls


class AutoscalerPolicy:
    """Warm-pool policy interface driven by the cluster emulator."""
    name = "base"
    # optional SLO health engine (repro.obs.health): policies may read
    # ``self.health.early_warning()`` as a congestion early-warning —
    # e.g. VerticalFineGrained withholds opportunistic quota grows
    # while an alert is firing.  None (the default) changes nothing.
    health = None

    def seed_pools(self, sim) -> None:
        """Populate initial warm pools (sim.invokers exist, sim.now == 0)."""

    def on_dispatch(self, sim, func: str, inv_idx: int, cold: bool,
                    service_ms: float) -> None:
        """Observe one task dispatch (cold tells whether a warm container
        was found); schedule pre-warms / scale down as the policy sees fit."""

    def on_tick(self, sim, payload) -> None:
        """Handle an ``autoscale`` event the policy scheduled earlier."""

    def on_complete(self, sim, task) -> None:
        """Observe a task completion (capacity was just released)."""

    def on_congestion(self, sim, app, stage, candidates) -> bool:
        """No candidate config could be placed.  Return True after
        freeing capacity (e.g. shrinking running quotas) so the emulator
        retries placement once; False to let the queue block."""
        return False

    def on_reclaim_warning(self, sim, inv_idx: int) -> None:
        """Drain-and-migrate: a spot invoker announced its reclamation.
        The default policy re-homes every live keep-alive container of
        the doomed invoker onto surviving invokers (spread order, which
        prefers on-demand SKUs while a burn-rate alert is firing) so the
        warm capacity — not the running tasks, those are killed at the
        reclaim — survives the outage.  Policies may override for
        smarter draining; the hook only fires on fleets with spot SKUs,
        so default runs never enter it."""
        from repro.cluster.emulator import KEEPALIVE_MS
        doomed = sim.invokers[inv_idx]
        moved = 0
        for func in sorted(doomed.device.pools):
            entries = doomed.device.warm_entries(func, sim.now)
            if not entries:
                continue
            targets = [i for i in self.spread_order(sim, func)
                       if i.idx != inv_idx and not i.down
                       and not i.draining]
            if not targets:
                continue
            for j, _ in enumerate(entries):
                targets[j % len(targets)].add_warm(
                    func, sim.now + KEEPALIVE_MS, sim.now)
                moved += 1
        if moved:
            sim.migrations += moved
            rec = getattr(sim, "recorder", None)
            if rec is not None and rec.enabled:
                rec.on_migrate(sim.now, inv_idx, moved)

    def prefetch(self, sim, app, stage: str, inv_idx: int) -> int:
        """Predictive next-stage weight prefetch (the Torpor lever,
        called by the emulator when ``sim.prefetch_weights`` is on):
        when stage ``i`` of a pipeline dispatches on ``inv_idx``, the
        successor stages' weights are enqueued there as *background*
        PCIe copies — locality placement probes that invoker first, so
        the copy overlaps stage ``i``'s execution and the successor's
        start pays only the residual.  Returns the number of copies
        enqueued; policies may override the prediction."""
        inv = sim.invokers[inv_idx]
        issued = 0
        for succ in app.edges.get(stage, ()):
            issued += int(inv.prefetch(app.func_of[succ], sim.now))
        rec = getattr(sim, "recorder", None)
        if issued and rec is not None and rec.enabled:
            rec.on_prefetch_issued(sim.now, issued)
        return issued

    # ---- shared helpers ---------------------------------------------------
    @staticmethod
    def warm_count(sim, func: str) -> int:
        return sum(len(inv.device.warm_entries(func, sim.now))
                   for inv in sim.invokers)

    @staticmethod
    def spread_order(sim, func: str) -> list:
        """Invokers ordered for pre-warm placement: emptiest first; under
        a memory-aware scheduler, invokers where the function's weights
        are already resident come first (a pre-warm there maps the shared
        read-only weights instead of staging a new copy), with the legacy
        emptiest-first order breaking ties — memory-blind runs see the
        legacy order unchanged."""
        order = sorted(sim.invokers, key=lambda i: -i.free_vgpu)
        if getattr(sim.sched, "placement", None) == "memory":
            cold_ms = sim.profiles[func].cold_ms
            order.sort(key=lambda i: i.start_penalty_ms(func, cold_ms,
                                                        sim.now))
        if getattr(sim, "prefer_on_demand", False):
            # burn-rate alert firing: stable re-sort puts reliable
            # on-demand SKUs ahead of preemptible spot capacity (no-op
            # on homogeneous fleets — every key is False)
            order.sort(key=lambda i: i.sku.spot)
        return order


@_register
class NoPrewarm(AutoscalerPolicy):
    """Every container start is cold; keep-alive reuse still applies."""
    name = "none"


@_register
class EwmaPrewarm(AutoscalerPolicy):
    """EWMA inter-arrival pre-warming (paper §4) — the default policy.

    Replicates the emulator's original hard-coded behaviour exactly:
      * ``initial_warm`` containers per function on every invoker at t=0;
      * a cold start reactively warms one extra container on that invoker;
      * per function, an EWMA of the dispatch inter-arrival schedules the
        next pre-warm ``cold_ms`` ahead of the predicted next request.
    """
    name = "ewma"

    def __init__(self, initial_warm: int = 2, alpha: float = 0.3,
                 bootstrap_interval_ms: float = 1000.0):
        self.initial_warm = initial_warm
        self.alpha = alpha
        self.bootstrap_interval_ms = bootstrap_interval_ms
        self.ewma: dict[str, tuple[float, float]] = {}  # func -> (interval, last)

    def seed_pools(self, sim):
        if not self.initial_warm:
            return
        from repro.cluster.emulator import KEEPALIVE_MS
        for inv in sim.invokers:
            for func in sim.profiles:
                for _ in range(self.initial_warm):
                    inv.add_warm(func, KEEPALIVE_MS)

    def on_dispatch(self, sim, func, inv_idx, cold, service_ms):
        from repro.cluster.emulator import KEEPALIVE_MS
        if cold:
            # reactive scale-up: a cold start signals under-provisioned
            # capacity — warm an extra container alongside this one
            sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS,
                                           sim.now)
        prev = self.ewma.get(func)
        if prev is None:
            self.ewma[func] = (self.bootstrap_interval_ms, sim.now)
            return
        interval, last = prev
        obs = sim.now - last
        interval = (1.0 - self.alpha) * interval + self.alpha * obs
        self.ewma[func] = (interval, sim.now)
        lead = sim.profiles[func].cold_ms
        when = sim.now + max(interval - lead, 0.0)
        sim.push_event(when, "prewarm", (func, inv_idx))


@_register
class FineGrained(AutoscalerPolicy):
    """HAS-GPU-style fine-grained scale-up/down (arXiv 2505.01968).

    Per function, a sliding window of dispatch timestamps estimates the
    arrival rate and an EWMA tracks the service time.  Little's law gives
    the target number of concurrently-needed containers::

        target = ceil(rate * service_ms * headroom)

    Deficits are pre-warmed immediately (spread over the least-loaded
    invokers); surpluses beyond ``target + slack`` are retired by dropping
    the latest-expiring warm entries (scale-down) — the lever uniform
    keep-alive pools lack.
    """
    name = "finegrained"

    def __init__(self, window: int = 16, headroom: float = 1.25,
                 slack: int = 1, initial_warm: int = 1):
        self.window = window
        self.headroom = headroom
        self.slack = slack
        self.initial_warm = initial_warm
        self._times: dict[str, list[float]] = {}
        self._service: dict[str, float] = {}
        self._pending: dict[str, int] = {}   # prewarms pushed, not yet applied

    def seed_pools(self, sim):
        if not self.initial_warm:
            return
        from repro.cluster.emulator import KEEPALIVE_MS, home_invoker
        n = len(sim.invokers)
        seeded = set()
        # minimal footprint: seed each app's root-stage function on the
        # home invoker locality placement will actually probe first
        for app in sim.apps.values():
            for root in app.roots:
                func = app.func_of[root]
                idx = home_invoker(app.name, func, n)
                if (func, idx) in seeded:
                    continue
                seeded.add((func, idx))
                for _ in range(self.initial_warm):
                    sim.invokers[idx].add_warm(func, KEEPALIVE_MS)

    def _target(self, sim, func: str) -> Optional[int]:
        ts = self._times.get(func, ())
        if len(ts) < 2:
            return None
        span = ts[-1] - ts[0]
        if span <= 0:
            return None
        rate = (len(ts) - 1) / span                       # req / ms
        service = self._service.get(
            func, sim.profiles[func].exec_ms(Config(1, 1, 1)))
        return max(1, math.ceil(rate * service * self.headroom))

    def on_dispatch(self, sim, func, inv_idx, cold, service_ms):
        from repro.cluster.emulator import KEEPALIVE_MS
        ts = self._times.setdefault(func, [])
        ts.append(sim.now)
        if len(ts) > self.window:
            del ts[0]
        prev = self._service.get(func)
        self._service[func] = (service_ms if prev is None
                               else 0.7 * prev + 0.3 * service_ms)
        target = self._target(sim, func)
        if target is None:
            if cold:  # bootstrap: behave reactively until the window fills
                sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS,
                                               sim.now)
            return
        # count prewarms already in flight (pushed but not yet popped by
        # the event loop) or same-instant dispatches would re-push the
        # whole deficit each time and overshoot the target
        have = self.warm_count(sim, func) + self._pending.get(func, 0)
        if have < target:
            # scale up: pre-warm the deficit on the emptiest invokers
            # (weight-resident invokers first under a memory-aware
            # scheduler — see ``spread_order``)
            order = self.spread_order(sim, func)
            for j in range(target - have):
                inv = order[j % len(order)]
                sim.push_event(sim.now, "autoscale", (func, inv.idx))
                self._pending[func] = self._pending.get(func, 0) + 1
        elif have > target + self.slack:
            # scale down: retire the latest-expiring surplus containers
            surplus = have - target
            pools = sorted(
                ((c, inv) for inv in sim.invokers
                 for c in inv.device.warm_entries(func, sim.now)),
                key=lambda p: -p[0].expiry)
            rec = getattr(sim, "recorder", None)
            recording = rec is not None and rec.enabled
            for c, inv in pools[:surplus]:
                inv.device.retire(func, c)
                if recording:
                    rec.on_retire(sim.now)

    def on_tick(self, sim, payload):
        from repro.cluster.emulator import KEEPALIVE_MS
        func, inv_idx = payload
        sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS, sim.now)
        self._pending[func] = max(self._pending.get(func, 0) - 1, 0)


@_register
class VerticalFineGrained(FineGrained):
    """``FineGrained`` + vertical fractional-vGPU reallocation of
    *running* pools (HAS-GPU arXiv 2505.01968's actual lever).

    Two moves, both through ``sim.resize_task`` so latency, cost and the
    device slice ledger stay consistent:

      * **grow** — when a task completes (or a dispatch leaves slack)
        and *no work is queued*, idle slices are granted to the running
        tasks on that invoker, up to ``grow_cap`` x the dispatched
        quota; tasks finish early instead of the slices idling.
      * **shrink** — when a queued stage cannot be placed anywhere, the
        policy throttles running tasks (never below ``shrink_floor`` x
        the dispatched quota, and never below one slice) on the best
        candidate invoker until the blocked config fits, then the
        emulator retries placement.  Container-granularity scaling can
        only wait for a whole container to finish; this is the lever it
        lacks.
    """
    name = "vertical"

    def __init__(self, grow_cap: float = 2.0, shrink_floor: float = 0.5,
                 **kw):
        super().__init__(**kw)
        self.grow_cap = grow_cap
        self.shrink_floor = shrink_floor

    # ---- helpers ----------------------------------------------------------
    @staticmethod
    def _queued(sim) -> bool:
        return any(len(q) for q in sim.queues.values())

    def _floor(self, task) -> int:
        return max(1, math.ceil(task.config.vgpu * SLICES_PER_VGPU *
                                self.shrink_floor))

    def _cap(self, task) -> int:
        return max(1, int(task.config.vgpu * SLICES_PER_VGPU *
                          self.grow_cap))

    @staticmethod
    def _running_on(sim, inv_idx: int):
        return sorted((t for t in sim.running.values()
                       if t.invoker == inv_idx),
                      key=lambda t: (-t.end_ms, t.tid))

    # ---- grow -------------------------------------------------------------
    def _grow(self, sim, inv_idx: int):
        if self._queued(sim):
            return                      # queued work gets the slices instead
        if self.health is not None and self.health.early_warning():
            # a firing alert (SLO burn, queue buildup, cold-start spike)
            # predicts imminent queued work: keep the idle slices free
            # for it instead of granting them to running tasks — the
            # shrink path would only claw them back a resize later
            return
        inv = sim.invokers[inv_idx]
        free = inv.device.free_slices
        for task in self._running_on(sim, inv_idx):   # latest finisher first
            if free <= 0:
                break
            grant = min(free, self._cap(task) - task.quota_slices)
            if grant > 0 and sim.resize_task(task,
                                             task.quota_slices + grant):
                free -= grant

    def on_complete(self, sim, task):
        self._grow(sim, task.invoker)

    def on_dispatch(self, sim, func, inv_idx, cold, service_ms):
        super().on_dispatch(sim, func, inv_idx, cold, service_ms)
        self._grow(sim, inv_idx)

    # ---- shrink -----------------------------------------------------------
    def on_congestion(self, sim, app, stage, candidates) -> bool:
        func = app.func_of[stage]
        for cfg in candidates:
            if not sim.gpu_sharing:
                # mirror the emulator's ablation transform: the retried
                # placement will ask for the whole device, so freeing
                # less than that is pointless throttling
                cfg = Config(cfg.batch, cfg.vcpu, sim.invokers[0].vgpus)
            need = cfg.vgpu * SLICES_PER_VGPU
            for inv in sim.invokers:
                if inv.free_vcpu < cfg.vcpu:
                    continue
                if not inv.device.hbm_admits(inv.model_mb(func), func,
                                             sim.now):
                    continue            # memory, not compute, is the blocker
                deficit = need - inv.device.free_slices
                running = self._running_on(sim, inv.idx)
                headroom = sum(max(t.quota_slices - self._floor(t), 0)
                               for t in running)
                if deficit <= 0 or headroom < deficit:
                    continue
                # throttle the biggest donors first until the config fits
                for t in sorted(running,
                                key=lambda t: (self._floor(t) -
                                               t.quota_slices, t.tid)):
                    give = min(max(t.quota_slices - self._floor(t), 0),
                               deficit)
                    if give > 0 and sim.resize_task(
                            t, t.quota_slices - give):
                        deficit -= give
                    if deficit <= 0:
                        return True
        return False


def get_autoscaler(name: str, **kw) -> AutoscalerPolicy:
    if name not in AUTOSCALERS:
        raise KeyError(f"unknown autoscaler {name!r}; "
                       f"have {sorted(AUTOSCALERS)}")
    return AUTOSCALERS[name](**kw)
