"""Pluggable warm-pool / vGPU autoscaler policies.

The cluster emulator used to hard-code its pre-warming behaviour (EWMA
inter-arrival prediction + reactive warm-on-cold + static initial pools).
That logic now lives here behind ``AutoscalerPolicy`` so serving runs can
swap policies without touching the event loop:

  * ``EwmaPrewarm``  — the paper-§4 default, bit-compatible with the old
    emulator behaviour (initial pools, reactive scale-up on a cold start,
    EWMA-timed pre-warm events).
  * ``FineGrained``  — HAS-GPU-style fine-grained scaling: per-function
    arrival-rate and service-time estimates drive a Little's-law target
    pool size; surplus containers are retired early (scale-down), deficits
    are pre-warmed immediately.
  * ``NoPrewarm``    — cold-start-always baseline (no pools, no events).

Policies interact with the emulator through three hooks:
  ``seed_pools(sim)``                       once, after invokers exist;
  ``on_dispatch(sim, func, inv, cold, ms)`` after every task dispatch;
  ``on_tick(sim, payload)``                 on ``autoscale`` timer events
                                            the policy scheduled itself.
Pre-warms are requested by pushing the emulator's generic ``prewarm``
event; scale-down manipulates invoker pools directly.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.profiles import Config

AUTOSCALERS: dict[str, type] = {}


def _register(cls):
    AUTOSCALERS[cls.name] = cls
    return cls


class AutoscalerPolicy:
    """Warm-pool policy interface driven by the cluster emulator."""
    name = "base"

    def seed_pools(self, sim) -> None:
        """Populate initial warm pools (sim.invokers exist, sim.now == 0)."""

    def on_dispatch(self, sim, func: str, inv_idx: int, cold: bool,
                    service_ms: float) -> None:
        """Observe one task dispatch (cold tells whether a warm container
        was found); schedule pre-warms / scale down as the policy sees fit."""

    def on_tick(self, sim, payload) -> None:
        """Handle an ``autoscale`` event the policy scheduled earlier."""

    # ---- shared helpers ---------------------------------------------------
    @staticmethod
    def warm_count(sim, func: str) -> int:
        now = sim.now
        return sum(sum(1 for e in inv.warm[func] if e >= now)
                   for inv in sim.invokers)


@_register
class NoPrewarm(AutoscalerPolicy):
    """Every container start is cold; keep-alive reuse still applies."""
    name = "none"


@_register
class EwmaPrewarm(AutoscalerPolicy):
    """EWMA inter-arrival pre-warming (paper §4) — the default policy.

    Replicates the emulator's original hard-coded behaviour exactly:
      * ``initial_warm`` containers per function on every invoker at t=0;
      * a cold start reactively warms one extra container on that invoker;
      * per function, an EWMA of the dispatch inter-arrival schedules the
        next pre-warm ``cold_ms`` ahead of the predicted next request.
    """
    name = "ewma"

    def __init__(self, initial_warm: int = 2, alpha: float = 0.3,
                 bootstrap_interval_ms: float = 1000.0):
        self.initial_warm = initial_warm
        self.alpha = alpha
        self.bootstrap_interval_ms = bootstrap_interval_ms
        self.ewma: dict[str, tuple[float, float]] = {}  # func -> (interval, last)

    def seed_pools(self, sim):
        if not self.initial_warm:
            return
        from repro.cluster.emulator import KEEPALIVE_MS
        for inv in sim.invokers:
            for func in sim.profiles:
                for _ in range(self.initial_warm):
                    inv.add_warm(func, KEEPALIVE_MS)

    def on_dispatch(self, sim, func, inv_idx, cold, service_ms):
        from repro.cluster.emulator import KEEPALIVE_MS
        if cold:
            # reactive scale-up: a cold start signals under-provisioned
            # capacity — warm an extra container alongside this one
            sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS)
        prev = self.ewma.get(func)
        if prev is None:
            self.ewma[func] = (self.bootstrap_interval_ms, sim.now)
            return
        interval, last = prev
        obs = sim.now - last
        interval = (1.0 - self.alpha) * interval + self.alpha * obs
        self.ewma[func] = (interval, sim.now)
        lead = sim.profiles[func].cold_ms
        when = sim.now + max(interval - lead, 0.0)
        sim.push_event(when, "prewarm", (func, inv_idx))


@_register
class FineGrained(AutoscalerPolicy):
    """HAS-GPU-style fine-grained scale-up/down (arXiv 2505.01968).

    Per function, a sliding window of dispatch timestamps estimates the
    arrival rate and an EWMA tracks the service time.  Little's law gives
    the target number of concurrently-needed containers::

        target = ceil(rate * service_ms * headroom)

    Deficits are pre-warmed immediately (spread over the least-loaded
    invokers); surpluses beyond ``target + slack`` are retired by dropping
    the latest-expiring warm entries (scale-down) — the lever uniform
    keep-alive pools lack.
    """
    name = "finegrained"

    def __init__(self, window: int = 16, headroom: float = 1.25,
                 slack: int = 1, initial_warm: int = 1):
        self.window = window
        self.headroom = headroom
        self.slack = slack
        self.initial_warm = initial_warm
        self._times: dict[str, list[float]] = {}
        self._service: dict[str, float] = {}
        self._pending: dict[str, int] = {}   # prewarms pushed, not yet applied

    def seed_pools(self, sim):
        if not self.initial_warm:
            return
        from repro.cluster.emulator import KEEPALIVE_MS, home_invoker
        n = len(sim.invokers)
        seeded = set()
        # minimal footprint: seed each app's root-stage function on the
        # home invoker locality placement will actually probe first
        for app in sim.apps.values():
            for root in app.roots:
                func = app.func_of[root]
                idx = home_invoker(app.name, func, n)
                if (func, idx) in seeded:
                    continue
                seeded.add((func, idx))
                for _ in range(self.initial_warm):
                    sim.invokers[idx].add_warm(func, KEEPALIVE_MS)

    def _target(self, sim, func: str) -> Optional[int]:
        ts = self._times.get(func, ())
        if len(ts) < 2:
            return None
        span = ts[-1] - ts[0]
        if span <= 0:
            return None
        rate = (len(ts) - 1) / span                       # req / ms
        service = self._service.get(
            func, sim.profiles[func].exec_ms(Config(1, 1, 1)))
        return max(1, math.ceil(rate * service * self.headroom))

    def on_dispatch(self, sim, func, inv_idx, cold, service_ms):
        from repro.cluster.emulator import KEEPALIVE_MS
        ts = self._times.setdefault(func, [])
        ts.append(sim.now)
        if len(ts) > self.window:
            del ts[0]
        prev = self._service.get(func)
        self._service[func] = (service_ms if prev is None
                               else 0.7 * prev + 0.3 * service_ms)
        target = self._target(sim, func)
        if target is None:
            if cold:  # bootstrap: behave reactively until the window fills
                sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS)
            return
        # count prewarms already in flight (pushed but not yet popped by
        # the event loop) or same-instant dispatches would re-push the
        # whole deficit each time and overshoot the target
        have = self.warm_count(sim, func) + self._pending.get(func, 0)
        if have < target:
            # scale up: pre-warm the deficit on the emptiest invokers
            order = sorted(sim.invokers, key=lambda i: -i.free_vgpu)
            for j in range(target - have):
                inv = order[j % len(order)]
                sim.push_event(sim.now, "autoscale", (func, inv.idx))
                self._pending[func] = self._pending.get(func, 0) + 1
        elif have > target + self.slack:
            # scale down: retire the latest-expiring surplus containers
            surplus = have - target
            pools = sorted(
                ((e, inv) for inv in sim.invokers
                 for e in inv.warm[func] if e >= sim.now),
                key=lambda p: -p[0])
            for e, inv in pools[:surplus]:
                inv.warm[func].remove(e)

    def on_tick(self, sim, payload):
        from repro.cluster.emulator import KEEPALIVE_MS
        func, inv_idx = payload
        sim.invokers[inv_idx].add_warm(func, sim.now + KEEPALIVE_MS)
        self._pending[func] = max(self._pending.get(func, 0) - 1, 0)


def get_autoscaler(name: str, **kw) -> AutoscalerPolicy:
    if name not in AUTOSCALERS:
        raise KeyError(f"unknown autoscaler {name!r}; "
                       f"have {sorted(AUTOSCALERS)}")
    return AUTOSCALERS[name](**kw)
