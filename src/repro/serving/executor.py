"""Real-compute execution bridge: compile-cached batched Pallas serving.

``RealExecutor`` runs the *actual* jax/Pallas kernels (flash_attention
prefill, scalar-prefetch flash_decode, WKV6 for SSM archs — all via
``models/model.py``) behind the emulator's Gateway → autoscaler →
``ClusterSim`` dispatch path.  The emulator stays the timing/placement
model; every dispatched task is additionally *executed for real* here,
and the measured wall times validate the emulator's predictions
(``BENCH_realcompute.json``).

Fast-path design, in order of importance:

* **Batch-lattice bucketing** — a dispatched batch of n jobs pads up to
  the nearest ``batch_lattice`` bucket, so the set of shapes the device
  ever sees is the profile lattice itself.  Each (arch, stage,
  batch-bucket, quota) cell compiles exactly once.
* **Persistent compile cache** — stage step functions are AOT-compiled
  (``jit(...).lower(...).compile()``) into ``self._exe`` keyed on that
  tuple, with hit/miss counters; after ``warmup()`` the steady-state
  hit rate is exactly 1.0 (asserted in CI).  Fractional-quota variants
  of a bucket share the bucket's executables (quota is a run-count, see
  below), so a quota change can never trigger a recompile either.
* **Donated decode buffers** — the decode step donates the KV cache
  (``donate_argnums``), so the hot loop updates the cache in place
  instead of allocating a fresh one per token.
* **Async dispatch** — ``submit()`` enqueues onto a single-worker
  executor and returns a future immediately; the gateway/emulator
  thread never blocks on device completion.  ``drain()`` collects the
  measured records at end of run.

Fractional compute quota q < 1 is emulated on a time-sliced sharing
model: the cell runs ``round(1/q)`` serialized passes, so the measured
latency is what a container throttled to a 1/q device share observes.
This is the measured counterpart of the profile model's
``QUOTA_SLOWDOWN_EXP`` (cross-checked by ``launch/profile_kernels.py``).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.gpu import SLICES_PER_VGPU
from repro.models.model import RunOptions, get_model

DEFAULT_BATCH_LATTICE = (1, 2, 4, 8)
DEFAULT_QUOTAS = (1.0, 0.5, 0.25)


@dataclasses.dataclass
class ExecRecord:
    """One real execution of a dispatched task (or a profiling rep)."""
    tid: int                    # emulator task id (-1 for profiling runs)
    func: str
    stage: str                  # emulator stage name ("" for profiling)
    n_jobs: int                 # real jobs in the batch (before padding)
    bucket: int                 # padded batch bucket actually executed
    quota: float                # fractional compute quota emulated
    wall_ms: float              # measured end-to-end (prefill + decode)
    prefill_ms: float           # prefill component
    decode_ms: float            # decode-loop component (gen_len steps)
    cache_hit: bool             # compile cache hit at submit time


class RealExecutor:
    """Compile-cached batched real execution for one (reduced) arch."""

    def __init__(self, arch: str,
                 batch_lattice: tuple = DEFAULT_BATCH_LATTICE,
                 quotas: tuple = DEFAULT_QUOTAS,
                 prompt_len: int = 32, gen_len: int = 4,
                 seed: int = 0, use_kernels: bool = True):
        self.arch = arch
        self.cfg = reduced(get_config(arch))
        self.opts = RunOptions(use_kernels=use_kernels, remat="none",
                               attn_chunk=64, param_dtype=jnp.float32,
                               act_dtype=jnp.float32)
        self.model = get_model(self.cfg, self.opts)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.batch_lattice = tuple(sorted(batch_lattice))
        self.quotas = tuple(sorted(quotas, reverse=True))
        if 1.0 not in self.quotas:
            self.quotas = (1.0,) + self.quotas
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.max_len = prompt_len + gen_len
        rng = np.random.default_rng(seed)
        # deterministic per-bucket token batches: padding a real batch
        # reuses the bucket's prefix so shapes — and therefore compiled
        # executables — are a pure function of the bucket
        self._tokens = {
            b: jnp.asarray(rng.integers(0, self.cfg.vocab,
                                        (b, prompt_len)), jnp.int32)
            for b in self.batch_lattice
        }
        # compile cache: (arch, stage, bucket, quota) -> executable.
        # Quota variants alias the bucket's two stage executables (quota
        # is a serialized-pass count, not a shape), so they can never
        # force a recompile; they still get their own cache entries so
        # the hit/miss accounting covers the full dispatch key.
        self._exe: dict[tuple, Any] = {}
        self.compiles = 0            # actual XLA compilations performed
        self.warmup_compiles = 0     # ... of which during warmup()
        self.cache_hits = 0          # submit()-time cache hits
        self.cache_misses = 0        # submit()-time compile-cache misses
        self._warmed = False
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._futures: dict[int, Future] = {}
        self.records: list[ExecRecord] = []

    # ---- compile cache ----------------------------------------------------
    def _compile_bucket(self, bucket: int) -> tuple:
        """AOT-compile the prefill and donated-cache decode executables
        for one batch bucket (the expensive path — once per bucket)."""
        toks = self._tokens[bucket]
        max_len = self.max_len

        def prefill_fn(params, tokens):
            return self.model.prefill(params, {"tokens": tokens},
                                      max_len=max_len)

        def decode_fn(params, cache, tokens):
            return self.model.decode(params, cache, tokens)

        prefill = jax.jit(prefill_fn).lower(self.params, toks).compile()
        self.compiles += 1
        _, cache = prefill(self.params, toks)
        nxt = jnp.zeros((bucket, 1), jnp.int32)
        # donate the KV cache: the decode hot loop rewrites it in place
        decode = jax.jit(decode_fn, donate_argnums=(1,)).lower(
            self.params, cache, nxt).compile()
        self.compiles += 1
        jax.block_until_ready(cache)
        return prefill, decode

    def _cell(self, stage: str, bucket: int, quota: float):
        """Cache lookup for one (arch, stage, bucket, quota) cell;
        compiles on miss.  Returns (executable, hit)."""
        key = (self.arch, stage, bucket, quota)
        exe = self._exe.get(key)
        if exe is not None:
            return exe, True
        base_p = (self.arch, "prefill", bucket, 1.0)
        base_d = (self.arch, "decode", bucket, 1.0)
        if base_p not in self._exe:
            prefill, decode = self._compile_bucket(bucket)
            self._exe[base_p] = prefill
            self._exe[base_d] = decode
        # quota aliases: same executables, distinct cache identity
        self._exe[(self.arch, "prefill", bucket, quota)] = self._exe[base_p]
        self._exe[(self.arch, "decode", bucket, quota)] = self._exe[base_d]
        return self._exe[key], False

    def warmup(self) -> dict:
        """Compile every (stage, bucket, quota) lattice cell and run one
        pass per bucket, so steady-state serving never compiles again
        (post-warmup hit rate == 1.0, the CI-asserted invariant)."""
        t0 = time.perf_counter()
        before = self.compiles
        for bucket in self.batch_lattice:
            for quota in self.quotas:
                self._cell("prefill", bucket, quota)
                self._cell("decode", bucket, quota)
            self._run(bucket, 1.0)     # execute once: warm allocators
        self.warmup_compiles = self.compiles - before
        self._warmed = True
        # warmup fills are not serving traffic: reset serving counters
        self.cache_hits = self.cache_misses = 0
        return {"warmup_compiles": self.warmup_compiles,
                "warmup_s": time.perf_counter() - t0,
                "cells": len(self._exe)}

    # ---- execution --------------------------------------------------------
    def _run(self, bucket: int, quota: float) -> tuple[float, float]:
        """One real serve of a bucket at a quota: prefill + gen_len
        greedy decode steps, ``round(1/q)`` serialized passes.  Returns
        (prefill_ms, decode_ms) wall components."""
        prefill, _ = self._cell("prefill", bucket, quota)
        decode, _ = self._cell("decode", bucket, quota)
        passes = max(int(round(1.0 / quota)), 1)
        toks = self._tokens[bucket]
        pre_ms = dec_ms = 0.0
        for _ in range(passes):
            t0 = time.perf_counter()
            logits, cache = prefill(self.params, toks)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            jax.block_until_ready(nxt)
            t1 = time.perf_counter()
            for _ in range(self.gen_len):
                logits, cache = decode(self.params, cache, nxt)
                nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            jax.block_until_ready(nxt)
            pre_ms += (t1 - t0) * 1e3
            dec_ms += (time.perf_counter() - t1) * 1e3
        return pre_ms, dec_ms

    def bucket_of(self, n: int) -> int:
        for b in self.batch_lattice:
            if n <= b:
                return b
        return self.batch_lattice[-1]

    def quota_of(self, task) -> float:
        """Snap a task's delivered slice quota to the measured lattice."""
        cfg = task.config
        q = task.quota_slices / max(cfg.vgpu * SLICES_PER_VGPU, 1)
        return min(self.quotas, key=lambda x: abs(x - q))

    # ---- emulator hook ----------------------------------------------------
    def submit(self, task) -> Future:
        """ClusterSim._dispatch hook: execute the dispatched task for
        real, asynchronously.  Never blocks the emulator thread."""
        n_jobs = len(task.jobs)
        bucket = self.bucket_of(max(n_jobs, 1))
        quota = self.quota_of(task)
        # cache accounting happens on the caller thread so the hit/miss
        # ordering matches dispatch order deterministically
        _, hit_p = self._cell("prefill", bucket, quota)
        _, hit_d = self._cell("decode", bucket, quota)
        hit = hit_p and hit_d
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        tid, func, stage = task.tid, task.func, task.stage

        def work() -> ExecRecord:
            pre, dec = self._run(bucket, quota)
            rec = ExecRecord(tid=tid, func=func, stage=stage,
                             n_jobs=n_jobs, bucket=bucket, quota=quota,
                             wall_ms=pre + dec, prefill_ms=pre,
                             decode_ms=dec, cache_hit=hit)
            self.records.append(rec)
            return rec

        fut = self._pool.submit(work)
        self._futures[tid] = fut
        return fut

    def measure(self, bucket: int, quota: float, reps: int = 3,
                ) -> ExecRecord:
        """Synchronous timed run for profiling: floor of ``reps``.

        Wall-clock noise on a shared host is one-sided (runs only ever
        get slower), so the minimum is the reproducible statistic — a
        median of few reps swings ~10% run to run at ms-scale cells."""
        runs = [self._run(bucket, quota) for _ in range(reps)]
        pre = float(np.min([r[0] for r in runs]))
        dec = float(np.min([r[1] for r in runs]))
        return ExecRecord(tid=-1, func=self.arch, stage="", n_jobs=bucket,
                          bucket=bucket, quota=quota, wall_ms=pre + dec,
                          prefill_ms=pre, decode_ms=dec, cache_hit=True)

    # ---- teardown / stats -------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> list[ExecRecord]:
        """Wait for all in-flight work; returns the full record list."""
        for fut in list(self._futures.values()):
            fut.result(timeout=timeout)
        return list(self.records)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)

    def stats(self) -> dict:
        served = self.cache_hits + self.cache_misses
        return {
            "arch": self.arch,
            "batch_lattice": list(self.batch_lattice),
            "quotas": list(self.quotas),
            "prompt_len": self.prompt_len,
            "gen_len": self.gen_len,
            "compiles": self.compiles,
            "warmup_compiles": self.warmup_compiles,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "post_warmup_hit_rate": (self.cache_hits / served) if served
            else None,
            "executed": len(self.records),
        }
