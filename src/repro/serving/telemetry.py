"""Serving telemetry: per-stage latency histograms, SLO attainment, cost,
utilization, GPU device-model metrics (slices, HBM, swap tiers), shed
precision, cold-start and shed counters.

``Telemetry`` is fed from two sides:
  * the gateway increments injection/admission/shed counters online (shed
    decisions are logged with budget + prediction for precision scoring);
  * after (or during) a run, ``collect(sim)`` derives per-stage queue/exec
    histograms, per-app SLO attainment, utilization, cost, the aggregated
    device-model counters (hot/warm hits, swap-ins, demotions, vertical
    resizes, HBM peak) and shed precision from the emulator's logs.

Shed precision: each shed is scored retrospectively — *true* if the
request was provably doomed (budget below the empty-cluster fastest
path) or the completed same-app request arriving nearest in time missed
that budget too; *false* if that neighbour made it; *unknown* when no
completed neighbour exists to compare against.

``summary()`` returns the structured dict the benchmarks consume;
``format_table(rows)`` renders a list of such dicts as the human-readable
sweep table.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import defaultdict
from typing import Any, Optional

import numpy as np

from repro.gpu import SLICES_PER_VGPU


class LatencyHistogram:
    """Log-bucketed latency histogram (0.01 ms .. ~28 h, 8 buckets/decade).

    Exact values are not retained; percentiles interpolate inside the
    matched bucket, which is plenty for serving dashboards and keeps the
    memory footprint O(1) in trace length.
    """

    def __init__(self, lo_ms: float = 1e-2, hi_ms: float = 1e8,
                 buckets_per_decade: int = 8):
        n = int(np.ceil(np.log10(hi_ms / lo_ms) * buckets_per_decade)) + 1
        self.bounds = lo_ms * 10 ** (np.arange(n) / buckets_per_decade)
        self.counts = np.zeros(n + 1, dtype=np.int64)
        self.total = 0.0
        self.n = 0
        self.max_ms = 0.0
        self._cum: Optional[np.ndarray] = None   # cumsum cache

    def record(self, ms: float):
        idx = int(np.searchsorted(self.bounds, ms, side="right"))
        self.counts[idx] += 1
        self.total += ms
        self.n += 1
        self.max_ms = max(self.max_ms, ms)
        self._cum = None

    def record_many(self, values) -> None:
        """Vectorized ``record`` over an array of samples: one
        searchsorted + bincount instead of a Python loop per sample
        (bucket counts come out identical; ``total`` may differ from the
        loop in the last ulp since the sum is reassociated)."""
        a = np.asarray(values, dtype=np.float64)
        if a.size == 0:
            return
        idx = np.searchsorted(self.bounds, a, side="right")
        self.counts += np.bincount(idx, minlength=self.counts.size)
        self.total += float(a.sum())
        self.n += int(a.size)
        self.max_ms = max(self.max_ms, float(a.max()))
        self._cum = None

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold ``other`` into this histogram in place (sharded-emulator
        aggregation): the result is exactly what recording the union of
        both sample streams would have produced.  Bucket layouts must
        match."""
        if self.bounds.shape != other.bounds.shape or \
                not np.array_equal(self.bounds, other.bounds):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        self.counts += other.counts
        self.total += other.total
        self.n += other.n
        self.max_ms = max(self.max_ms, other.max_ms)
        self._cum = None
        return self

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def _cumsum(self) -> np.ndarray:
        # O(1) amortised across repeated percentile() calls (to_dict
        # alone takes three); invalidated by record()/merge()
        if self._cum is None:
            self._cum = np.cumsum(self.counts)
        return self._cum

    def percentile(self, p: float) -> float:
        """p in [0, 100]; linear interpolation within the hit bucket."""
        if not self.n:
            return 0.0
        rank = p / 100.0 * self.n
        cum = self._cumsum()
        idx = int(np.searchsorted(cum, rank, side="left"))
        idx = min(idx, len(self.counts) - 1)
        lo = self.bounds[idx - 1] if idx > 0 else 0.0
        hi = self.bounds[idx] if idx < len(self.bounds) else self.max_ms
        prev = cum[idx - 1] if idx > 0 else 0
        frac = (rank - prev) / max(self.counts[idx], 1)
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    def to_dict(self) -> dict[str, float]:
        return {"n": int(self.n), "mean_ms": self.mean,
                "p50_ms": self.percentile(50), "p95_ms": self.percentile(95),
                "p99_ms": self.percentile(99), "max_ms": self.max_ms}


@dataclasses.dataclass
class ShedRecord:
    """One load-shedding decision, kept for precision scoring."""
    t_ms: float
    app: str
    budget_ms: float
    need_ms: float               # fastest + predicted queueing at decision
    fastest_ms: float            # empty-cluster critical path


@dataclasses.dataclass
class StageStats:
    queue: LatencyHistogram = dataclasses.field(default_factory=LatencyHistogram)
    exec: LatencyHistogram = dataclasses.field(default_factory=LatencyHistogram)
    jobs: int = 0
    tasks: int = 0
    cold: int = 0


class Telemetry:
    """Aggregated serving metrics for one run."""

    def __init__(self):
        self.injected: dict[str, int] = defaultdict(int)
        self.admitted: dict[str, int] = defaultdict(int)
        self.shed: dict[str, int] = defaultdict(int)
        self.stage: dict[tuple[str, str], StageStats] = defaultdict(StageStats)
        self.e2e = LatencyHistogram()
        self.slo_hits = 0
        self.completed = 0
        self.cold_starts = 0
        self.total_cost = 0.0
        self.gpu_busy_ms = 0.0
        self.gpu_capacity_ms = 0.0
        self.horizon_ms = 0.0
        self.scheduler = ""
        self.autoscaler = ""
        self.scenario = ""
        self.gpu: dict[str, Any] = {}
        self.fastest_ms: dict[str, float] = {}   # set by the gateway
        self.shed_records: list[ShedRecord] = []
        self.shed_true = 0
        self.shed_false = 0
        self.shed_unknown = 0
        # planner-audit calibration (predicted vs realized per-stage
        # latency error quantiles, each block carrying its sample count
        # ``n`` — quantiles are None below 2 samples), filled by
        # collect() when the sim carries an enabled flight recorder
        # with an audit log
        self.predicted_vs_realized: dict[str, Any] = {}
        # online-calibrator factor state (repro.obs.calibrate) and SLO
        # health-engine alert summary (repro.obs.health), when attached
        self.calibration: dict[str, Any] = {}
        self.health: dict[str, Any] = {}
        # per-function profile provenance ("zoo" analytic tables vs
        # "measured" real-kernel artifacts) — surfaces which numbers
        # the planner trusted for each function this run
        self.profile_provenance: dict[str, str] = {}

    # ---- gateway-side ------------------------------------------------------
    def on_injected(self, app: str):
        self.injected[app] += 1

    def on_admitted(self, app: str):
        self.admitted[app] += 1

    def on_shed(self, app: str, t_ms: Optional[float] = None,
                budget_ms: Optional[float] = None,
                need_ms: Optional[float] = None,
                fastest_ms: Optional[float] = None):
        self.shed[app] += 1
        if budget_ms is not None:
            self.shed_records.append(ShedRecord(
                t_ms or 0.0, app, budget_ms, need_ms or 0.0,
                fastest_ms or 0.0))

    # ---- streaming collection (retain="stream" sims) -----------------------
    def attach_stream(self, sim) -> "Telemetry":
        """Subscribe to a ``retain="stream"`` ClusterSim: per-stage and
        end-to-end metrics accumulate online at task retirement /
        request completion, because a streaming sim does not keep the
        object lists ``collect`` would otherwise scan.  The hooks fire
        before the sim recycles tasks/jobs through its pools, so the
        records they read are still intact."""
        self._done_by_app: dict[str, tuple[list, list]] = {}
        sim.on_task_retire = self._on_task_retire
        sim.on_request_done = self._on_request_done
        return self

    def _on_task_retire(self, task) -> None:
        st = self.stage[(task.jobs[0].inst.app.name, task.stage)]
        st.tasks += 1
        st.jobs += len(task.jobs)
        st.cold += int(task.cold)
        st.exec.record(task.end_ms - task.start_ms)
        start = task.start_ms
        for j in task.jobs:
            st.queue.record(max(start - j.ready_ms, 0.0))

    def _on_request_done(self, inst) -> None:
        lat = inst.finish_ms - inst.arrival_ms
        self.e2e.record(lat)
        self.completed += 1
        self.slo_hits += int(lat <= inst.slo_ms)
        # (arrival, latency) per app, for retrospective shed scoring —
        # ~2 floats/request, the only per-request state stream mode keeps
        arr, lats = self._done_by_app.setdefault(inst.app.name, ([], []))
        arr.append(inst.arrival_ms)
        lats.append(lat)

    # ---- post-run collection ----------------------------------------------
    def collect(self, sim) -> "Telemetry":
        """Derive stage/app metrics from a finished (or paused) ClusterSim."""
        self.scheduler = sim.sched.name
        self.autoscaler = getattr(sim.autoscaler, "name", "?")
        self.cold_starts = sim.cold_starts
        self.total_cost = sim.total_cost
        if getattr(sim, "retain", "full") == "stream":
            # stage/e2e metrics already accumulated via attach_stream
            horizon = sim._horizon_ms
        else:
            horizon = max((t.end_ms for t in sim.tasks), default=0.0)
            horizon = max(horizon, max((i.finish_ms for i in sim.completed),
                                       default=0.0))
            for t in sim.tasks:
                key = (t.jobs[0].inst.app.name, t.stage)
                st = self.stage[key]
                st.tasks += 1
                st.jobs += len(t.jobs)
                st.cold += int(t.cold)
                st.exec.record(t.end_ms - t.start_ms)
                for j in t.jobs:
                    st.queue.record(max(t.start_ms - j.ready_ms, 0.0))
            for inst in sim.completed:
                lat = inst.finish_ms - inst.arrival_ms
                self.e2e.record(lat)
                self.completed += 1
                self.slo_hits += int(lat <= inst.slo_ms)
        self.horizon_ms = horizon
        # busy time integrates the *actual* fractional quota over time
        # (vertical resizes included), not the dispatched config
        self.gpu_busy_ms = sim.slice_busy_ms / SLICES_PER_VGPU
        cap = sum(inv.vgpus for inv in sim.invokers)
        self.gpu_capacity_ms = cap * horizon
        self.gpu = sim.gpu_summary()
        self._score_sheds(sim)
        rec = getattr(sim, "recorder", None)
        if rec is not None and getattr(rec, "enabled", False):
            if getattr(rec, "audit", None) is not None:
                self.predicted_vs_realized = rec.calibration()
            health = getattr(rec, "health", None)
            if health is not None:
                self.health = health.summary()
        cal = getattr(sim.sched, "calibrator", None)
        if cal is not None:
            self.calibration = cal.summary()
        self.profile_provenance = {
            n: getattr(p, "provenance", "zoo")
            for n, p in sim.profiles.items()}
        return self

    def _score_sheds(self, sim) -> None:
        """Classify each shed decision as true/false/unknown (see module
        docstring) against the realized latencies of admitted traffic."""
        by_app: dict[str, tuple[list[float], list[float]]] = {}
        if getattr(sim, "retain", "full") == "stream":
            # same (arrival, latency) pairs in the same completion order
            # as sim.completed would hold, so the stable sort yields
            # arrays identical to the full-retention scan below
            for app, (arr, lats) in getattr(self, "_done_by_app",
                                            {}).items():
                order = sorted(range(len(arr)), key=arr.__getitem__)
                by_app[app] = ([arr[i] for i in order],
                               [lats[i] for i in order])
        else:
            for inst in sorted(sim.completed, key=lambda i: i.arrival_ms):
                arr, lat = by_app.setdefault(inst.app.name, ([], []))
                arr.append(inst.arrival_ms)
                lat.append(inst.finish_ms - inst.arrival_ms)
        self.shed_true = self.shed_false = self.shed_unknown = 0
        for rec in self.shed_records:
            if rec.budget_ms < rec.fastest_ms:
                self.shed_true += 1      # provably doomed on an idle cluster
                continue
            arr_lat = by_app.get(rec.app)
            if not arr_lat or not arr_lat[0]:
                self.shed_unknown += 1
                continue
            arr, lat = arr_lat
            i = bisect.bisect_left(arr, rec.t_ms)
            if i > 0 and (i == len(arr) or
                          rec.t_ms - arr[i - 1] <= arr[i] - rec.t_ms):
                i -= 1                   # nearest completed arrival in time
            if lat[i] > rec.budget_ms:
                self.shed_true += 1
            else:
                self.shed_false += 1

    # ---- sharded aggregation ----------------------------------------------
    def merge(self, other: "Telemetry") -> "Telemetry":
        """Fold another shard's telemetry into this one in place.

        Every shard owns a disjoint app population and invoker
        sub-fleet, so counters/costs/busy-time add, histograms merge
        exactly (``LatencyHistogram.merge``), peaks take the max, and
        shed scoring — already exact per shard, since a shed's scoring
        neighbours are same-app completions and an app lives in exactly
        one shard — adds.  Per-shard diagnostic blocks
        (``predicted_vs_realized`` / ``calibration`` / ``health``) are
        not combined; consumers read those from the per-shard exports."""
        for mine, theirs in ((self.injected, other.injected),
                             (self.admitted, other.admitted),
                             (self.shed, other.shed)):
            for app, c in theirs.items():
                mine[app] += c
        for key, st in other.stage.items():
            m = self.stage[key]
            m.queue.merge(st.queue)
            m.exec.merge(st.exec)
            m.jobs += st.jobs
            m.tasks += st.tasks
            m.cold += st.cold
        self.e2e.merge(other.e2e)
        self.slo_hits += other.slo_hits
        self.completed += other.completed
        self.cold_starts += other.cold_starts
        self.total_cost += other.total_cost
        self.gpu_busy_ms += other.gpu_busy_ms
        self.gpu_capacity_ms += other.gpu_capacity_ms
        self.horizon_ms = max(self.horizon_ms, other.horizon_ms)
        if not self.scheduler:
            self.scheduler, self.autoscaler, self.scenario = \
                other.scheduler, other.autoscaler, other.scenario
        self.fastest_ms.update(other.fastest_ms)
        self.shed_records.extend(other.shed_records)
        self.shed_true += other.shed_true
        self.shed_false += other.shed_false
        self.shed_unknown += other.shed_unknown
        # device counters are fleet sums except the HBM peak (fleet max:
        # max over shard maxes == max over the union fleet)
        for k, v in other.gpu.items():
            if k == "hbm_peak_mb":
                self.gpu[k] = max(self.gpu.get(k, 0.0), v)
            elif isinstance(v, (int, float)):
                self.gpu[k] = self.gpu.get(k, 0) + v
            else:
                self.gpu.setdefault(k, v)
        return self

    def shed_precision(self) -> Optional[float]:
        """True sheds over scored sheds; None when nothing was scorable."""
        scored = self.shed_true + self.shed_false
        return self.shed_true / scored if scored else None

    def prefetch_hit_rate(self) -> Optional[float]:
        """Predictive-prefetch hits over issued copies (overlapped swap
        pipeline); None when no prefetch was ever issued — the analogue
        of shed precision for the transfer engine's speculation."""
        issued = self.gpu.get("prefetch_issued", 0)
        return self.gpu["prefetch_hits"] / issued if issued else None

    def penalty_hidden_frac(self) -> Optional[float]:
        """Fraction of the additive-model restart penalty the transfer
        engine hid behind execution/data transfer; None when no penalty
        was ever due."""
        full = self.gpu.get("penalty_full_ms", 0.0)
        return (self.gpu["penalty_hidden_ms"] / full) if full else None

    # ---- summaries ---------------------------------------------------------
    @property
    def n_injected(self) -> int:
        return sum(self.injected.values())

    @property
    def n_shed(self) -> int:
        return sum(self.shed.values())

    @property
    def n_admitted(self) -> int:
        return sum(self.admitted.values())

    def slo_attainment(self) -> float:
        """Hits over *offered* load: shed requests count as misses."""
        offered = self.n_injected if self.n_injected else self.completed
        return self.slo_hits / offered if offered else 0.0

    def cost_per_1k(self) -> float:
        done = self.completed
        return self.total_cost / done * 1000.0 if done else 0.0

    def utilization(self) -> float:
        return (self.gpu_busy_ms / self.gpu_capacity_ms
                if self.gpu_capacity_ms else 0.0)

    def summary(self) -> dict[str, Any]:
        return {
            "scheduler": self.scheduler,
            "autoscaler": self.autoscaler,
            "scenario": self.scenario,
            "injected": self.n_injected,
            "admitted": self.n_admitted,
            "shed": self.n_shed,
            "completed": self.completed,
            "slo_attainment": self.slo_attainment(),
            "cost_per_1k": self.cost_per_1k(),
            "total_cost": self.total_cost,
            "cold_starts": self.cold_starts,
            "utilization": self.utilization(),
            "shed_true": self.shed_true,
            "shed_false": self.shed_false,
            "shed_unknown": self.shed_unknown,
            "shed_precision": self.shed_precision(),
            "prefetch_hit_rate": self.prefetch_hit_rate(),
            "penalty_hidden_frac": self.penalty_hidden_frac(),
            "predicted_vs_realized": dict(self.predicted_vs_realized),
            "calibration": dict(self.calibration),
            "health": dict(self.health),
            "profile_provenance": dict(self.profile_provenance),
            "gpu": dict(self.gpu),
            "latency": self.e2e.to_dict(),
            "per_stage": {
                f"{app}/{stage}": {
                    "tasks": st.tasks, "jobs": st.jobs, "cold": st.cold,
                    "queue": st.queue.to_dict(), "exec": st.exec.to_dict(),
                }
                for (app, stage), st in sorted(self.stage.items())
            },
            "per_app": {
                app: {"injected": self.injected[app],
                      "admitted": self.admitted[app],
                      "shed": self.shed[app]}
                for app in sorted(set(self.injected) | set(self.admitted)
                                  | set(self.shed))
            },
        }


TABLE_COLS = [
    ("scenario", "scenario", "{}"),
    ("scheduler", "sched", "{}"),
    ("autoscaler", "scaler", "{}"),
    ("slo_attainment", "slo%", "{:.1%}"),
    ("cost_per_1k", "$/1k", "{:.4f}"),
    ("cold_starts", "cold", "{}"),
    ("shed", "shed", "{}"),
    ("completed", "done", "{}"),
    ("utilization", "util", "{:.1%}"),
    ("p95_ms", "p95_ms", "{:.0f}"),
]


def format_table(rows: list[dict[str, Any]],
                 extra_cols: Optional[list[tuple[str, str, str]]] = None) -> str:
    """Render summary dicts (see Telemetry.summary) as an aligned table."""
    cols = TABLE_COLS + (extra_cols or [])
    cells = [[hdr for _, hdr, _ in cols]]
    for r in rows:
        lat = r.get("latency") or {}
        flat = {**r, "p95_ms": lat.get("p95_ms", "")}
        row = []
        for key, _, fmt in cols:
            v = flat.get(key, "")
            # None metrics (e.g. shed_precision / prefetch_hit_rate with
            # nothing scorable) render as '-', same as missing keys —
            # "{:.1%}".format(None) would raise
            row.append(fmt.format(v) if v != "" and v is not None else "-")
        cells.append(row)
    widths = [max(len(c[i]) for c in cells) for i in range(len(cols))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths))
             for row in cells]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)
