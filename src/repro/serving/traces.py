"""Trace-driven scenario engine (online-serving workloads).

Generalises ``cluster/workload.py`` beyond the paper's three
uniform-interval settings into a scenario library.  Every scenario is a
deterministic function of its seed: ``arrivals(app_names, n, seed)``
returns the same timestamped request stream on every call, so benchmark
sweeps and tests are exactly reproducible.

Catalogue (``SCENARIOS``):
  * ``uniform-{light,normal,heavy}`` — the paper's §4.1 Azure-derived
    uniform inter-arrival ranges (back-compat with ``workload.generate``).
  * ``diurnal``     — sinusoid-modulated Poisson process (day/night swing).
  * ``mmpp``        — 2-state Markov-modulated Poisson process (bursty
    traffic: quiet state / burst state with geometric dwell times).
  * ``flash-crowd`` — steady Poisson load with a sudden multi-x spike
    window (news-event traffic).
  * ``azure-tail``  — heavy-tailed (Lomax/Pareto-II) inter-arrivals, the
    shape reported for Azure Functions production traces.
  * ``skewed-mix``  — uniform arrivals but an 80/20 per-app traffic mix.
  * ``trace-replay`` — replay a recorded ``(t_ms, app)`` CSV (real
    Azure/production traces; see ``benchmarks/traces/``).
  * ``spot-storm``  — steady Poisson arrivals plus supply-side
    reclamation-storm windows for preemptible fleets.
  * ``hetero-mix``  — MMPP bursts aimed at a mixed-SKU fleet.

Add a scenario by subclassing ``Scenario`` (override ``_interval``, or
``arrivals`` for non-generative sources) and registering a factory in
``SCENARIOS``.
"""
from __future__ import annotations

import dataclasses
import gzip
import math
import zlib
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.cluster.workload import INTERVALS_MS


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of the injected trace."""
    uid: int
    t_ms: float
    app: str


class Scenario:
    """Base scenario: i.i.d. or state-dependent inter-arrival generator.

    ``app_weights`` maps app name -> relative traffic share (unknown apps
    are ignored, missing apps get weight 0 if any weight is given,
    otherwise the mix is uniform).
    """
    name = "base"

    def __init__(self, app_weights: Optional[dict[str, float]] = None):
        self.app_weights = app_weights

    # ---- subclass hooks ---------------------------------------------------
    def _reset(self, rng: np.random.Generator, n: int):
        """Called once per trace before interval generation."""

    def _interval(self, rng: np.random.Generator, i: int, t_ms: float) -> float:
        """Inter-arrival gap (ms) before request ``i`` at current time."""
        raise NotImplementedError

    # ---- public API -------------------------------------------------------
    def iter_arrivals(self, app_names: Sequence[str], n: int,
                      seed: int = 0):
        """Lazy generator form of ``arrivals`` — the identical sequence,
        one ``Arrival`` at a time (the day-scale streaming path: feed it
        to ``ClusterSim.add_arrival_stream`` and no arrival list is ever
        materialized)."""
        rng = np.random.default_rng(seed)
        self._reset(rng, n)
        probs = self._mix(app_names)
        t = 0.0
        for uid in range(n):
            t += max(float(self._interval(rng, uid, t)), 1e-6)
            app = app_names[int(rng.choice(len(app_names), p=probs))]
            yield Arrival(uid, t, app)

    def arrivals(self, app_names: Sequence[str], n: int,
                 seed: int = 0) -> list[Arrival]:
        return list(self.iter_arrivals(app_names, n, seed))

    def _mix(self, app_names: Sequence[str]) -> np.ndarray:
        if not self.app_weights:
            return np.full(len(app_names), 1.0 / len(app_names))
        w = np.array([max(float(self.app_weights.get(a, 0.0)), 0.0)
                      for a in app_names])
        if w.sum() <= 0:
            return np.full(len(app_names), 1.0 / len(app_names))
        return w / w.sum()


class UniformScenario(Scenario):
    """The paper's uniform-interval setting (workload.py semantics)."""
    name = "uniform"

    def __init__(self, lo_ms: float, hi_ms: float, **kw):
        super().__init__(**kw)
        self.lo_ms, self.hi_ms = lo_ms, hi_ms

    def _interval(self, rng, i, t_ms):
        return rng.uniform(self.lo_ms, self.hi_ms)


class DiurnalScenario(Scenario):
    """Poisson arrivals whose rate follows a sinusoid (diurnal swing).

    rate(t) = (1/mean_interval) * (1 + amplitude * sin(2*pi*t/period)),
    sampled via per-arrival exponential gaps at the current rate (a
    piecewise approximation of inhomogeneous-Poisson thinning that keeps
    generation O(n) and exactly seeded).
    """
    name = "diurnal"

    def __init__(self, mean_interval_ms: float = 30.0, amplitude: float = 0.8,
                 period_ms: float = 20_000.0, **kw):
        super().__init__(**kw)
        assert 0.0 <= amplitude < 1.0
        self.mean_interval_ms = mean_interval_ms
        self.amplitude = amplitude
        self.period_ms = period_ms

    def _interval(self, rng, i, t_ms):
        rate = (1.0 / self.mean_interval_ms) * (
            1.0 + self.amplitude * math.sin(2 * math.pi * t_ms / self.period_ms))
        return rng.exponential(1.0 / max(rate, 1e-9))


class MMPPScenario(Scenario):
    """2-state Markov-modulated Poisson process (quiet / burst).

    Dwell times are geometric in arrival counts: after each arrival the
    chain flips state with probability ``p_switch``.  The burst state runs
    ``burst_factor`` x the quiet rate, producing the clustered arrivals
    uniform settings cannot express.
    """
    name = "mmpp"

    def __init__(self, mean_interval_ms: float = 30.0,
                 burst_factor: float = 8.0, p_switch: float = 0.05, **kw):
        super().__init__(**kw)
        self.mean_interval_ms = mean_interval_ms
        self.burst_factor = burst_factor
        self.p_switch = p_switch
        self._state = 0

    def _reset(self, rng, n):
        self._state = 0

    def _interval(self, rng, i, t_ms):
        if rng.random() < self.p_switch:
            self._state = 1 - self._state
        mean = self.mean_interval_ms
        if self._state:
            mean = mean / self.burst_factor
        return rng.exponential(mean)


class FlashCrowdScenario(Scenario):
    """Steady Poisson load with one ``spike_mult``-x spike window.

    The spike covers arrivals in ``[spike_start_frac, spike_end_frac) * n``
    (index space so the spike always materialises regardless of n).
    """
    name = "flash-crowd"

    def __init__(self, mean_interval_ms: float = 40.0, spike_mult: float = 10.0,
                 spike_start_frac: float = 0.4, spike_end_frac: float = 0.6,
                 **kw):
        super().__init__(**kw)
        self.mean_interval_ms = mean_interval_ms
        self.spike_mult = spike_mult
        self.spike_start_frac = spike_start_frac
        self.spike_end_frac = spike_end_frac
        self._n = 0

    def _reset(self, rng, n):
        self._n = n

    def in_spike(self, i: int) -> bool:
        return (self.spike_start_frac * self._n <= i
                < self.spike_end_frac * self._n)

    def _interval(self, rng, i, t_ms):
        mean = self.mean_interval_ms
        if self.in_spike(i):
            mean = mean / self.spike_mult
        return rng.exponential(mean)


class HeavyTailScenario(Scenario):
    """Heavy-tailed (Lomax / Pareto-II) inter-arrivals, Azure-trace-like.

    ``alpha`` is the tail index (smaller = heavier tail; must be > 1 so the
    mean exists).  Scale is chosen so the mean inter-arrival equals
    ``mean_interval_ms``: mean = scale / (alpha - 1).
    """
    name = "azure-tail"

    def __init__(self, mean_interval_ms: float = 30.0, alpha: float = 1.5,
                 **kw):
        super().__init__(**kw)
        assert alpha > 1.0
        self.mean_interval_ms = mean_interval_ms
        self.alpha = alpha

    def _interval(self, rng, i, t_ms):
        scale = self.mean_interval_ms * (self.alpha - 1.0)
        return float(rng.pareto(self.alpha)) * scale


class TraceReplayScenario(Scenario):
    """Replay a recorded request trace of ``(t_ms, app)`` rows — the hook
    for injecting real Azure/production traces instead of synthetic
    processes.

    Sources (first match wins): ``rows`` (any iterable of ``(t_ms, app)``
    pairs, consumed once — generators welcome), ``csv_path`` (CSV with a
    ``t_ms,app`` header, as shipped under ``benchmarks/traces/``,
    streamed lazily via ``iter_csv``), else a small built-in bursty
    sample so the scenario is usable straight from the catalogue.

    Semantics:
      * rows are sorted by time; ``time_scale`` stretches/compresses the
        clock (2.0 = half the request rate) and ``speedup`` divides it
        (10.0 = replay a long trace 10x faster — the knob that fits the
        hour-scale Azure traces into smoke-test budgets);
      * an ``app`` name not in ``app_names`` (e.g. a hashed production
        function id, or the ``*`` wildcard) is remapped deterministically
        (crc32 of ``name/uid``) onto ``app_names`` — seeds do not change
        a replay, by design;
      * when ``n`` exceeds the trace length the trace wraps, shifted by
        one trace-period per lap (diurnal traces repeat day over day);
      * timestamps are forced strictly increasing and positive.
    """
    name = "trace-replay"

    def __init__(self, csv_path: Optional[str] = None,
                 rows: Optional[Iterable[tuple[float, str]]] = None,
                 time_scale: float = 1.0, speedup: float = 1.0,
                 presorted: bool = False, **kw):
        super().__init__(**kw)
        if not speedup > 0.0:          # also rejects NaN
            raise ValueError(
                f"trace-replay: speedup must be > 0 (it divides the "
                f"trace clock; 10.0 replays 10x faster), got {speedup!r}")
        self.csv_path = csv_path
        # presorted + csv_path: never materialize — each arrivals() lap
        # streams the file from disk (the day-scale path; the file must
        # already be in ``sorted((t_ms, app))`` order, as
        # ``convert_azure.py`` emits, and may be gzip-compressed)
        self.presorted = bool(presorted and csv_path is not None
                              and rows is None)
        if self.presorted:
            self.rows = None
        else:
            if rows is None and csv_path is not None:
                rows = self.iter_csv(csv_path)
            if rows is None:
                rows = DEFAULT_TRACE_ROWS
            # ``rows`` may be any iterable (including the lazy CSV
            # reader): it is consumed exactly once, straight into the
            # sorted trace
            self.rows = sorted((float(t), str(app)) for t, app in rows)
            if not self.rows:
                raise ValueError("trace-replay: empty trace")
        self.speedup = speedup
        self.time_scale = time_scale / speedup

    @staticmethod
    def iter_csv(path: str):
        """Stream a ``t_ms,app`` CSV (header required, extra cols
        ignored), yielding one ``(t_ms, app)`` tuple per row.

        Rows are parsed lazily — hour-long Azure traces never hold the
        file or a per-row dict list in memory beyond the single tuple
        list the caller builds.  Blank and whitespace-only rows — the
        trailing newline junk real trace exports ship with — are
        skipped; a row missing either value, or with an unparsable
        ``t_ms``, raises a ``ValueError`` naming the file and line
        instead of a bare ``KeyError``."""
        import csv as _csv
        opener = gzip.open if str(path).endswith(".gz") else open
        with opener(path, "rt", newline="") as f:
            reader = _csv.DictReader(f)
            if reader.fieldnames is None or \
                    not {"t_ms", "app"} <= set(reader.fieldnames):
                raise ValueError(
                    f"{path}: trace CSV needs a 't_ms,app' header "
                    f"(extra columns are ignored), got {reader.fieldnames}")
            for r in reader:
                cells = [v for v in r.values() if v is not None]
                if all(not str(v).strip() for v in cells):
                    continue                       # blank/trailing line
                t_raw, app = r.get("t_ms"), r.get("app")
                if t_raw is None or not t_raw.strip() or \
                        app is None or not app.strip():
                    raise ValueError(
                        f"{path} line {reader.line_num}: row needs both "
                        f"'t_ms' and 'app' values, got {dict(r)!r}")
                try:
                    t = float(t_raw)
                except ValueError:
                    raise ValueError(
                        f"{path} line {reader.line_num}: t_ms must be a "
                        f"number, got {t_raw!r}") from None
                yield (t, app.strip())

    @staticmethod
    def read_csv(path: str) -> list[tuple[float, str]]:
        """Materialized form of ``iter_csv`` (back-compat helper)."""
        return list(TraceReplayScenario.iter_csv(path))

    def _lap_rows(self):
        """One pass over the trace: the materialized sorted rows, or —
        presorted streaming mode — a fresh lazy read of the CSV."""
        if self.rows is not None:
            return iter(self.rows)
        return self.iter_csv(self.csv_path)

    def iter_arrivals(self, app_names: Sequence[str], n: int,
                      seed: int = 0):
        """Identical to the materialized replay (seeds never matter, by
        design); in presorted mode the file is re-read per wrap lap and
        the wrap period falls out of lap 0's last row/count — exactly
        the ``rows[-1]``/``len(rows)`` the materialized path uses."""
        known = set(app_names)
        t_prev = 0.0
        uid = 0
        lap = 0
        span = 0.0                     # unused on lap 0
        while uid < n:
            count = 0
            prev_raw = -math.inf
            last_raw = 0.0
            for t_raw, app in self._lap_rows():
                if t_raw < prev_raw:
                    raise ValueError(
                        f"{self.csv_path}: presorted trace is not "
                        f"time-sorted (t_ms={t_raw} after {prev_raw})")
                prev_raw = last_raw = t_raw
                count += 1
                t = (t_raw + lap * span) * self.time_scale
                t = max(t, t_prev + 1e-6)             # strictly increasing
                t_prev = t
                if app not in known:
                    app = app_names[zlib.crc32(f"{app}/{uid}".encode())
                                    % len(app_names)]
                yield Arrival(uid, t, app)
                uid += 1
                if uid >= n:
                    return
            if count == 0:
                raise ValueError("trace-replay: empty trace")
            if lap == 0:
                span = last_raw + max(last_raw / count, 1.0)  # wrap period
            lap += 1


class SpotStormScenario(Scenario):
    """Steady exponential arrivals for preemptible-fleet stress tests.

    The arrival process itself is plain Poisson — the *storms* are on the
    supply side: ``storm_windows(horizon_ms)`` returns ``(t0, t1, mult)``
    windows during which spot reclamation rates should be multiplied
    (feed them to ``ClusterSim(reclaim_storms=...)``).  Two storms cover
    the middle of the horizon so retries, migration and backoff all get
    exercised while load is still arriving.  ``suggested_fleet(n)``
    mixes on-demand anchors with spot capacity (2 on-demand : 1 spot).
    """
    name = "spot-storm"

    def __init__(self, mean_interval_ms: float = 35.0,
                 storm_mult: float = 6.0, **kw):
        super().__init__(**kw)
        self.mean_interval_ms = mean_interval_ms
        self.storm_mult = storm_mult

    def _interval(self, rng, i, t_ms):
        return rng.exponential(self.mean_interval_ms)

    def storm_windows(self, horizon_ms: float) -> list[tuple[float, float, float]]:
        """Two reclamation storms in the middle half of the horizon."""
        return [
            (0.25 * horizon_ms, 0.40 * horizon_ms, self.storm_mult),
            (0.60 * horizon_ms, 0.75 * horizon_ms, self.storm_mult),
        ]

    @staticmethod
    def suggested_fleet(n_invokers: int) -> list[str]:
        """2 on-demand : 1 spot round-robin mix."""
        cycle = ("a100", "a100", "a100-spot")
        return [cycle[i % len(cycle)] for i in range(n_invokers)]


class HeteroMixScenario(MMPPScenario):
    """Bursty (MMPP) traffic aimed at a heterogeneous SKU mix.

    Arrival-side it is the 2-state MMPP process; the point of the
    scenario is ``suggested_fleet(n)``: a rotation over the whole SKU
    catalogue (fast H100s, baseline A100s, and the cheap spot tiers) so
    SKU-aware pricing, warm-up-from-zero and exec-rate scaling all see
    traffic in one run.
    """
    name = "hetero-mix"

    def __init__(self, mean_interval_ms: float = 35.0,
                 burst_factor: float = 6.0, p_switch: float = 0.04, **kw):
        super().__init__(mean_interval_ms=mean_interval_ms,
                         burst_factor=burst_factor, p_switch=p_switch, **kw)

    @staticmethod
    def suggested_fleet(n_invokers: int) -> list[str]:
        cycle = ("a100", "h100", "a100-spot", "a100", "a10g-spot")
        return [cycle[i % len(cycle)] for i in range(n_invokers)]


# Built-in sample: a quiet->burst->quiet day fragment (wildcard apps are
# remapped onto whatever app set the run serves).
DEFAULT_TRACE_ROWS: list[tuple[float, str]] = [
    (float(t), "*") for t in
    list(range(40, 2000, 70)) +          # quiet: ~14 req/s-equivalent spacing
    list(range(2000, 2600, 12)) +        # burst window: ~6x denser
    list(range(2600, 4600, 55))          # recovery
]


def _uniform_factory(load: str) -> Callable[..., Scenario]:
    lo, hi = INTERVALS_MS[load]
    return lambda **kw: UniformScenario(lo, hi, **kw)


SCENARIOS: dict[str, Callable[..., Scenario]] = {
    "uniform-light": _uniform_factory("light"),
    "uniform-normal": _uniform_factory("normal"),
    "uniform-heavy": _uniform_factory("heavy"),
    "diurnal": DiurnalScenario,
    "mmpp": MMPPScenario,
    "flash-crowd": FlashCrowdScenario,
    "azure-tail": HeavyTailScenario,
    "skewed-mix": lambda **kw: UniformScenario(
        20.0, 33.6, **{"app_weights": None, **kw}),
    "trace-replay": TraceReplayScenario,
    "spot-storm": SpotStormScenario,
    "hetero-mix": HeteroMixScenario,
}


def get_scenario(name: str, app_names: Optional[Sequence[str]] = None,
                 **overrides) -> Scenario:
    """Build a scenario by catalogue name.

    ``skewed-mix`` derives an 80/20 split over ``app_names`` when no
    explicit ``app_weights`` override is given.
    """
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    if name == "skewed-mix" and "app_weights" not in overrides and app_names:
        hot, rest = app_names[0], app_names[1:]
        weights = {hot: 0.8}
        for a in rest:
            weights[a] = 0.2 / max(len(rest), 1)
        overrides["app_weights"] = weights
    return SCENARIOS[name](**overrides)
