"""RWKV6 (Finch) 1.6B [arXiv:2404.05892] — attention-free, data-dependent decay."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1_6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/64 (WKV heads)
    d_head=64, d_ff=7168, vocab=65536,
    norm="layernorm", mlp="gelu",  # channel-mix uses squared relu; flag unused
    ssm_state=64,
    source="arXiv:2404.05892",
)
