"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-*] — MoE 128e top-1,
chunked-local attention (8192) with 1-in-4 global layers."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b_a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    norm="rmsnorm", mlp="swiglu", rope_theta=5e5,
    n_experts=128, top_k=1, capacity_factor=1.25,
    chunk_attn=8192, global_every=4,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (config family)",
)
