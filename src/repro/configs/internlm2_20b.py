"""InternLM2-20B [arXiv:2403.17297; hf] — dense GQA decoder."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e6,
    source="arXiv:2403.17297; hf",
)
