"""Mixtral 8x22B [arXiv:2401.04088] — 8 experts top-2, sliding-window attention."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e6,
    n_experts=8, top_k=2, capacity_factor=1.25,
    window=4096,
    source="arXiv:2401.04088",
)
