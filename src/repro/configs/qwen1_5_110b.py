"""Qwen1.5-110B [hf:Qwen/Qwen1.5-*] — dense GQA decoder with QKV bias."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab=152064,
    norm="rmsnorm", mlp="swiglu", qkv_bias=True, rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-110B",
)
