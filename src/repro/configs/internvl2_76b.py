"""InternVL2-76B [arXiv:2404.16821] — InternViT frontend (stub) + 70B-class LM backbone."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256,
    norm="rmsnorm", mlp="swiglu", rope_theta=5e5,
    frontend="vit", n_prefix=256,
    source="arXiv:2404.16821",
)
