"""Hymba-1.5B [arXiv:2411.13676] — parallel attention + mamba heads, SWA."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1_5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_head=64, d_ff=5504, vocab=32001,
    norm="rmsnorm", mlp="swiglu", rope_theta=1e4,
    window=1024, ssm_state=16,
    source="arXiv:2411.13676",
)
