"""StarCoder2-7B [arXiv:2402.19173; hf] — GQA, RoPE, GELU MLP, LayerNorm."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152,
    norm="layernorm", mlp="gelu", qkv_bias=True, rope_theta=1e5,
    source="arXiv:2402.19173; hf",
)
