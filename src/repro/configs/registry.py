"""Architecture + shape registry.

Every assigned architecture lives in ``src/repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig``.  ``get_config(name)`` returns it; ``reduced(cfg)``
shrinks it for CPU smoke tests (same family / code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = [
    "internlm2_20b",
    "qwen1_5_110b",
    "internlm2_1_8b",
    "starcoder2_7b",
    "rwkv6_1_6b",
    "llama4_maverick_400b_a17b",
    "mixtral_8x22b",
    "internvl2_76b",
    "musicgen_medium",
    "hymba_1_5b",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | moe | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # derived if 0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # --- attention flavour ---
    window: Optional[int] = None     # sliding-window size (None = full causal)
    chunk_attn: Optional[int] = None # llama4 chunked-local attention size
    global_every: Optional[int] = None  # 1-in-N layers use full attention
    # --- MoE ---
    n_experts: Optional[int] = None
    top_k: int = 1
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    # --- modality frontend stub ---
    frontend: Optional[str] = None   # vit | encodec | None
    n_prefix: int = 0                # prefix embeddings provided by frontend stub
    source: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """True if decode state is o(context): SSM, SWA or chunked attention."""
        if self.family == "ssm":
            return True
        return self.window is not None or self.chunk_attn is not None

    @property
    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS roofline term)."""
        return _count_params(self, active_only=False)

    @property
    def n_active_params(self) -> int:
        return _count_params(self, active_only=True)


def _count_params(c: ModelConfig, active_only: bool) -> int:
    d, f, L = c.d_model, c.d_ff, c.n_layers
    h, kv, dh = c.n_heads, c.n_kv_heads, c.d_head
    embed = c.vocab * d * (1 if c.tie_embeddings else 2)
    if c.family == "ssm":
        # RWKV6: time-mix (r,k,v,g,o ~ 5 d^2 + lora) + channel-mix (2 d*f)
        per_layer = 5 * d * d + 2 * d * f + 6 * d * 96
        return embed + L * per_layer
    attn = d * (h * dh) * 2 + d * (kv * dh) * 2
    if c.mlp == "swiglu":
        ffn = 3 * d * f
    else:
        ffn = 2 * d * f
    if c.n_experts:
        n_e = (c.top_k if active_only else c.n_experts)
        ffn = ffn * n_e
    per_layer = attn + ffn
    if c.family == "hybrid":
        per_layer += d * (2 * c.ssm_state + 2 * d)  # parallel SSM head branch
    return embed + L * per_layer


# ---------------------------------------------------------------------------
# Shapes (assigned: same 4 for every LM arch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch x shape) is a runnable dry-run cell (per spec)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN §4)"
    return True, ""


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab=256,
        window=min(cfg.window, 32) if cfg.window else None,
        chunk_attn=min(cfg.chunk_attn, 32) if cfg.chunk_attn else None,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else None,
        capacity_factor=4.0 if cfg.n_experts else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        n_prefix=min(cfg.n_prefix, 8) if cfg.n_prefix else 0,
    )
