"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens (frontend stub)."""
from repro.configs.registry import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,  # MHA
    d_head=64, d_ff=6144, vocab=2048,
    norm="layernorm", mlp="gelu", rope_theta=1e4,
    frontend="encodec", n_prefix=0,
    source="arXiv:2306.05284",
)
