"""Model-state footprints + host<->HBM swap timing (Torpor/FaaSwap model).

Torpor (arXiv 2306.03622) observes that for GPU serverless the dominant
"cold start" component is loading model weights into device memory, and
that keeping weights in *host* RAM and swapping them in over PCIe on
demand is an order of magnitude cheaper than a full container cold start.
This module provides that middle tier's cost model:

  * ``swap_in_ms(model_mb)`` — host -> HBM transfer time for a model
    checkpoint of ``model_mb`` megabytes at PCIe-class bandwidth plus a
    fixed allocator/stream-setup charge;
  * per-function weight footprints for the paper's six image functions
    (plausible fp16 checkpoint sizes; the zoo derives its own from the
    parameter counts, see ``cluster/tpu_profiles.py``).

The three warm tiers the device model distinguishes:

  hot   weights resident in HBM          -> restart penalty 0
  warm  weights in host RAM              -> restart penalty swap_in_ms
  cold  nothing anywhere                 -> restart penalty profile.cold_ms

``tier_penalty_ms`` maps a tier to that restart penalty and is the single
source of truth shared by the device model (``swap_cost_ms`` queries), the
emulator's dispatch accounting and the memory-aware placement ranking.

Heterogeneous fleets add one more dimension: a ``GpuSKU`` describes a
device class (exec-rate multiplier, HBM capacity, host->HBM bandwidth,
$/slice-hour price factor, warm-up-from-zero latency) plus the spot
contract — preemptible capacity with a seeded reclamation process whose
mean inter-reclaim gap, warning lead and recovery outage live here too.
``DEFAULT_SKU`` is neutral on every axis so a homogeneous fleet built
from it is bit-identical to the pre-SKU emulator.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

# Warm-state tiers (defined here, below the device model, so the cost
# helpers need no import from ``device`` — re-exported there).
HOT = "hot"      # weights resident in HBM
WARM = "warm"    # weights in host RAM (swap-in penalty on start)
COLD = "cold"    # no container anywhere (full cold start)

# Host -> device effective bandwidth.  PCIe 4.0 x16 peaks at 32 GB/s; real
# pinned-memory H2D copies sustain roughly half (Torpor reports ~1.5 s for
# multi-GB LLMs, consistent with this figure).  1 GB/s == 1 MB/ms.
H2D_GBPS = 16.0
# Fixed per-swap charge: device allocator + stream setup + cudnn/XLA
# re-binding of the resident executable to the new weight buffers.
SWAP_FIXED_MS = 5.0


def swap_in_ms(model_mb: float, gbps: float = H2D_GBPS) -> float:
    """Host->HBM restart penalty for a ``model_mb``-MB checkpoint.

    ``gbps`` lets per-SKU PCIe/NVLink bandwidth override the default
    PCIe-4.0 figure (1 GB/s == 1 MB/ms)."""
    if model_mb <= 0.0:
        return 0.0
    return SWAP_FIXED_MS + model_mb / gbps


def cold_components(model_mb: float,
                    cold_ms: Optional[float] = None,
                    gbps: float = H2D_GBPS) -> tuple[float, float]:
    """Split a full cold start into ``(provision_ms, weight_ms)``.

    ``weight_ms`` is the host->HBM checkpoint copy (the part a PCIe
    transfer engine can overlap or prefetch); ``provision_ms`` is the
    container/runtime setup that stays CPU-side.  The weight component
    is clamped to ``cold_ms`` — it is *part* of the measured cold start,
    never more than it — so ``provision + weight == cold_ms`` exactly
    (or ``(0, swap_in_ms)`` when no cold figure is known, matching the
    ``tier_penalty_ms`` lower-bound convention)."""
    weight = swap_in_ms(model_mb, gbps)
    if cold_ms is None:
        return 0.0, weight
    weight = min(weight, max(cold_ms, 0.0))
    return max(cold_ms - weight, 0.0), weight


def tier_penalty_ms(tier: str, model_mb: float,
                    cold_ms: Optional[float] = None,
                    gbps: float = H2D_GBPS) -> float:
    """Restart penalty a container pays when its warm state is ``tier``.

    ``cold_ms`` is the function's full cold-start time (container
    provisioning + weight load); when the caller cannot supply it the
    weight-load component alone is returned as an admissible lower
    bound (that keeps planners that price this penalty optimistic,
    never pessimistic).
    """
    if tier == HOT:
        return 0.0
    if tier == WARM:
        return swap_in_ms(model_mb, gbps)
    return cold_ms if cold_ms is not None else swap_in_ms(model_mb, gbps)


# ---------------------------------------------------------------------------
# Heterogeneous / preemptible fleet SKUs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GpuSKU:
    """One device class in a heterogeneous (possibly preemptible) fleet.

    Every field defaults to the neutral value the homogeneous emulator
    implicitly assumed, so ``DEFAULT_SKU`` leaves each code path
    arithmetically untouched (x * 1.0 is exact in IEEE-754):

      exec_rate        throughput multiplier vs the profiled baseline
                       device; exec time is divided by it
      hbm_per_vgpu_mb  HBM capacity per vGPU (None => the sim-level
                       ``hbm_per_vgpu_mb`` argument / unbounded)
      h2d_gbps         host->HBM bandwidth for swap-in / checkpoint
                       restore (PCIe or NVLink class)
      price_factor     multiplier on the vGPU component of $/slice-hour
                       (spot discounts < 1, premium parts > 1)
      warmup_ms        warm-up-from-zero: extra latency on the first
                       dispatch to a completely empty device (driver/
                       MIG partition bring-up)
      spot             preemptible capacity; reclamations are drawn from
                       a seeded exponential process with mean gap
                       ``reclaim_mean_s`` (scaled down inside storm
                       windows), announce themselves ``warn_ms`` ahead,
                       and take the device down for ``recover_ms``
    """
    name: str = "a100"
    exec_rate: float = 1.0
    hbm_per_vgpu_mb: Optional[float] = None
    h2d_gbps: float = H2D_GBPS
    price_factor: float = 1.0
    warmup_ms: float = 0.0
    spot: bool = False
    reclaim_mean_s: float = 0.0
    warn_ms: float = 2_000.0
    recover_ms: float = 8_000.0


DEFAULT_SKU = GpuSKU()

# Catalogue of plausible classes: exec rates are rough relative inference
# throughputs, price factors track on-demand vs spot market ratios.  The
# "a100" entry IS the neutral default — fleets spelled ["a100"] * n stay
# bit-identical to the homogeneous emulator.
SKU_CATALOG: dict[str, GpuSKU] = {
    "a100": DEFAULT_SKU,
    "h100": GpuSKU(name="h100", exec_rate=1.6, h2d_gbps=24.0,
                   price_factor=1.7, warmup_ms=150.0),
    "a100-spot": GpuSKU(name="a100-spot", price_factor=0.4, spot=True,
                        reclaim_mean_s=240.0),
    "a10g-spot": GpuSKU(name="a10g-spot", exec_rate=0.45,
                        hbm_per_vgpu_mb=6_000.0, h2d_gbps=8.0,
                        price_factor=0.22, warmup_ms=80.0, spot=True,
                        reclaim_mean_s=180.0),
    "t4-spot": GpuSKU(name="t4-spot", exec_rate=0.25,
                      hbm_per_vgpu_mb=4_000.0, h2d_gbps=6.0,
                      price_factor=0.12, warmup_ms=60.0, spot=True,
                      reclaim_mean_s=150.0),
}


def resolve_sku(sku: Union[str, GpuSKU, None]) -> GpuSKU:
    """Accept a catalogue name, a ``GpuSKU``, or None (=> default)."""
    if sku is None:
        return DEFAULT_SKU
    if isinstance(sku, GpuSKU):
        return sku
    try:
        return SKU_CATALOG[sku]
    except KeyError:
        raise KeyError(f"unknown GPU SKU {sku!r} "
                       f"(known: {sorted(SKU_CATALOG)})") from None


# fp16 checkpoint sizes (MB) for the paper's Table-3 image functions —
# typical published checkpoints for each task class (EDSR-class SR,
# DeepLab-class segmentation, DeblurGAN-class deblurring, ResNet-152-class
# classification, U^2-Net-class matting, MiDaS-large-class depth).
PAPER_MODEL_MB = {
    "super_resolution": 170.0,
    "segmentation": 460.0,
    "deblur": 380.0,
    "classification": 230.0,
    "background_removal": 680.0,
    "depth": 530.0,
}
