"""Model-state footprints + host<->HBM swap timing (Torpor/FaaSwap model).

Torpor (arXiv 2306.03622) observes that for GPU serverless the dominant
"cold start" component is loading model weights into device memory, and
that keeping weights in *host* RAM and swapping them in over PCIe on
demand is an order of magnitude cheaper than a full container cold start.
This module provides that middle tier's cost model:

  * ``swap_in_ms(model_mb)`` — host -> HBM transfer time for a model
    checkpoint of ``model_mb`` megabytes at PCIe-class bandwidth plus a
    fixed allocator/stream-setup charge;
  * per-function weight footprints for the paper's six image functions
    (plausible fp16 checkpoint sizes; the zoo derives its own from the
    parameter counts, see ``cluster/tpu_profiles.py``).

The three warm tiers the device model distinguishes:

  hot   weights resident in HBM          -> restart penalty 0
  warm  weights in host RAM              -> restart penalty swap_in_ms
  cold  nothing anywhere                 -> restart penalty profile.cold_ms

``tier_penalty_ms`` maps a tier to that restart penalty and is the single
source of truth shared by the device model (``swap_cost_ms`` queries), the
emulator's dispatch accounting and the memory-aware placement ranking.
"""
from __future__ import annotations

from typing import Optional

# Warm-state tiers (defined here, below the device model, so the cost
# helpers need no import from ``device`` — re-exported there).
HOT = "hot"      # weights resident in HBM
WARM = "warm"    # weights in host RAM (swap-in penalty on start)
COLD = "cold"    # no container anywhere (full cold start)

# Host -> device effective bandwidth.  PCIe 4.0 x16 peaks at 32 GB/s; real
# pinned-memory H2D copies sustain roughly half (Torpor reports ~1.5 s for
# multi-GB LLMs, consistent with this figure).  1 GB/s == 1 MB/ms.
H2D_GBPS = 16.0
# Fixed per-swap charge: device allocator + stream setup + cudnn/XLA
# re-binding of the resident executable to the new weight buffers.
SWAP_FIXED_MS = 5.0


def swap_in_ms(model_mb: float) -> float:
    """Host->HBM restart penalty for a ``model_mb``-MB checkpoint."""
    if model_mb <= 0.0:
        return 0.0
    return SWAP_FIXED_MS + model_mb / H2D_GBPS


def cold_components(model_mb: float,
                    cold_ms: Optional[float] = None) -> tuple[float, float]:
    """Split a full cold start into ``(provision_ms, weight_ms)``.

    ``weight_ms`` is the host->HBM checkpoint copy (the part a PCIe
    transfer engine can overlap or prefetch); ``provision_ms`` is the
    container/runtime setup that stays CPU-side.  The weight component
    is clamped to ``cold_ms`` — it is *part* of the measured cold start,
    never more than it — so ``provision + weight == cold_ms`` exactly
    (or ``(0, swap_in_ms)`` when no cold figure is known, matching the
    ``tier_penalty_ms`` lower-bound convention)."""
    weight = swap_in_ms(model_mb)
    if cold_ms is None:
        return 0.0, weight
    weight = min(weight, max(cold_ms, 0.0))
    return max(cold_ms - weight, 0.0), weight


def tier_penalty_ms(tier: str, model_mb: float,
                    cold_ms: Optional[float] = None) -> float:
    """Restart penalty a container pays when its warm state is ``tier``.

    ``cold_ms`` is the function's full cold-start time (container
    provisioning + weight load); when the caller cannot supply it the
    weight-load component alone is returned as an admissible lower
    bound (that keeps planners that price this penalty optimistic,
    never pessimistic).
    """
    if tier == HOT:
        return 0.0
    if tier == WARM:
        return swap_in_ms(model_mb)
    return cold_ms if cold_ms is not None else swap_in_ms(model_mb)


# fp16 checkpoint sizes (MB) for the paper's Table-3 image functions —
# typical published checkpoints for each task class (EDSR-class SR,
# DeepLab-class segmentation, DeblurGAN-class deblurring, ResNet-152-class
# classification, U^2-Net-class matting, MiDaS-large-class depth).
PAPER_MODEL_MB = {
    "super_resolution": 170.0,
    "segmentation": 460.0,
    "deblur": 380.0,
    "classification": 230.0,
    "background_removal": 680.0,
    "depth": 530.0,
}
