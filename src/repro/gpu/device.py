"""First-class shareable-GPU device model.

Turns one invoker's accelerator into a sliceable device with three
coupled resources, replacing the scalar ``vgpus`` counter the emulator
used to carry:

  * a **fractional compute lattice** — capacity is ``vgpus *
    SLICES_PER_VGPU`` slices; every running container holds an
    :class:`Allocation` whose slice quota can be *resized without a
    restart* (HAS-GPU's vertical-scaling lever, arXiv 2505.01968);
  * **HBM accounting** — running containers pin their model weights in
    device memory; idle warm containers keep weights resident ("hot")
    until capacity pressure demotes them to host RAM ("warm" tier,
    Torpor/FaaSwap, arXiv 2306.03622) — see ``footprints.swap_in_ms``
    for the restart penalty each tier pays;
  * **two-tier warm pools** — the keep-alive pool entries the emulator's
    ``take_warm``/``add_warm`` used to store as bare expiry floats are
    now :class:`WarmContainer` objects carrying their tier and resident
    bytes.

``hbm_per_vgpu_mb=None`` (the default) models an *unbounded* HBM: usage
and peaks are still tracked, but nothing is ever demoted and every warm
container stays hot — this is exactly the pre-device-model emulator
behaviour, so legacy runs reproduce bit-for-bit.  Pass a finite value to
turn memory into a real constraint.

``shared_weights=True`` switches the HBM ledger to Torpor's read-only
weight sharing: all containers of one function on the device map the
same resident checkpoint, so N containers charge ``model_mb`` *once*,
refcounted in a per-function :class:`WeightSet` (running allocations pin
the set; idle keep-alive containers reference it but leave it demotable).
Demotion and swap-in then act on the whole function at once — every
sibling container flips tier together, because they share the bytes.
The default ``shared_weights=False`` keeps the PR-2 per-container-copy
accounting bit-for-bit.

``overlap=True`` replaces the additive restart-penalty scalars with an
asynchronous per-device PCIe :class:`~repro.gpu.transfer.TransferEngine`
timeline: ``start`` routes swap-ins and cold weight loads through the
engine and returns a *completion time* (``Allocation.ready_ms``) instead
of a synchronous charge, ``prefetch`` re-promotes demoted weights as
background copies that overlap the predecessor stage's execution, and
``swap_cost_ms`` becomes a query of the *residual* transfer time.  The
default ``overlap=False`` keeps the PR-3 additive accounting bit-exact.

Every mutation re-verifies the oversubscription invariants (slices,
HBM, refcounts, per-allocation floors) and raises
:class:`OversubscribedError` on violation — the property tests drive
random alloc/resize/release/swap sequences straight through the public
API.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import math
from collections import Counter, defaultdict
from typing import Optional

from repro.gpu.footprints import (COLD, DEFAULT_SKU, HOT, WARM, GpuSKU,
                                  cold_components, swap_in_ms,
                                  tier_penalty_ms)
from repro.gpu.transfer import Transfer, TransferEngine

# Quota lattice resolution: 1/4 vGPU.  The scheduler's integer-vGPU
# configuration lattice maps onto it as ``cfg.vgpu * SLICES_PER_VGPU``;
# vertical resizes move in single-slice steps.
SLICES_PER_VGPU = 4
MIN_SLICES = 1


class OversubscribedError(RuntimeError):
    """A device invariant (slice or HBM capacity) was violated."""


@dataclasses.dataclass
class Allocation:
    """One running container's share of the device."""
    aid: int
    func: str
    slices: int              # current compute quota
    initial_slices: int      # quota granted at dispatch (resize anchor)
    hbm_mb: float            # weights pinned while running
    # --- overlap mode (transfer-engine timeline) ---
    ready_ms: float = 0.0            # when the weights land (exec gate)
    full_penalty_ms: float = 0.0     # what the additive model would charge


@dataclasses.dataclass
class WarmContainer:
    """One keep-alive pool entry."""
    func: str
    expiry: float
    hbm_mb: float            # resident bytes (0 once demoted, or shared)
    tier: str                # HOT | WARM
    # overlap mode: in-flight background copy backing this container's
    # HOT tier (non-shared ledger only; shared residency lives on the
    # WeightSet), and whether it counts toward predictive-prefetch stats
    transfer: Optional[Transfer] = None
    prefetched: bool = False


@dataclasses.dataclass
class WeightSet:
    """Refcounted read-only weight residency for one function on one
    device (``shared_weights`` mode): N containers charge ``mb`` once.

    ``resident`` stays True even for 0-byte footprints so unknown
    functions behave exactly like the per-copy ledger; ``mb`` is the
    HBM actually charged (0 once demoted to host RAM).
    """
    func: str
    mb: float = 0.0
    resident: bool = False
    run_refs: int = 0        # running allocations pinning the set
    warm_refs: int = 0       # idle keep-alive containers referencing it
    # overlap mode: the copy currently backing residency (None once it
    # landed long ago) and the predictive-prefetch accounting flag
    transfer: Optional[Transfer] = None
    prefetched: bool = False


@dataclasses.dataclass
class DeviceStats:
    hot_hits: int = 0
    warm_hits: int = 0       # container found but weights were in host RAM
    cold_misses: int = 0
    swap_ins: int = 0
    swap_in_ms: float = 0.0
    demotions: int = 0       # hot -> warm evictions under HBM pressure
    resizes_up: int = 0
    resizes_down: int = 0
    hbm_peak_mb: float = 0.0
    shared_hits: int = 0     # starts that mapped weights a peer had pinned
    # overlap mode: predictive-prefetch outcome accounting
    prefetch_issued: int = 0
    prefetch_hits: int = 0   # a start consumed prefetched weights
    prefetch_wasted: int = 0  # prefetched weights demoted/expired unused


class DeviceModel:
    def __init__(self, vgpus: int,
                 hbm_per_vgpu_mb: Optional[float] = None,
                 slices_per_vgpu: int = SLICES_PER_VGPU,
                 shared_weights: bool = False,
                 overlap: bool = False,
                 sku: Optional[GpuSKU] = None,
                 validate: bool = True):
        self.sku = sku if sku is not None else DEFAULT_SKU
        # when False, check() is a no-op: invariants are still upheld by
        # construction, we just skip re-verifying the ledgers after every
        # mutation (the dominant cost at day-scale replay)
        self.validate = validate
        # per-SKU host->HBM bandwidth feeds every swap/cold-load figure
        self._gbps = self.sku.h2d_gbps
        self.vgpus = vgpus
        self.slices_per_vgpu = slices_per_vgpu
        self.total_slices = vgpus * slices_per_vgpu
        self.used_slices = 0
        self.hbm_total_mb = (math.inf if hbm_per_vgpu_mb is None
                             else vgpus * hbm_per_vgpu_mb)
        self.hbm_used_mb = 0.0
        self.shared_weights = shared_weights
        self.overlap = overlap
        self.engine = TransferEngine()
        self.weights: dict[str, WeightSet] = {}
        self._gc_now = -math.inf
        # earliest expiry across every pooled container (lower bound:
        # removals may leave it stale-low, which only costs one no-op
        # sweep) — lets the per-probe _gc skip the pool scan entirely
        # until simulated time actually crosses an expiry
        self._next_expiry = math.inf
        self.pools: dict[str, list[WarmContainer]] = defaultdict(list)
        self.allocs: dict[int, Allocation] = {}
        self._aid = itertools.count()
        self.stats = DeviceStats()
        # flight recorder (repro.obs), set by Recorder.bind_sim; None
        # means unobserved
        self.recorder = None
        self.device_id = -1

    # ---- capacity views ---------------------------------------------------
    @property
    def free_slices(self) -> int:
        return self.total_slices - self.used_slices

    @property
    def free_hbm_mb(self) -> float:
        return self.hbm_total_mb - self.hbm_used_mb

    def _capped(self, model_mb: float) -> float:
        """Oversize checkpoints (> device HBM) run in streaming mode and
        pin the whole device rather than making placement infeasible."""
        return min(model_mb, self.hbm_total_mb)

    def _swap_ms(self, model_mb: float) -> float:
        """``footprints.swap_in_ms`` at this device's SKU bandwidth."""
        return swap_in_ms(model_mb, self._gbps)

    # ---- warm-pool upkeep -------------------------------------------------
    def _gc(self, now: float) -> None:
        """Drop expired keep-alive containers, releasing resident HBM.

        Simulated time is monotone and new pool entries always expire in
        the future, so repeated sweeps at the same instant (placement
        probes every invoker x candidate) are skipped."""
        if now <= self._gc_now:
            return
        self._gc_now = now
        if now <= self._next_expiry:
            return                   # nothing can have expired yet
        nxt = math.inf
        for func, pool in self.pools.items():
            live, dropped = [], 0
            for c in pool:
                if c.expiry < now:
                    self.hbm_used_mb -= c.hbm_mb
                    dropped += 1
                    self._abandon_transfer(c)
                else:
                    live.append(c)
                    if c.expiry < nxt:
                        nxt = c.expiry
            if dropped:
                self.pools[func][:] = live
                if self.shared_weights:
                    self._drop_warm_refs(func, dropped)
        self._next_expiry = nxt

    # ---- transfer-engine bookkeeping (overlap mode) -----------------------
    def _abandon_transfer(self, owner) -> None:
        """The ``WeightSet``/``WarmContainer`` backing a copy went away
        (demotion, expiry, retire): cancel the remaining bytes and score
        a predictive prefetch that never served a start as wasted."""
        if owner.transfer is not None:
            self.engine.cancel(owner.transfer)
            owner.transfer = None
        if owner.prefetched:
            self.stats.prefetch_wasted += 1
            owner.prefetched = False

    def _in_flight(self, tr: Optional[Transfer], now: float) -> bool:
        return tr is not None and (tr in self.engine.queue
                                   or tr.done_ms > now)

    def _residual(self, tr: Optional[Transfer], now: float) -> float:
        """Time until a copy's weights are usable (0 for none/landed)."""
        if tr is None:
            return 0.0
        return self.engine.residual_ms(tr, now)

    # ---- shared-weights ledger helpers ------------------------------------
    def _ws(self, func: str) -> WeightSet:
        ws = self.weights.get(func)
        if ws is None:
            ws = self.weights[func] = WeightSet(func)
        return ws

    def _drop_warm_refs(self, func: str, k: int) -> None:
        """k idle containers of ``func`` went away; free the weight set
        once nothing references it any more."""
        ws = self.weights.get(func)
        if ws is None:
            return
        ws.warm_refs -= k
        if ws.run_refs <= 0 and ws.warm_refs <= 0:
            self.hbm_used_mb -= ws.mb
            self._abandon_transfer(ws)
            del self.weights[func]

    def _resident(self, func: str) -> bool:
        ws = self.weights.get(func)
        return ws is not None and ws.resident

    def _pool_min_expiry(self, func: str) -> float:
        return min((c.expiry for c in self.pools[func]), default=math.inf)

    def _load_shared(self, func: str, model_mb: float) -> None:
        """Charge ``func``'s shared weight set and (re-)promote every
        sibling keep-alive container — they map the same bytes."""
        ws = self._ws(func)
        need = self._capped(model_mb)
        self.hbm_used_mb += need
        ws.mb = need
        ws.resident = True
        for c in self.pools[func]:
            c.tier = HOT

    def _demotable_mb(self, exclude_func: Optional[str] = None) -> float:
        if self.shared_weights:
            return sum(ws.mb for ws in self.weights.values()
                       if ws.run_refs == 0 and ws.mb > 0
                       and ws.func != exclude_func)
        return sum(c.hbm_mb for func, pool in self.pools.items()
                   for c in pool
                   if c.tier == HOT and func != exclude_func)

    def _ensure_hbm(self, need_mb: float) -> None:
        """Demote idle hot containers (earliest-expiry ~ LRU first) until
        ``need_mb`` fits.  Caller must have verified feasibility.  In
        shared mode the victim is a whole weight set (no running pins):
        its resident bytes go to host and every sibling container flips
        to the warm tier together."""
        while self.free_hbm_mb < need_mb:
            if self.shared_weights:
                victims = [ws for ws in self.weights.values()
                           if ws.run_refs == 0 and ws.mb > 0]
                if not victims:
                    raise OversubscribedError(
                        f"need {need_mb:.0f} MB HBM, "
                        f"free {self.free_hbm_mb:.0f} MB, nothing demotable")
                ws = min(victims,
                         key=lambda w: self._pool_min_expiry(w.func))
                self.hbm_used_mb -= ws.mb
                ws.mb = 0.0
                ws.resident = False
                self._abandon_transfer(ws)
                for c in self.pools[ws.func]:
                    c.tier = WARM
                self.stats.demotions += 1
                if self.recorder is not None:
                    self.recorder.on_demotion(self.device_id, ws.func,
                                              self._gc_now)
                continue
            victims = [c for pool in self.pools.values() for c in pool
                       if c.tier == HOT and c.hbm_mb > 0]
            if not victims:
                raise OversubscribedError(
                    f"need {need_mb:.0f} MB HBM, "
                    f"free {self.free_hbm_mb:.0f} MB, nothing demotable")
            victim = min(victims, key=lambda c: c.expiry)
            self.hbm_used_mb -= victim.hbm_mb
            victim.hbm_mb = 0.0
            victim.tier = WARM
            self._abandon_transfer(victim)
            self.stats.demotions += 1
            if self.recorder is not None:
                self.recorder.on_demotion(self.device_id, victim.func,
                                          self._gc_now)

    def _hot(self, func: str):
        return [c for c in self.pools[func] if c.tier == HOT]

    # ---- admission --------------------------------------------------------
    def fits(self, slices: int, model_mb: float = 0.0,
             func: Optional[str] = None, now: float = 0.0) -> bool:
        """Can a container of ``slices`` quota for ``func`` start now?

        HBM feasibility counts weights already resident in a hot warm
        container for ``func`` (they would be reused, costing nothing)
        and idle hot containers of *other* functions (they can be
        demoted to host to make room).  With ``shared_weights`` the
        whole check runs against the refcounted weight ledger: resident
        weights admit any number of sibling containers for free."""
        self._gc(now)
        if slices > self.free_slices:
            return False
        return self._hbm_feasible(model_mb, func)

    def hbm_admits(self, model_mb: float, func: Optional[str] = None,
                   now: float = 0.0) -> bool:
        """HBM-only feasibility (compute slices ignored) — lets the
        vertical autoscaler avoid shrinking quotas for a placement that
        memory would reject anyway."""
        self._gc(now)
        return self._hbm_feasible(model_mb, func)

    def _hbm_feasible(self, model_mb: float, func: Optional[str]) -> bool:
        if func is not None:
            if self.shared_weights:
                if self._resident(func):
                    return True              # shared reuse: no new HBM
            elif any(c.tier == HOT for c in self.pools[func]):
                return True                  # hot reuse: no new HBM needed
        need = self._capped(model_mb)
        return need <= self.free_hbm_mb + self._demotable_mb(func)

    # ---- residency queries (memory-aware placement / planning) ------------
    def residency(self, func: str, now: float) -> str:
        """Warm-state tier the *next* container start of ``func`` would
        pay: HOT (a hot keep-alive container exists — free restart),
        WARM (a container exists but its weights live in host RAM —
        swap-in penalty), COLD (nothing — full cold start)."""
        self._gc(now)
        pool = self.pools.get(func, ())
        if any(c.tier == HOT for c in pool):
            return HOT
        if pool:
            return WARM
        return COLD

    def swap_cost_ms(self, func: str, model_mb: float, now: float,
                     cold_ms: Optional[float] = None) -> float:
        """Predicted restart penalty of starting ``func`` on this device
        right now (0 hot / ``swap_in_ms`` warm / ``cold_ms`` cold; with
        no ``cold_ms`` the weight-load lower bound is used for COLD).

        Shared-weights refinement: when the pool is empty but a *peer*
        container keeps the function's weights resident, a new container
        still cold-boots — yet its weight load is a free mapping, so the
        cold penalty is discounted by the weight-load component.  This
        is also what the emulator bills, and it is what makes
        memory-aware placement prefer weight-dense invokers even when
        every keep-alive container of the function is busy.

        Overlap mode turns this into a query of *residual* transfer
        time: a HOT tier backed by an in-flight copy costs the time
        until the bytes land, and a cold boot costs only the slower of
        container provisioning and the weight copy — the two overlap on
        the transfer-engine timeline instead of adding up."""
        tier = self.residency(func, now)
        if self.overlap:
            if tier == HOT:
                if self.shared_weights:
                    ws = self.weights.get(func)
                    return self._residual(ws.transfer if ws else None, now)
                res = [self._residual(c.transfer, now)
                       for c in self._hot(func)]
                return min(res) if res else 0.0
            if tier == WARM:
                return self._swap_ms(model_mb)   # demand copy from host RAM
            prov, w = cold_components(model_mb, cold_ms, self._gbps)
            if self.shared_weights and self._resident(func):
                # peer-resident weights: the boot waits only for
                # provisioning — or for the peer's copy still in flight
                ws = self.weights[func]
                return max(prov, self._residual(ws.transfer, now))
            return max(prov, w)
        if tier == COLD and self.shared_weights and self._resident(func):
            if cold_ms is None:
                return 0.0
            return max(cold_ms - self._swap_ms(model_mb), 0.0)
        return tier_penalty_ms(tier, model_mb, cold_ms, self._gbps)

    # ---- container lifecycle ---------------------------------------------
    def start(self, func: str, slices: int, model_mb: float,
              now: float,
              cold_ms: Optional[float] = None) -> tuple[Allocation, str]:
        """Start a container: pop the best warm-pool entry (hot before
        warm, earliest expiry first) and pin weights + quota.  Returns
        ``(allocation, tier)`` where tier tells the caller which restart
        penalty to charge (hot: 0, warm: ``swap_in_ms``, cold: full
        cold start).

        In overlap mode the penalty is a *timeline* instead of a scalar:
        swap-ins and cold weight loads are enqueued on the PCIe transfer
        engine and ``alloc.ready_ms`` carries the completion time the
        caller gates execution on (``exec_start = max(start, ready)``);
        ``alloc.full_penalty_ms`` records what the additive model would
        have charged, so the hidden portion is auditable.  ``cold_ms``
        (the function's full cold-start figure) is only consulted on the
        overlap path — the legacy path charges it at the emulator."""
        self._gc(now)
        if slices > self.free_slices:
            raise OversubscribedError(
                f"alloc {slices} slices > free {self.free_slices}")
        pool = self.pools[func]
        # the pool is expiry-sorted, so "min expiry within a tier" is
        # the first entry of that tier — one early-exit scan, no
        # per-tier list builds (day-scale pools run hundreds deep)
        hit: Optional[WarmContainer] = None
        if self.overlap and not self.shared_weights:
            # prefer a hot copy whose weights have landed over one
            # still in flight (legacy expiry order breaks ties);
            # settle the lazy queue first so a prefetch that
            # already arrived is not misread as in flight — but only
            # when a hot copy exists, as the legacy path did
            first_warm = advanced = None
            for c in pool:
                if c.tier == HOT:
                    if not advanced:
                        self.engine._advance(now)
                        advanced = True
                    if not self._in_flight(c.transfer, now):
                        hit = c
                        break
                    if hit is None:
                        hit = c              # earliest in-flight hot
                elif first_warm is None and c.tier == WARM:
                    first_warm = c
            if hit is None:
                hit = first_warm
        else:
            first_warm = None
            for c in pool:
                if c.tier == HOT:
                    hit = c
                    break
                if first_warm is None and c.tier == WARM:
                    first_warm = c
            if hit is None:
                hit = first_warm
        if hit is not None:
            pool.remove(hit)
        ready, full = now, 0.0
        if self.shared_weights:
            was_resident = self._resident(func)
            tier, hbm = self._attach_shared(func, model_mb, hit)
            if self.overlap:
                ready, full = self._shared_timeline(
                    func, model_mb, tier, was_resident, cold_ms, now)
        elif hit is not None and hit.tier == HOT:
            tier, hbm = HOT, hit.hbm_mb      # weights stay where they are
            self.stats.hot_hits += 1
            if self.overlap:
                ready, full = self._consume_hot(hit, now)
        else:
            need = self._capped(model_mb)
            self._ensure_hbm(need)
            self.hbm_used_mb += need
            hbm = need
            if hit is not None:
                tier = WARM
                self.stats.warm_hits += 1
                self.stats.swap_ins += 1
                self.stats.swap_in_ms += self._swap_ms(model_mb)
                if self.overlap:
                    full = self._swap_ms(model_mb)
                    ready = self.engine.demand(func, full, now).done_ms
            else:
                tier = COLD
                self.stats.cold_misses += 1
                if self.overlap:
                    # container provisioning (CPU-side) overlaps the
                    # weight copy on the PCIe engine
                    prov, w = cold_components(model_mb, cold_ms, self._gbps)
                    wdone = (self.engine.demand(func, w, now).done_ms
                             if w > 0.0 else now)
                    ready, full = max(now + prov, wdone), prov + w
        self.used_slices += slices
        alloc = Allocation(next(self._aid), func, slices, slices, hbm,
                           ready_ms=ready, full_penalty_ms=full)
        self.allocs[alloc.aid] = alloc
        self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                     self.hbm_used_mb)
        self.check()
        return alloc, tier

    # ---- overlap-mode start timelines -------------------------------------
    def _ready_of(self, owner, now: float,
                  count_hit: bool = True) -> tuple[float, float]:
        """(ready_ms, full_penalty_ms) of consuming ``owner``'s HOT
        weights.  An in-flight prefetch is *promoted* — only the
        remaining bytes finish at demand priority.  ``full`` rolls the
        warm state back to what the additive model (which has no
        background copies) would have seen: the copy's full duration
        while it is unconsumed/in flight, zero once it has genuinely
        served a start."""
        tr = owner.transfer
        ready, full = now, 0.0
        if tr is not None:
            if tr in self.engine.queue:
                self.engine.promote(tr, now)
            ready = max(tr.done_ms, now)
            if owner.prefetched or tr.done_ms > now:
                full = tr.total_ms
        if owner.prefetched:
            if count_hit:
                self.stats.prefetch_hits += 1
            owner.prefetched = False
        return ready, full

    def _consume_hot(self, hit: WarmContainer, now: float) -> tuple[float, float]:
        return self._ready_of(hit, now)

    def _shared_timeline(self, func: str, model_mb: float, tier: str,
                         was_resident: bool, cold_ms: Optional[float],
                         now: float) -> tuple[float, float]:
        """Overlap timeline of a shared-weights attach (runs after
        ``_attach_shared`` settled tier and HBM accounting)."""
        ws = self._ws(func)
        w_full = self._swap_ms(model_mb)
        if tier == HOT:
            return self._ready_of(ws, now)
        if tier == WARM:
            # demoted set re-loaded on the critical path: demand copy;
            # every sibling shares the completion time
            ws.prefetched = False
            ws.transfer = self.engine.demand(func, w_full, now)
            return ws.transfer.done_ms, w_full
        prov, w = cold_components(model_mb, cold_ms, self._gbps)
        if was_resident:
            # peer-resident weights (PR-3 discount): the cold boot waits
            # only for provisioning — or for the peer's copy in flight
            wready, wfull = self._ready_of(ws, now)
            return max(now + prov, wready), prov + wfull
        ws.prefetched = False
        if w > 0.0:
            ws.transfer = self.engine.demand(func, w, now)
            return max(now + prov, ws.transfer.done_ms), prov + w
        ws.transfer = None
        return now + prov, prov

    def _attach_shared(self, func: str, model_mb: float,
                       hit: Optional[WarmContainer]) -> tuple[str, float]:
        """Shared-weights attach: the new container maps the function's
        refcounted weight set instead of charging its own copy.  Returns
        ``(tier, alloc_hbm_mb)`` — the allocation itself carries 0 bytes,
        all residency lives on the :class:`WeightSet`."""
        ws = self._ws(func)
        if hit is not None:
            ws.warm_refs -= 1
        if ws.resident:
            # bytes still mapped by a *peer* (not just the popped hit):
            # the attach shares them instead of charging a copy
            if ws.run_refs > 0 or ws.warm_refs > 0:
                self.stats.shared_hits += 1
            if hit is not None:
                tier = HOT                   # container + weights both live
                self.stats.hot_hits += 1
            else:
                tier = COLD                  # container must still cold-boot
                self.stats.cold_misses += 1
        else:
            need = self._capped(model_mb)
            self._ensure_hbm(need)
            self._load_shared(func, model_mb)
            if hit is not None:
                # container survived, the shared set was demoted: one
                # swap-in re-promotes every sibling at once
                tier = WARM
                self.stats.warm_hits += 1
                self.stats.swap_ins += 1
                self.stats.swap_in_ms += self._swap_ms(model_mb)
            else:
                tier = COLD
                self.stats.cold_misses += 1
        ws.run_refs += 1
        return tier, 0.0

    def resize(self, aid: int, new_slices: int) -> bool:
        """Vertically resize a *running* allocation's compute quota
        without a restart.  Returns False (no-op) if the target is
        below the floor or the device lacks free slices to grow."""
        a = self.allocs.get(aid)
        if a is None or new_slices < MIN_SLICES:
            return False
        delta = new_slices - a.slices
        if delta == 0:
            return False
        if delta > 0 and delta > self.free_slices:
            return False
        self.used_slices += delta
        a.slices = new_slices
        if delta > 0:
            self.stats.resizes_up += 1
        else:
            self.stats.resizes_down += 1
        self.check()
        return True

    def stop(self, aid: int, expiry: float) -> WarmContainer:
        """Finish a container: free its quota and park it in the
        keep-alive pool *hot* — weights remain resident until expiry or
        demotion."""
        a = self.allocs.pop(aid)
        self.used_slices -= a.slices
        if self.shared_weights:
            ws = self._ws(a.func)
            ws.run_refs -= 1
            ws.warm_refs += 1
            # the running allocation pinned the set (_ensure_hbm never
            # demotes while run_refs > 0), so the weights are resident
            # and the container always parks hot
            c = WarmContainer(a.func, expiry, 0.0, HOT)
        else:
            c = WarmContainer(a.func, expiry, a.hbm_mb, HOT)
        pool = self.pools[a.func]
        bisect.insort(pool, c, key=lambda x: x.expiry)
        if c.expiry < self._next_expiry:
            self._next_expiry = c.expiry
        self.check()
        return c

    # ---- spot reclamation -------------------------------------------------
    def kill(self, aid: int) -> Allocation:
        """Reclamation kill: drop a *running* allocation without parking
        a keep-alive container — unlike :meth:`stop`, the container and
        its pinned weights die with the device.  In shared mode the run
        pin is released and the weight set is freed once nothing else
        references it."""
        a = self.allocs.pop(aid)
        self.used_slices -= a.slices
        if self.shared_weights:
            ws = self._ws(a.func)
            ws.run_refs -= 1
            if ws.run_refs <= 0 and ws.warm_refs <= 0:
                self.hbm_used_mb -= ws.mb
                self._abandon_transfer(ws)
                del self.weights[a.func]
        else:
            self.hbm_used_mb -= a.hbm_mb
        self.check()
        return a

    def reclaim(self) -> None:
        """The device vanished (spot reclamation): wipe every keep-alive
        pool, weight set and in-flight transfer.  Running allocations
        must have been :meth:`kill`-ed first; afterwards the HBM ledger
        reads zero and ``check()`` still holds, so a later recovery
        restarts from a genuinely cold device."""
        if self.allocs:
            raise OversubscribedError(
                f"reclaim() with {len(self.allocs)} live allocations")
        for pool in self.pools.values():
            for c in pool:
                self.hbm_used_mb -= c.hbm_mb
                self._abandon_transfer(c)
            pool.clear()
        for func in list(self.weights):
            ws = self.weights.pop(func)
            self.hbm_used_mb -= ws.mb
            self._abandon_transfer(ws)
        self.check()

    def empty(self, now: float) -> bool:
        """No running allocation and no live keep-alive container — the
        next start on a SKU with ``warmup_ms`` pays the warm-up-from-zero
        latency."""
        self._gc(now)
        return not self.allocs and \
            not any(pool for pool in self.pools.values())

    # ---- warm-pool API (autoscalers / emulator) ---------------------------
    def add_warm(self, func: str, expiry: float, model_mb: float,
                 now: float = 0.0) -> WarmContainer:
        """Pre-warm a container.  It comes up hot if HBM is free; under
        pressure it is provisioned warm (weights staged in host RAM) —
        pre-warming never demotes somebody else's resident weights."""
        self._gc(now)
        if self.shared_weights:
            ws = self._ws(func)
            if ws.resident:
                c = WarmContainer(func, expiry, 0.0, HOT)   # maps the peer's
            elif self._capped(model_mb) <= self.free_hbm_mb:
                # re-loading a previously-demoted set promotes every WARM
                # sibling at once; that H2D copy is a real swap-in and is
                # counted.  Legacy mode treats it as a free background
                # copy (no start ever pays its latency); overlap mode
                # puts it on the PCIe engine, so a start arriving before
                # the bytes land pays the honest residual.
                repromote = any(e.tier == WARM for e in self.pools[func])
                if repromote:
                    self.stats.swap_ins += 1
                    self.stats.swap_in_ms += self._swap_ms(model_mb)
                self._load_shared(func, model_mb)
                if self.overlap and repromote and self._swap_ms(model_mb) > 0:
                    self._ws(func).transfer = self.engine.prefetch(
                        func, self._swap_ms(model_mb), now)
                c = WarmContainer(func, expiry, 0.0, HOT)
                self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                             self.hbm_used_mb)
            else:
                c = WarmContainer(func, expiry, 0.0, WARM)
            ws.warm_refs += 1
        else:
            need = self._capped(model_mb)
            if need <= self.free_hbm_mb:
                self.hbm_used_mb += need
                c = WarmContainer(func, expiry, need, HOT)
                self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                             self.hbm_used_mb)
            else:
                c = WarmContainer(func, expiry, 0.0, WARM)
        bisect.insort(self.pools[func], c, key=lambda x: x.expiry)
        if c.expiry < self._next_expiry:
            self._next_expiry = c.expiry
        self.check()
        return c

    def prefetch(self, func: str, model_mb: float, now: float) -> bool:
        """Predictively re-promote ``func``'s demoted weights (WARM
        tier) as a *background* PCIe copy — Torpor's predicted-next
        prefetch: issued when the pipeline's previous stage dispatches,
        the copy overlaps that stage's execution so the successor's
        start finds the weights landed (or mostly landed).

        Speculative work never hurts bystanders: the copy only runs on
        link time no demand copy wants, and HBM is only taken when it
        is free — a guess never demotes somebody else's weights.
        Returns True when a copy was enqueued (overlap mode only)."""
        if not self.overlap:
            return False
        self._gc(now)
        if self.residency(func, now) != WARM:
            return False                 # nothing demoted to re-promote
        need = self._capped(model_mb)
        if need > self.free_hbm_mb:
            return False
        w = self._swap_ms(model_mb)
        if w <= 0.0:
            return False
        tr = self.engine.prefetch(func, w, now)
        if self.shared_weights:
            self._load_shared(func, model_mb)    # charges HBM, flips pool
            ws = self._ws(func)
            ws.transfer, ws.prefetched = tr, True
        else:
            # promote the longest-lived staged container (most useful)
            victim = max((c for c in self.pools[func] if c.tier == WARM),
                         key=lambda c: c.expiry)
            self.hbm_used_mb += need
            victim.hbm_mb = need
            victim.tier = HOT
            victim.transfer, victim.prefetched = tr, True
        self.stats.swap_ins += 1
        self.stats.swap_in_ms += w
        self.stats.prefetch_issued += 1
        self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                     self.hbm_used_mb)
        self.check()
        return True

    def has_warm(self, func: str, now: float) -> bool:
        return any(c.expiry >= now for c in self.pools[func])

    def warm_entries(self, func: str, now: float) -> list[WarmContainer]:
        return [c for c in self.pools[func] if c.expiry >= now]

    def retire(self, func: str, container: WarmContainer) -> None:
        """Scale-down: drop one keep-alive container, freeing HBM (in
        shared mode the weights stay until the last reference goes)."""
        self.pools[func].remove(container)
        self.hbm_used_mb -= container.hbm_mb
        self._abandon_transfer(container)
        if self.shared_weights:
            self._drop_warm_refs(func, 1)
        self.check()

    # ---- invariants -------------------------------------------------------
    def check(self) -> None:
        """Raise OversubscribedError if any invariant is violated."""
        if not self.validate:
            return
        used = sum(a.slices for a in self.allocs.values())
        if used != self.used_slices:
            raise OversubscribedError(
                f"slice ledger drift: {used} != {self.used_slices}")
        if not 0 <= self.used_slices <= self.total_slices:
            raise OversubscribedError(
                f"slices oversubscribed: {self.used_slices}"
                f"/{self.total_slices}")
        if any(a.slices < MIN_SLICES for a in self.allocs.values()):
            raise OversubscribedError("allocation below MIN_SLICES")
        if self.shared_weights:
            resident = sum(ws.mb for ws in self.weights.values())
            run_counts = Counter(a.func for a in self.allocs.values())
            referenced = set(run_counts) | \
                {f for f, p in self.pools.items() if p}
            if referenced != set(self.weights):
                raise OversubscribedError(
                    f"weight-set drift: ledger {sorted(self.weights)} vs "
                    f"referenced {sorted(referenced)}")
            for func, ws in self.weights.items():
                if ws.run_refs != run_counts.get(func, 0) or \
                        ws.warm_refs != len(self.pools.get(func, ())):
                    raise OversubscribedError(
                        f"refcount drift for {func}: runs {ws.run_refs}/"
                        f"{run_counts.get(func, 0)}, warms {ws.warm_refs}/"
                        f"{len(self.pools.get(func, ()))}")
                if ws.mb < 0 or (not ws.resident and ws.mb != 0):
                    raise OversubscribedError(
                        f"weight bytes drift for {func}: mb={ws.mb} "
                        f"resident={ws.resident}")
                if any((c.tier == HOT) != ws.resident
                       for c in self.pools.get(func, ())):
                    raise OversubscribedError(
                        f"tier desync for {func}: shared weights resident="
                        f"{ws.resident} but pool tiers disagree")
            if any(c.hbm_mb for pool in self.pools.values() for c in pool) \
                    or any(a.hbm_mb for a in self.allocs.values()):
                raise OversubscribedError(
                    "per-container HBM charged in shared-weights mode")
        else:
            resident = sum(a.hbm_mb for a in self.allocs.values()) + \
                sum(c.hbm_mb for pool in self.pools.values() for c in pool)
        if not math.isclose(resident, self.hbm_used_mb,
                            rel_tol=1e-9, abs_tol=1e-6):
            raise OversubscribedError(
                f"HBM ledger drift: {resident} != {self.hbm_used_mb}")
        if math.isfinite(self.hbm_total_mb) and \
                self.hbm_used_mb > self.hbm_total_mb + 1e-6:
            raise OversubscribedError(
                f"HBM oversubscribed: {self.hbm_used_mb:.0f}"
                f"/{self.hbm_total_mb:.0f} MB")
        # overlap mode: the transfer ledger is work-conserving and
        # prefetch flags only ever back resident (HOT) weights
        self.engine.check()
        if self.shared_weights:
            if any(ws.prefetched and not ws.resident
                   for ws in self.weights.values()):
                raise OversubscribedError(
                    "prefetched weight set not resident")
        elif any(c.prefetched and c.tier != HOT
                 for pool in self.pools.values() for c in pool):
            raise OversubscribedError("prefetched container not HOT")
