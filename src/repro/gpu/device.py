"""First-class shareable-GPU device model.

Turns one invoker's accelerator into a sliceable device with three
coupled resources, replacing the scalar ``vgpus`` counter the emulator
used to carry:

  * a **fractional compute lattice** — capacity is ``vgpus *
    SLICES_PER_VGPU`` slices; every running container holds an
    :class:`Allocation` whose slice quota can be *resized without a
    restart* (HAS-GPU's vertical-scaling lever, arXiv 2505.01968);
  * **HBM accounting** — running containers pin their model weights in
    device memory; idle warm containers keep weights resident ("hot")
    until capacity pressure demotes them to host RAM ("warm" tier,
    Torpor/FaaSwap, arXiv 2306.03622) — see ``footprints.swap_in_ms``
    for the restart penalty each tier pays;
  * **two-tier warm pools** — the keep-alive pool entries the emulator's
    ``take_warm``/``add_warm`` used to store as bare expiry floats are
    now :class:`WarmContainer` objects carrying their tier and resident
    bytes.

``hbm_per_vgpu_mb=None`` (the default) models an *unbounded* HBM: usage
and peaks are still tracked, but nothing is ever demoted and every warm
container stays hot — this is exactly the pre-device-model emulator
behaviour, so legacy runs reproduce bit-for-bit.  Pass a finite value to
turn memory into a real constraint.

Every mutation re-verifies the oversubscription invariants (slices,
HBM, per-allocation floors) and raises :class:`OversubscribedError` on
violation — the property tests drive random alloc/resize/release/swap
sequences straight through the public API.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import math
from collections import defaultdict
from typing import Optional

from repro.gpu.footprints import swap_in_ms

# Quota lattice resolution: 1/4 vGPU.  The scheduler's integer-vGPU
# configuration lattice maps onto it as ``cfg.vgpu * SLICES_PER_VGPU``;
# vertical resizes move in single-slice steps.
SLICES_PER_VGPU = 4
MIN_SLICES = 1

HOT = "hot"      # weights resident in HBM
WARM = "warm"    # weights in host RAM (swap-in penalty on start)
COLD = "cold"    # no container anywhere (full cold start)


class OversubscribedError(RuntimeError):
    """A device invariant (slice or HBM capacity) was violated."""


@dataclasses.dataclass
class Allocation:
    """One running container's share of the device."""
    aid: int
    func: str
    slices: int              # current compute quota
    initial_slices: int      # quota granted at dispatch (resize anchor)
    hbm_mb: float            # weights pinned while running


@dataclasses.dataclass
class WarmContainer:
    """One keep-alive pool entry."""
    func: str
    expiry: float
    hbm_mb: float            # resident bytes (0 once demoted)
    tier: str                # HOT | WARM


@dataclasses.dataclass
class DeviceStats:
    hot_hits: int = 0
    warm_hits: int = 0       # container found but weights were in host RAM
    cold_misses: int = 0
    swap_ins: int = 0
    swap_in_ms: float = 0.0
    demotions: int = 0       # hot -> warm evictions under HBM pressure
    resizes_up: int = 0
    resizes_down: int = 0
    hbm_peak_mb: float = 0.0


class DeviceModel:
    def __init__(self, vgpus: int,
                 hbm_per_vgpu_mb: Optional[float] = None,
                 slices_per_vgpu: int = SLICES_PER_VGPU):
        self.vgpus = vgpus
        self.slices_per_vgpu = slices_per_vgpu
        self.total_slices = vgpus * slices_per_vgpu
        self.used_slices = 0
        self.hbm_total_mb = (math.inf if hbm_per_vgpu_mb is None
                             else vgpus * hbm_per_vgpu_mb)
        self.hbm_used_mb = 0.0
        self._gc_now = -math.inf
        self.pools: dict[str, list[WarmContainer]] = defaultdict(list)
        self.allocs: dict[int, Allocation] = {}
        self._aid = itertools.count()
        self.stats = DeviceStats()

    # ---- capacity views ---------------------------------------------------
    @property
    def free_slices(self) -> int:
        return self.total_slices - self.used_slices

    @property
    def free_hbm_mb(self) -> float:
        return self.hbm_total_mb - self.hbm_used_mb

    def _capped(self, model_mb: float) -> float:
        """Oversize checkpoints (> device HBM) run in streaming mode and
        pin the whole device rather than making placement infeasible."""
        return min(model_mb, self.hbm_total_mb)

    # ---- warm-pool upkeep -------------------------------------------------
    def _gc(self, now: float) -> None:
        """Drop expired keep-alive containers, releasing resident HBM.

        Simulated time is monotone and new pool entries always expire in
        the future, so repeated sweeps at the same instant (placement
        probes every invoker x candidate) are skipped."""
        if now <= self._gc_now:
            return
        self._gc_now = now
        for func, pool in self.pools.items():
            live = []
            for c in pool:
                if c.expiry < now:
                    self.hbm_used_mb -= c.hbm_mb
                else:
                    live.append(c)
            if len(live) != len(pool):
                self.pools[func][:] = live

    def _demotable_mb(self, exclude_func: Optional[str] = None) -> float:
        return sum(c.hbm_mb for func, pool in self.pools.items()
                   for c in pool
                   if c.tier == HOT and func != exclude_func)

    def _ensure_hbm(self, need_mb: float) -> None:
        """Demote idle hot containers (earliest-expiry ~ LRU first) until
        ``need_mb`` fits.  Caller must have verified feasibility."""
        while self.free_hbm_mb < need_mb:
            victims = [c for pool in self.pools.values() for c in pool
                       if c.tier == HOT and c.hbm_mb > 0]
            if not victims:
                raise OversubscribedError(
                    f"need {need_mb:.0f} MB HBM, "
                    f"free {self.free_hbm_mb:.0f} MB, nothing demotable")
            victim = min(victims, key=lambda c: c.expiry)
            self.hbm_used_mb -= victim.hbm_mb
            victim.hbm_mb = 0.0
            victim.tier = WARM
            self.stats.demotions += 1

    def _hot(self, func: str):
        return [c for c in self.pools[func] if c.tier == HOT]

    # ---- admission --------------------------------------------------------
    def fits(self, slices: int, model_mb: float = 0.0,
             func: Optional[str] = None, now: float = 0.0) -> bool:
        """Can a container of ``slices`` quota for ``func`` start now?

        HBM feasibility counts weights already resident in a hot warm
        container for ``func`` (they would be reused, costing nothing)
        and idle hot containers of *other* functions (they can be
        demoted to host to make room)."""
        self._gc(now)
        if slices > self.free_slices:
            return False
        if func is not None and self._hot(func):
            return True                      # hot reuse: no new HBM needed
        need = self._capped(model_mb)
        return need <= self.free_hbm_mb + self._demotable_mb(func)

    def hbm_admits(self, model_mb: float, func: Optional[str] = None,
                   now: float = 0.0) -> bool:
        """HBM-only feasibility (compute slices ignored) — lets the
        vertical autoscaler avoid shrinking quotas for a placement that
        memory would reject anyway."""
        self._gc(now)
        if func is not None and self._hot(func):
            return True
        return self._capped(model_mb) <= \
            self.free_hbm_mb + self._demotable_mb(func)

    # ---- container lifecycle ---------------------------------------------
    def start(self, func: str, slices: int, model_mb: float,
              now: float) -> tuple[Allocation, str]:
        """Start a container: pop the best warm-pool entry (hot before
        warm, earliest expiry first) and pin weights + quota.  Returns
        ``(allocation, tier)`` where tier tells the caller which restart
        penalty to charge (hot: 0, warm: ``swap_in_ms``, cold: full
        cold start)."""
        self._gc(now)
        if slices > self.free_slices:
            raise OversubscribedError(
                f"alloc {slices} slices > free {self.free_slices}")
        pool = self.pools[func]
        hit: Optional[WarmContainer] = None
        for want_tier in (HOT, WARM):
            tiered = [c for c in pool if c.tier == want_tier]
            if tiered:
                hit = min(tiered, key=lambda c: c.expiry)
                break
        if hit is not None:
            pool.remove(hit)
        if hit is not None and hit.tier == HOT:
            tier, hbm = HOT, hit.hbm_mb      # weights stay where they are
            self.stats.hot_hits += 1
        else:
            need = self._capped(model_mb)
            self._ensure_hbm(need)
            self.hbm_used_mb += need
            hbm = need
            if hit is not None:
                tier = WARM
                self.stats.warm_hits += 1
                self.stats.swap_ins += 1
                self.stats.swap_in_ms += swap_in_ms(model_mb)
            else:
                tier = COLD
                self.stats.cold_misses += 1
        self.used_slices += slices
        alloc = Allocation(next(self._aid), func, slices, slices, hbm)
        self.allocs[alloc.aid] = alloc
        self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                     self.hbm_used_mb)
        self.check()
        return alloc, tier

    def resize(self, aid: int, new_slices: int) -> bool:
        """Vertically resize a *running* allocation's compute quota
        without a restart.  Returns False (no-op) if the target is
        below the floor or the device lacks free slices to grow."""
        a = self.allocs.get(aid)
        if a is None or new_slices < MIN_SLICES:
            return False
        delta = new_slices - a.slices
        if delta == 0:
            return False
        if delta > 0 and delta > self.free_slices:
            return False
        self.used_slices += delta
        a.slices = new_slices
        if delta > 0:
            self.stats.resizes_up += 1
        else:
            self.stats.resizes_down += 1
        self.check()
        return True

    def stop(self, aid: int, expiry: float) -> WarmContainer:
        """Finish a container: free its quota and park it in the
        keep-alive pool *hot* — weights remain resident until expiry or
        demotion."""
        a = self.allocs.pop(aid)
        self.used_slices -= a.slices
        c = WarmContainer(a.func, expiry, a.hbm_mb, HOT)
        pool = self.pools[a.func]
        bisect.insort(pool, c, key=lambda x: x.expiry)
        self.check()
        return c

    # ---- warm-pool API (autoscalers / emulator) ---------------------------
    def add_warm(self, func: str, expiry: float, model_mb: float,
                 now: float = 0.0) -> WarmContainer:
        """Pre-warm a container.  It comes up hot if HBM is free; under
        pressure it is provisioned warm (weights staged in host RAM) —
        pre-warming never demotes somebody else's resident weights."""
        self._gc(now)
        need = self._capped(model_mb)
        if need <= self.free_hbm_mb:
            self.hbm_used_mb += need
            c = WarmContainer(func, expiry, need, HOT)
            self.stats.hbm_peak_mb = max(self.stats.hbm_peak_mb,
                                         self.hbm_used_mb)
        else:
            c = WarmContainer(func, expiry, 0.0, WARM)
        bisect.insort(self.pools[func], c, key=lambda x: x.expiry)
        self.check()
        return c

    def has_warm(self, func: str, now: float) -> bool:
        return any(c.expiry >= now for c in self.pools[func])

    def warm_entries(self, func: str, now: float) -> list[WarmContainer]:
        return [c for c in self.pools[func] if c.expiry >= now]

    def retire(self, func: str, container: WarmContainer) -> None:
        """Scale-down: drop one keep-alive container, freeing HBM."""
        self.pools[func].remove(container)
        self.hbm_used_mb -= container.hbm_mb
        self.check()

    # ---- invariants -------------------------------------------------------
    def check(self) -> None:
        """Raise OversubscribedError if any invariant is violated."""
        used = sum(a.slices for a in self.allocs.values())
        if used != self.used_slices:
            raise OversubscribedError(
                f"slice ledger drift: {used} != {self.used_slices}")
        if not 0 <= self.used_slices <= self.total_slices:
            raise OversubscribedError(
                f"slices oversubscribed: {self.used_slices}"
                f"/{self.total_slices}")
        if any(a.slices < MIN_SLICES for a in self.allocs.values()):
            raise OversubscribedError("allocation below MIN_SLICES")
        resident = sum(a.hbm_mb for a in self.allocs.values()) + \
            sum(c.hbm_mb for pool in self.pools.values() for c in pool)
        if not math.isclose(resident, self.hbm_used_mb,
                            rel_tol=1e-9, abs_tol=1e-6):
            raise OversubscribedError(
                f"HBM ledger drift: {resident} != {self.hbm_used_mb}")
        if math.isfinite(self.hbm_total_mb) and \
                self.hbm_used_mb > self.hbm_total_mb + 1e-6:
            raise OversubscribedError(
                f"HBM oversubscribed: {self.hbm_used_mb:.0f}"
                f"/{self.hbm_total_mb:.0f} MB")
