"""Shareable-GPU device model (fractional vGPU slices, HBM accounting,
model-state swap tiers).

  * ``device``     — :class:`DeviceModel`: per-invoker slice lattice,
                     resizable running allocations, two-tier warm pools;
  * ``footprints`` — model-weight footprints + the Torpor-style
                     host->HBM swap-in timing model.
"""
from repro.gpu.device import (COLD, HOT, MIN_SLICES, SLICES_PER_VGPU, WARM,
                              Allocation, DeviceModel, DeviceStats,
                              OversubscribedError, WarmContainer, WeightSet)
from repro.gpu.footprints import PAPER_MODEL_MB, swap_in_ms, tier_penalty_ms

__all__ = [
    "Allocation", "COLD", "DeviceModel", "DeviceStats", "HOT",
    "MIN_SLICES", "OversubscribedError", "PAPER_MODEL_MB",
    "SLICES_PER_VGPU", "WARM", "WarmContainer", "WeightSet",
    "swap_in_ms", "tier_penalty_ms",
]
