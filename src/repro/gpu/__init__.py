"""Shareable-GPU device model (fractional vGPU slices, HBM accounting,
model-state swap tiers).

  * ``device``     — :class:`DeviceModel`: per-invoker slice lattice,
                     resizable running allocations, two-tier warm pools;
  * ``footprints`` — model-weight footprints + the Torpor-style
                     host->HBM swap-in timing model;
  * ``transfer``   — :class:`TransferEngine`: per-device asynchronous
                     PCIe copy timeline (overlapped swap + prefetch).
"""
from repro.gpu.device import (COLD, HOT, MIN_SLICES, SLICES_PER_VGPU, WARM,
                              Allocation, DeviceModel, DeviceStats,
                              OversubscribedError, WarmContainer, WeightSet)
from repro.gpu.footprints import (DEFAULT_SKU, PAPER_MODEL_MB, SKU_CATALOG,
                                  GpuSKU, cold_components, resolve_sku,
                                  swap_in_ms, tier_penalty_ms)
from repro.gpu.transfer import DEMAND, PREFETCH, Transfer, TransferEngine

__all__ = [
    "Allocation", "COLD", "DEFAULT_SKU", "DEMAND", "DeviceModel",
    "DeviceStats", "GpuSKU", "HOT", "MIN_SLICES", "OversubscribedError",
    "PAPER_MODEL_MB", "PREFETCH", "SKU_CATALOG", "SLICES_PER_VGPU",
    "Transfer", "TransferEngine", "WARM", "WarmContainer", "WeightSet",
    "cold_components", "resolve_sku", "swap_in_ms", "tier_penalty_ms",
]
