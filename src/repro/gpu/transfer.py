"""Asynchronous per-device PCIe transfer engine (overlapped swap pipeline).

Replaces the additive-scalar restart-penalty model with an explicit
timeline of host->HBM weight copies, so the emulator can overlap a
stage's swap-in with its predecessor's execution (Torpor/FaaSwap's
pipelined swap, arXiv 2306.03622) and with predictive prefetch of the
pipeline's next stage.

Two traffic classes share one device's PCIe link:

  * **demand** copies sit on a task's critical path (the weights a start
    is waiting for).  They run on the reserved demand stream and take
    exactly their transfer duration from the moment they are issued —
    the same assumption the legacy additive model makes — so turning
    overlap on can never make an individual task *slower* than the
    additive accounting (the monotone-improvement invariant the
    differential tests pin).
  * **prefetch** copies are speculative background work (predicted
    next-stage weights, autoscaler re-promotions).  They serialize FIFO
    on the leftover bandwidth and *pause* whenever a demand copy holds
    the link, so background traffic never steals critical-path
    bandwidth.

A demand request for weights that already have a prefetch in flight
**promotes** the prefetch: only the remaining bytes are copied at demand
priority, and the bytes already landed are never re-transferred — every
byte of every movement is booked on the link exactly once (the
``busy_ms == demand_ms + prefetch_ms`` work-conservation invariant the
property tests walk).

The engine is lazily evaluated: simulated time is monotone and every
operation passes ``now``, so queue progress is materialised on access
(``_advance``) instead of via scheduled events — the emulator's event
loop never needs to know the engine exists.
"""
from __future__ import annotations

import dataclasses
import math

DEMAND = "demand"
PREFETCH = "prefetch"

_EPS = 1e-9


@dataclasses.dataclass(eq=False)
class Transfer:
    """One host->HBM weight copy on a device's PCIe link.

    ``eq=False``: queue membership (``in`` / ``remove``) must be by
    *identity* — two copies of the same checkpoint enqueued at the same
    instant are distinct pieces of work, not equal values."""
    func: str
    total_ms: float              # full copy duration (the additive penalty)
    remaining_ms: float          # work not yet performed
    kind: str                    # DEMAND | PREFETCH
    enq_ms: float                # when the copy was requested
    done_ms: float = math.inf    # completion time, once known

    def residual_ms(self, now: float) -> float:
        """Time until the copy completes, 0 if already done.  Only valid
        once ``done_ms`` is known (demand copies, drained prefetches);
        queued prefetches go through :meth:`TransferEngine.eta`."""
        return max(self.done_ms - now, 0.0)


class TransferEngine:
    """Serialized background-transfer queue with demand preemption."""

    def __init__(self):
        self.queue: list[Transfer] = []   # pending/in-flight prefetches, FIFO
        self.block_until = 0.0            # demand copies hold the link until
        self.last_ms = 0.0                # queue progress materialised up to
        # work-conserving accounting (ms of link time actually used)
        self.busy_ms = 0.0
        self.demand_ms = 0.0
        self.prefetch_ms = 0.0
        # flight recorder (repro.obs), set by Recorder.bind_sim; None
        # means unobserved — hooks are guarded so the unrecorded path
        # does no extra work
        self.recorder = None
        self.device_id = -1

    # ---- lazy queue progress ----------------------------------------------
    def _advance(self, now: float) -> None:
        """Materialise prefetch-queue progress up to ``now``.

        The queue only runs while no demand copy holds the link, i.e. in
        the window ``(max(last_ms, block_until), now]``.  ``block_until``
        only changes inside engine operations and every operation calls
        ``_advance`` first, so computing the window with the *current*
        value is exact."""
        t = max(self.last_ms, self.block_until)
        while self.queue and t < now - _EPS:
            head = self.queue[0]
            step = min(head.remaining_ms, now - t)
            head.remaining_ms -= step
            self.busy_ms += step
            self.prefetch_ms += step
            t += step
            if head.remaining_ms <= _EPS:
                head.remaining_ms = 0.0
                head.done_ms = t
                self.queue.pop(0)
        self.last_ms = max(self.last_ms, now)

    # ---- requests ----------------------------------------------------------
    def demand(self, func: str, dur_ms: float, now: float) -> Transfer:
        """Critical-path copy: runs on the reserved demand stream, takes
        exactly ``dur_ms`` from ``now``, and pauses the prefetch queue
        until it completes."""
        self._advance(now)
        tr = Transfer(func, dur_ms, 0.0, DEMAND, now, done_ms=now + dur_ms)
        self.busy_ms += dur_ms
        self.demand_ms += dur_ms
        self.block_until = max(self.block_until, tr.done_ms)
        if self.recorder is not None:
            self.recorder.on_transfer(self.device_id, tr, DEMAND)
        return tr

    def prefetch(self, func: str, dur_ms: float, now: float) -> Transfer:
        """Background copy: appended to the FIFO, drains whenever the
        link is demand-free.  Completion time is resolved lazily (a
        later demand copy may push it out); query :meth:`eta`."""
        self._advance(now)
        tr = Transfer(func, dur_ms, dur_ms, PREFETCH, now)
        self.queue.append(tr)
        if self.recorder is not None:
            self.recorder.on_transfer(self.device_id, tr, PREFETCH)
        return tr

    def promote(self, tr: Transfer, now: float) -> Transfer:
        """A start demands weights whose prefetch is still in flight:
        the remaining bytes finish at demand priority (the bytes already
        landed are not copied again)."""
        self._advance(now)
        if tr in self.queue:
            self.queue.remove(tr)
            rem = tr.remaining_ms
            tr.remaining_ms = 0.0
            tr.kind = DEMAND
            tr.done_ms = now + rem
            self.busy_ms += rem
            self.demand_ms += rem
            self.block_until = max(self.block_until, tr.done_ms)
            if self.recorder is not None:
                self.recorder.on_promote(self.device_id, tr.func, now)
        return tr

    def cancel(self, tr: Transfer) -> None:
        """Abandon a queued prefetch (its target was demoted or
        expired).  Work already performed stays booked — those bytes
        really crossed the link — but the remaining bytes never do."""
        if tr in self.queue:
            self.queue.remove(tr)
            tr.remaining_ms = 0.0
            tr.done_ms = math.inf

    # ---- queries ------------------------------------------------------------
    def eta(self, tr: Transfer, now: float) -> float:
        """Predicted completion time of ``tr`` given the current queue
        and demand blockage (later demand copies may still push a
        queued prefetch out further — the estimate is a lower bound,
        which keeps planners optimistic, never pessimistic)."""
        self._advance(now)
        if tr not in self.queue:
            return tr.done_ms
        t = max(now, self.block_until)
        for q in self.queue:
            t += q.remaining_ms
            if q is tr:
                break
        return t

    def residual_ms(self, tr: Transfer, now: float) -> float:
        """Time until ``tr``'s weights are usable, 0 once landed."""
        return max(self.eta(tr, now) - now, 0.0)

    def check(self) -> None:
        """Engine invariants (driven by the device model's ``check``)."""
        if any(t.remaining_ms < 0 for t in self.queue):
            raise AssertionError("negative remaining transfer work")
        if not math.isclose(self.busy_ms, self.demand_ms + self.prefetch_ms,
                            rel_tol=1e-9, abs_tol=1e-6):
            raise AssertionError(
                f"PCIe work double-booked: busy {self.busy_ms} != "
                f"demand {self.demand_ms} + prefetch {self.prefetch_ms}")
