"""Jitted wrapper for the WKV6 kernel, in the model's (B, T, H, K) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.rwkv6 import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def wkv6(r, k, v, lw, u, s0):
    """r/k/v/lw: (B, T, H, K); u: (H, K); s0: (B, H, K, V) f32.

    Returns (y (B, T, H, V), s_fin)."""
    t = r.shape[1]
    chunk = 32
    pad = (-t) % chunk
    args = [jnp.moveaxis(a, 1, 2) for a in (r, k, v)]
    lwT = jnp.moveaxis(lw, 1, 2)
    if pad:
        args = [jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0))) for a in args]
        # pad decays with 0 (= decay 1.0) so the padded steps keep S intact;
        # padded k rows are zero so they add nothing
        lwT = jnp.pad(lwT, ((0, 0), (0, 0), (0, pad), (0, 0)))
    y, s_fin = wkv6_pallas(*args, lwT, u, s0, chunk=chunk,
                           interpret=not _on_tpu())
    y = y[:, :, :t]
    return jnp.moveaxis(y, 1, 2), s_fin
