"""Sequential-oracle for the WKV6 kernel (literal per-token recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u, s0):
    """r/k/v/lw: (B, H, T, K); u: (H, K); s0: (B, H, K, V) f32.

    Token-by-token recurrence — slow but unambiguous."""
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.exp(lw.astype(jnp.float32))          # per-step decay in (0, 1]
    u = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                     # (B, H, K) each
        kv = kt[..., :, None] * vt[..., None, :]                 # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 2)                   # (B, H, T, V)
    return y.astype(r.dtype), s_fin
