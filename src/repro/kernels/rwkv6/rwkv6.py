"""WKV6 (RWKV-6 "Finch" time-mix) chunked-recurrence Pallas TPU kernel.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

Grid: (batch, heads, T // C) — the time dim iterates innermost, so the
running state S (K x V fp32) persists in VMEM scratch across chunks; it is
(re)loaded from ``s0`` at chunk 0 and written out after the last chunk.

Within a chunk (C = 32) the recurrence is evaluated in parallel exactly as
the jnp oracle does: cumulative log-decays, an inter-chunk matmul against
S, a (C, C, K) pairwise-decay intra-chunk term kept in log space (so no
exp overflow — decays ratios are always <= 1), and a rank-C state update.
VMEM: the pair tensor C*C*K*4B = 256 KiB at C=32, K=64 — the budget driver.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, sfin_ref, state_ref, *, chunk: int):
    cb = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(cb == 0)
    def _load():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    rc = r_ref[0, 0].astype(jnp.float32)        # (C, K)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)
    lwc = lw_ref[0, 0].astype(jnp.float32)      # (C, K) log-decay <= 0
    u = u_ref[0].astype(jnp.float32)            # (K,)
    s = state_ref[...]                          # (K, V)

    cum = jnp.cumsum(lwc, axis=0)               # inclusive
    cum_prev = cum - lwc
    # inter-chunk: y += (r * exp(cum_prev)) @ S
    r_dec = rc * jnp.exp(cum_prev)
    y = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, V)
    # intra-chunk pairwise term (log-space decay ratios)
    ddiff = cum_prev[:, None, :] - cum[None, :, :]        # (C, C, K)
    att = jnp.sum(rc[:, None, :] * kc[None, :, :] *
                  jnp.exp(jnp.clip(ddiff, -60.0, 0.0)), axis=-1)  # (C, C)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(mask, att, 0.0)
    y += jax.lax.dot_general(att, vc, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # diagonal bonus: r_t (u . k_t) v_t
    diag = jnp.sum(rc * u[None, :] * kc, axis=-1)         # (C,)
    y += diag[:, None] * vc
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: S' = diag(exp(cum_C)) S + sum_s exp(cum_C - cum_s) k_s v_s
    tail = cum[-1:, :] - cum                               # (C, K) <= 0
    k_dec = kc * jnp.exp(tail)
    state_ref[...] = s * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        k_dec, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(cb == n_c - 1)
    def _store():
        sfin_ref[0, 0] = state_ref[...]


def wkv6_pallas(r, k, v, lw, u, s0, *, chunk: int = 32, interpret=False):
    """r/k/v/lw: (B, H, T, K); u: (H, K); s0: (B, H, K, V) f32.

    Returns (y (B, H, T, K_v), s_fin (B, H, K, V) f32).  T % chunk == 0."""
    b, h, t, kd = r.shape
    vd = s0.shape[-1]
    assert t % chunk == 0, "pad T to a chunk multiple"
    nc = t // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, kd), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, kd), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, vd), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, kd), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, kd), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, kd, vd), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, vd), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, kd, vd), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, vd), r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, vd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, s_fin
