"""Pure-jnp oracle for flash_decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def decode_ref(q, k_cache, v_cache, *, t, window=None, local_block=None):
    """q: (B, H, D); caches: (B, S, KV, D) -> (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kvh
    k = jnp.repeat(k_cache, n_rep, axis=2)
    v = jnp.repeat(v_cache, n_rep, axis=2)
    sc = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / np.sqrt(d)
    slots = jnp.arange(s)
    if window is None and local_block is None:
        kv_pos = slots
        valid = kv_pos <= t
    else:
        kv_pos = t - ((t - slots) % s)
        valid = kv_pos >= 0
        if window is not None:
            valid &= (t - kv_pos) < window
        if local_block is not None:
            valid &= kv_pos >= (t // local_block) * local_block
    sc = jnp.where(valid[None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
