"""Jitted wrapper for flash_decode (interpret on non-TPU backends)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.flash_decode import (
    flash_decode as _kernel, flash_decode_dynamic as _kernel_dyn)
from repro.kernels.flash_decode.ref import decode_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("t", "window", "local_block", "block_k"))
def flash_decode(q, k_cache, v_cache, *, t, window=None, local_block=None,
                 block_k=512):
    return _kernel(q, k_cache, v_cache, t=t, window=window,
                   local_block=local_block, block_k=block_k,
                   interpret=not _on_tpu())


@functools.partial(jax.jit,
                   static_argnames=("window", "local_block", "block_k"))
def flash_decode_at(q, k_cache, v_cache, t, *, window=None, local_block=None,
                    block_k=512):
    """``flash_decode`` with a *traced* position ``t`` (scalar prefetch):
    one compiled executable serves the whole decode loop — the variant
    the serving executor and the model decode path use, since a static
    ``t`` would recompile every token."""
    return _kernel_dyn(q, k_cache, v_cache, t, window=window,
                       local_block=local_block, block_k=block_k,
                       interpret=not _on_tpu())
