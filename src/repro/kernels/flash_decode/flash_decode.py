"""Flash-decoding Pallas TPU kernel: one query token vs a long KV cache.

Grid: (batch, num_kv_blocks) — the kv dim iterates innermost (split-K over
the context); all query heads are processed together per block (decode is
HBM-bandwidth-bound: each cache byte is read exactly once).  Online-softmax
state (m, l, acc) sits in VMEM scratch, sized (H, D) — e.g. 64 heads x 128
x 4 B = 32 KiB.

Ring-cache masking (sliding-window / chunked-local) is supported via the
absolute-position reconstruction  p_i = t - ((t - i) mod W)  used by the
jnp path (`layers.decode_ring_attention`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, t: int, window, local_block,
               block_k: int, kv_len: int, n_rep: int):
    kb = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (H, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, KV, D)
    v = v_ref[0].astype(jnp.float32)

    slots = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k,), 0)
    if window is None and local_block is None:
        kv_pos = slots                                  # linear cache
        valid = kv_pos <= t
    else:
        w = kv_len
        kv_pos = t - ((t - slots) % w)                  # ring cache
        valid = kv_pos >= 0
        if window is not None:
            valid &= (t - kv_pos) < window
        if local_block is not None:
            valid &= kv_pos >= (t // local_block) * local_block
    valid &= slots < kv_len

    # scores: (H, bk) — q head h reads kv head h // n_rep
    k2 = jnp.repeat(k, n_rep, axis=1) if n_rep > 1 else k   # (bk, H, D)
    v2 = jnp.repeat(v, n_rep, axis=1) if n_rep > 1 else v
    sc = jnp.einsum("hd,khd->hk", q, k2,
                    preferred_element_type=jnp.float32)          # (H, bk)
    sc = jnp.where(valid[None, :], sc, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    v2 = jnp.where(valid[:, None, None], v2, 0.0)
    pv = jnp.einsum("hk,khd->hd", p, v2,
                    preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _fd_dyn_kernel(t_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, window, local_block,
                   block_k: int, kv_len: int, n_rep: int):
    """Dynamic-position variant: ``t`` arrives as a scalar-prefetch ref
    (SMEM) instead of a Python int baked into the trace, so one compiled
    executable serves every decode step — the per-token recompile the
    static kernel would force is exactly what the serving executor's
    compile cache must never see."""
    t = t_ref[0]
    kb = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (H, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, KV, D)
    v = v_ref[0].astype(jnp.float32)

    slots = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k,), 0)
    if window is None and local_block is None:
        kv_pos = slots                                  # linear cache
        valid = kv_pos <= t
    else:
        w = kv_len
        kv_pos = t - ((t - slots) % w)                  # ring cache
        valid = kv_pos >= 0
        if window is not None:
            valid &= (t - kv_pos) < window
        if local_block is not None:
            valid &= kv_pos >= (t // local_block) * local_block
    valid &= slots < kv_len

    k2 = jnp.repeat(k, n_rep, axis=1) if n_rep > 1 else k   # (bk, H, D)
    v2 = jnp.repeat(v, n_rep, axis=1) if n_rep > 1 else v
    sc = jnp.einsum("hd,khd->hk", q, k2,
                    preferred_element_type=jnp.float32)          # (H, bk)
    sc = jnp.where(valid[None, :], sc, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    v2 = jnp.where(valid[:, None, None], v2, 0.0)
    pv = jnp.einsum("hk,khd->hd", p, v2,
                    preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_dynamic(q, k_cache, v_cache, t, *, window=None,
                         local_block=None, block_k=512, interpret=False):
    """Like :func:`flash_decode`, but ``t`` is a traced int32 scalar
    delivered via scalar prefetch — jit once, decode every position.

    q: (B, H, D); caches: (B, S, KV, D); t: int32 array (any 0/1-d shape).
    Returns (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kvh
    block_k = min(block_k, s)
    nk = pl.cdiv(s, block_k)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _fd_dyn_kernel, scale=scale, window=window, local_block=local_block,
        block_k=block_k, kv_len=s, n_rep=n_rep)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, j, t_: (b_, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, d), lambda b_, j, t_: (b_, j, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, d), lambda b_, j, t_: (b_, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, j, t_: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
        ],
    )
    t_arr = jnp.reshape(jnp.asarray(t, jnp.int32), (1,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(t_arr, q, k_cache, v_cache)


def flash_decode(q, k_cache, v_cache, *, t, window=None, local_block=None,
                 block_k=512, interpret=False):
    """q: (B, H, D); caches: (B, S, KV, D); t: python int (current position).

    Returns (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    n_rep = h // kvh
    block_k = min(block_k, s)
    nk = pl.cdiv(s, block_k)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _fd_kernel, scale=scale, t=t, window=window, local_block=local_block,
        block_k=block_k, kv_len=s, n_rep=n_rep)

    return pl.pallas_call(
        kernel,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, j: (b_, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, d), lambda b_, j: (b_, j, 0, 0)),
            pl.BlockSpec((1, block_k, kvh, d), lambda b_, j: (b_, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, j: (b_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, d), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache)
