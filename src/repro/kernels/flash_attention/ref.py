"""Pure-jnp oracle for the flash-attention kernel (naive materialised)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, local_block=None,
                  q_offset=0):
    """q: (B, H, Sq, D); k/v: (B, KV, Skv, D).  fp32 softmax, full scores."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    n_rep = h // kvh
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    q_pos = q_offset + jnp.arange(sq)
    kv_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    if local_block is not None:
        mask &= (q_pos[:, None] // local_block) == (kv_pos[None, :] // local_block)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
