"""Jitted wrapper: model-layout adapter + CPU interpret fallback.

The model passes (B, S, H, D) activations; the kernel wants heads-major.
On non-TPU backends the kernel body runs under ``interpret=True`` (Python
emulation — correctness only).  ``use_kernel=False`` falls back to the
oracle entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "local_block", "q_offset"))
def flash_attention(q, k, v, *, causal=True, window=None, local_block=None,
                    q_offset=0):
    """q: (B, S, H, D); k/v: (B, S, KV, D) -> (B, S, H, D)."""
    qT = jnp.swapaxes(q, 1, 2)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(qT, kT, vT, causal=causal, window=window,
                              local_block=local_block, q_offset=q_offset,
                              interpret=not _on_tpu())
    return jnp.swapaxes(out, 1, 2)


def flash_attention_oracle(q, k, v, **kw):
    qT = jnp.swapaxes(q, 1, 2)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    return jnp.swapaxes(attention_ref(qT, kT, vT, **kw), 1, 2)
