"""Flash-attention (forward) Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv dim iterates
innermost so the online-softmax state (m, l, acc) lives in VMEM scratch and
carries across kv blocks.  GQA is handled in the k/v index_maps (q head h
reads kv head h // n_rep).  Causal / sliding-window / chunked-local masks
are applied per block; fully-masked blocks skip their matmuls via
``pl.when``.

Block sizes default to (128, 128): MXU-aligned (lane = 128) with the fp32
scratch well inside VMEM: acc 128xD x4B + q/k/v blocks ~= a few hundred KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window, local_block,
               q_offset: int, block_q: int, block_k: int, kv_len: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kv_pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level reachability: skip matmuls of fully-masked tiles
    run = jnp.asarray(True)
    mask = kv_pos < kv_len
    if causal:
        run &= q_offset + (qb + 1) * block_q - 1 >= kb * block_k
        mask &= q_pos >= kv_pos
    if window is not None:
        run &= q_offset + qb * block_q < (kb + 1) * block_k + window
        mask &= q_pos - kv_pos < window
    if local_block is not None:
        run &= ((q_offset + (qb + 1) * block_q - 1) // local_block
                >= (kb * block_k) // local_block)
        run &= ((q_offset + qb * block_q) // local_block
                <= ((kb + 1) * block_k - 1) // local_block)
        mask &= (q_pos // local_block) == (kv_pos // local_block)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        # zero the tail padding: 0 x garbage = NaN otherwise
        kv_valid = (kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < kv_len
        v = jnp.where(kv_valid, v, 0.0)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=None,
                        local_block=None, q_offset=0,
                        block_q=128, block_k=128, interpret=False):
    """q: (B, H, Sq, D); k/v: (B, KV, Skv, D).  Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    n_rep = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        local_block=local_block, q_offset=q_offset,
        block_q=block_q, block_k=block_k, kv_len=skv)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j: (b_, h_ // n_rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, i, j: (b_, h_ // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
