"""Shared neural-net layers for the model zoo.

Everything is pure-functional JAX (params passed explicitly) so that models
compose under ``jax.lax.scan`` over stacked layer weights and lower cleanly
under pjit on arbitrary meshes.

Attention comes in three flavours:
  * ``chunked_attention``  — flash-style blockwise causal attention (the jnp
    oracle of the Pallas kernel) used for train/prefill shapes.  Memory is
    O(S * chunk) instead of O(S^2).
  * ``decode_attention``   — single-token attention against a (possibly
    sequence-sharded) KV cache.
  * sliding-window / chunked-local variants via ``window`` masking on a ring
    cache (sub-quadratic decode).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
NEG_INF = -1e30


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax.shard_map(check_vma=) on new jax,
    jax.experimental.shard_map.shard_map(check_rep=) on older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def constrain(x: Array, opts, pattern: tuple) -> Array:
    """with_sharding_constraint helper.  pattern entries: 'B' (batch/dp axes),
    'M' (model/TP axis), None.  No-op unless opts.shard_constraints."""
    if opts is None or not getattr(opts, "shard_constraints", False) \
            or opts.dp_spec is None:
        return x
    # dp_only mode: 'model' carries batch; 'M' entries collapse to None
    tp = opts.tp_name if opts.tp_name not in tuple(opts.dp_spec) else None
    spec = jax.sharding.PartitionSpec(
        *[tuple(opts.dp_spec) if e == "B" else
          (tp if e == "M" else None) for e in pattern])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + 0.0) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x: Array, norm_params: dict[str, Array], kind: str) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, norm_params["scale"])
    return layer_norm(x, norm_params["scale"], norm_params["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D) ; positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (flash-style chunked oracle; also the ref for the Pallas kernel)
# ---------------------------------------------------------------------------
def _expand_kv(k: Array, n_rep: int) -> Array:
    """(B, S, KV, D) -> (B, S, KV * n_rep, D) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d))
    return k.reshape(b, s, kv * n_rep, d)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    local_block: int | None = None,
    chunk: int = 1024,
    q_offset: int = 0,
) -> Array:
    """Blockwise (flash-style) attention.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D) with H % KV == 0.
    ``window``: sliding-window size (None = full causal).
    Memory: O(Sq * chunk) per head.  Computes all (q-chunk, kv-chunk) pairs;
    masked pairs cost FLOPs but no memory (see EXPERIMENTS §Perf for the
    triangular-pair optimisation).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    n_rep = h // k.shape[2]
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)

    scale = 1.0 / np.sqrt(d)
    chunk = min(chunk, skv)
    n_chunks = skv // chunk
    rem = skv - n_chunks * chunk

    q_pos = q_offset + jnp.arange(sq)

    qf = (q * scale).astype(q.dtype)

    def attend_block(carry, inputs):
        acc, m_run, l_run = carry
        k_blk, v_blk, kv_start = inputs
        # scores: (B, H, Sq, C)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_blk,
                       preferred_element_type=jnp.float32)
        kv_pos = kv_start + jnp.arange(k_blk.shape[1])
        mask = jnp.ones((sq, k_blk.shape[1]), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        if local_block is not None:
            mask &= (q_pos[:, None] // local_block) == (kv_pos[None, :] // local_block)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)

    if n_chunks > 0:
        ks = k[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, h, d)
        vs = v[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, h, d)
        ks = jnp.moveaxis(ks, 1, 0)
        vs = jnp.moveaxis(vs, 1, 0)
        starts = jnp.arange(n_chunks) * chunk
        (acc0, m0, l0), _ = jax.lax.scan(
            attend_block, (acc0, m0, l0), (ks, vs, starts))
    if rem:
        (acc0, m0, l0), _ = attend_block(
            (acc0, m0, l0),
            (k[:, n_chunks * chunk:], v[:, n_chunks * chunk:],
             jnp.asarray(n_chunks * chunk)),
        )

    out = acc0 / jnp.maximum(l0[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, D)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    *,
    length: Array | int,
) -> Array:
    """One-token attention.  q: (B, 1, H, D); caches: (B, S, KV, D).

    ``length`` — number of valid cache entries.  The cache sequence dim may be
    sharded (long-context decode); softmax reductions then lower to
    all-reduces under GSPMD (flash-decode-style combine).
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    n_rep = h // k_cache.shape[2]
    k = _expand_kv(k_cache, n_rep)
    v = _expand_kv(v_cache, n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / np.sqrt(d)
    valid = jnp.arange(s)[None, None, None, :] < jnp.asarray(length).reshape(-1, 1, 1, 1)
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def decode_ring_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    *,
    t: Array,
    window: int | None = None,
    local_block: int | None = None,
) -> Array:
    """One-token attention over a ring cache.

    q: (B, 1, H, D); caches: (B, W, KV, D).  ``t`` = current position (the new
    token's position; cache holds positions <= t).  Ring slot i holds absolute
    position  p_i = t - ((t - i) mod W)  (-ve => not yet written).
    """
    b, _, h, d = q.shape
    w = k_cache.shape[1]
    n_rep = h // k_cache.shape[2]
    k = _expand_kv(k_cache, n_rep)
    v = _expand_kv(v_cache, n_rep)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / np.sqrt(d)
    i = jnp.arange(w)
    kv_pos = t - ((t - i) % w)                 # (W,) absolute positions
    mask = kv_pos >= 0
    if window is not None:
        mask &= (t - kv_pos) < window
    if local_block is not None:
        mask &= kv_pos >= (t // local_block) * local_block
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------
def swiglu_mlp(x: Array, w1: Array, w2: Array, w3: Array) -> Array:
    """LLaMA-style gated MLP.  w1/w2: (D, F); w3: (F, D).  The row-parallel
    w3 dot emits the activation dtype directly so the TP partial-sum
    all-reduce runs in bf16, not the f32 accumulator (EXPERIMENTS §Perf)."""
    h = jnp.einsum("bsd,df->bsf", x, w1) * jax.nn.silu(
        jnp.einsum("bsd,df->bsf", x, w2))
    return jnp.einsum("bsf,fd->bsd", h, w3, preferred_element_type=x.dtype)


def explicit_tp_swiglu(x: Array, w1: Array, w2: Array, w3: Array,
                       opts) -> Array:
    """SwiGLU with *explicit* TP collectives via shard_map (§Perf P5).

    GSPMD reduces the row-parallel partial sums on the dot's f32
    excess-precision accumulator (P0: dtype hints refuted) and re-gathers
    the FSDP weight shards in whatever dtype it meets.  Here the FFN runs
    per TP shard: weights are all-gathered over 'data' in bf16, the local
    dot output stays bf16 into an explicit psum over 'model' — halving
    both collective families.  Differentiable (shard_map AD:
    psum <-> identity, all_gather <-> psum_scatter)."""
    mesh = opts.mesh
    tp = opts.tp_name
    fsdp = "data"

    def local_fn(x, w1, w2, w3):
        # weight blocks arrive (D/|data|, F/|model|): un-FSDP in bf16
        w1 = jax.lax.all_gather(w1, fsdp, axis=0, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp, axis=0, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp, axis=1, tiled=True)
        h = jnp.einsum("bsd,df->bsf", x, w1) * jax.nn.silu(
            jnp.einsum("bsd,df->bsf", x, w2))
        y = jnp.einsum("bsf,fd->bsd", h, w3,
                       preferred_element_type=x.dtype)
        return jax.lax.psum(y, tp)

    P = jax.sharding.PartitionSpec
    b = tuple(opts.dp_spec) if opts.dp_spec else None
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(b, None, None), P(fsdp, tp), P(fsdp, tp), P(tp, fsdp)),
        out_specs=P(b, None, None))
    return fn(x, w1, w2, w3)


def explicit_tp_matmul(x: Array, w: Array, opts, *, row: bool) -> Array:
    """Column-/row-parallel projection with explicit bf16 collectives.

    Shards the *flattened* feature dim (always divisible by |model|, unlike
    head counts), all-gathers the FSDP weight shard in bf16, and row mode
    psums the bf16 partial outputs (GSPMD would reduce the f32
    excess-precision accumulator — §Perf P0/P5).  AD: dw reduces via
    psum_scatter over 'data' (bf16 ZeRO-grad), dx stays local (row) /
    psums bf16 (col)."""
    mesh, tp, fsdp = opts.mesh, opts.tp_name, "data"
    P = jax.sharding.PartitionSpec
    b = tuple(opts.dp_spec) if opts.dp_spec else None
    if row:   # x: (B,S,K) K sharded over tp; w: (K,N) P(tp, fsdp)
        def f(x, w):
            w = jax.lax.all_gather(w, fsdp, axis=1, tiled=True)
            y = jnp.einsum("bsk,kn->bsn", x, w,
                           preferred_element_type=x.dtype)
            return jax.lax.psum(y, tp)
        return shard_map(f, mesh=mesh,
                     in_specs=(P(b, None, tp), P(tp, fsdp)),
                     out_specs=P(b, None, None))(x, w)
    # column: x replicated over tp; w: (K,N) P(fsdp, tp) -> out tp-sharded
    def f(x, w):
        w = jax.lax.all_gather(w, fsdp, axis=0, tiled=True)
        return jnp.einsum("bsk,kn->bsn", x, w,
                          preferred_element_type=x.dtype)
    return shard_map(f, mesh=mesh,
                     in_specs=(P(b, None, None), P(fsdp, tp)),
                     out_specs=P(b, None, tp))(x, w)


def gelu_mlp(x: Array, w1: Array, b1: Array, w3: Array, b3: Array) -> Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w1) + b1)
    return jnp.einsum("bsf,fd->bsd", h, w3,
                      preferred_element_type=x.dtype) + b3


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------
def dense_init(key: Array, shape: tuple[int, ...], dtype=jnp.bfloat16,
               scale: float | None = None) -> Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key: Array, n: int) -> list[Array]:
    return list(jax.random.split(key, n))
