"""KV-cache structures.

Three cache layouts, chosen per architecture:
  * full ring-less cache   (B, S_max, KV, DH) per layer — full causal attention.
  * ring cache             (B, W, KV, DH) — sliding-window (mixtral, hymba) and
    chunked-local (llama4 local layers).  ``positions`` (B, W) records absolute
    positions so masks can be recovered after wrap-around.
  * SSM state              (B, H, K, V) + token-shift states — RWKV6 / hymba.

All per-layer caches are stacked on a leading layer axis so the layer loop is a
single ``lax.scan`` with the cache as scanned-over xs/ys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cache_len(cfg, shape_kind_max_len: int, kind: str) -> int:
    """Physical cache length for a layer kind given logical max context."""
    if kind == "local" and cfg.chunk_attn:
        return min(cfg.chunk_attn, shape_kind_max_len)
    if cfg.window is not None:
        return min(cfg.window, shape_kind_max_len)
    return shape_kind_max_len


def ring_slots(pos0: Array | int, n: int, width: int) -> Array:
    """Physical slots for logical positions pos0..pos0+n-1 in a ring of width."""
    return (pos0 + jnp.arange(n)) % width


def ring_write(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array,
               pos0: Array | int) -> tuple[Array, Array]:
    """Write S_new entries at logical positions pos0.. into ring caches.

    k_cache: (B, W, KV, DH); k_new: (B, S_new, KV, DH).  If S_new >= W only the
    last W entries survive (handled by the modular scatter: later writes win —
    we pre-truncate to the last W entries to keep scatter deterministic).
    """
    w = k_cache.shape[1]
    s_new = k_new.shape[1]
    if s_new >= w:
        # keep only last W entries
        start = s_new - w
        k_new = jax.lax.dynamic_slice_in_dim(k_new, start, w, axis=1)
        v_new = jax.lax.dynamic_slice_in_dim(v_new, start, w, axis=1)
        pos0 = pos0 + start
        s_new = w
    slots = ring_slots(pos0, s_new, w)  # (S_new,)
    k_cache = k_cache.at[:, slots].set(k_new)
    v_cache = v_cache.at[:, slots].set(v_new)
    return k_cache, v_cache


def ring_positions(pos_array: Array, pos0: Array | int, n: int) -> Array:
    """Update the shared (B-agnostic) position map (W,) int32."""
    w = pos_array.shape[0]
    if n >= w:
        start = n - w
        pos0 = pos0 + start
        n = w
    slots = ring_slots(pos0, n, w)
    return pos_array.at[slots].set(pos0 + jnp.arange(n))
