"""Unified model facade over the zoo families.

``Model(cfg, opts)`` exposes the step functions consumed by the launcher,
dry-run, serving layer and tests:

    loss(params, batch)                -> scalar           (train)
    prefill(params, batch)             -> (logits, cache)  (inference-prefill)
    decode(params, cache, tokens)      -> (logits, cache)  (decode)
    param_specs() / init(key)
    cache_specs(batch, max_len) / init_cache(batch, max_len)
    input_specs(shape) / dummy_inputs(shape, key)

Batches are dicts: {"tokens": (B, S) int32, "labels": (B, S) int32,
["prefix_embeds": (B, P, D)]}.  Modality frontends are stubs per the
assignment spec: ``input_specs`` provides precomputed patch/frame embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig, ShapeSpec
from repro.models import transformer, rwkv6, hymba
from repro.models.transformer import RunOptions

Array = jax.Array


def _family_module(cfg: ModelConfig):
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return hymba
    return transformer  # dense | moe | vlm | audio


class Model:
    def __init__(self, cfg: ModelConfig, opts: RunOptions = RunOptions()):
        self.cfg = cfg
        self.opts = opts
        self._m = _family_module(cfg)

    # ---- params -----------------------------------------------------------
    def param_specs(self):
        return self._m.param_specs(self.cfg, self.opts)

    def init(self, key: Array):
        return self._m.init_params(self.cfg, key, self.opts)

    # ---- steps ------------------------------------------------------------
    def loss(self, params, batch):
        return self._m.lm_loss(self.cfg, params, batch["tokens"],
                               batch["labels"],
                               batch.get("prefix_embeds"), opts=self.opts)

    def forward(self, params, batch):
        return self._m.forward(self.cfg, params, batch["tokens"],
                               batch.get("prefix_embeds"), self.opts, "train")

    def prefill(self, params, batch, max_len: Optional[int] = None):
        kw = {}
        if self._m is transformer:
            kw["max_len"] = max_len
        return self._m.forward(self.cfg, params, batch["tokens"],
                               batch.get("prefix_embeds"), self.opts,
                               "prefill", **kw)

    def decode(self, params, cache, tokens):
        return self._m.decode_step(self.cfg, params, cache, tokens, self.opts)

    # ---- caches -----------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        return self._m.cache_specs(self.cfg, batch, max_len, self.opts)

    def init_cache(self, batch: int, max_len: int):
        return self._m.init_cache(self.cfg, batch, max_len, self.opts)

    # ---- inputs -----------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        b = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        s = shape.seq_len
        specs = {}
        if cfg.frontend == "vit" and cfg.n_prefix:
            s_tok = s - cfg.n_prefix
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix, cfg.d_model), self.opts.act_dtype)
        else:
            s_tok = s
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
        return specs

    def dummy_inputs(self, shape: ShapeSpec, key: Array) -> dict:
        specs = self.input_specs(shape)
        out = {}
        for name, s in specs.items():
            key, k = jax.random.split(key)
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k, s.shape, 0, self.cfg.vocab,
                                               jnp.int32)
            else:
                out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
        return out


def get_model(cfg: ModelConfig, opts: RunOptions = RunOptions()) -> Model:
    return Model(cfg, opts)
