"""Hymba [arXiv:2411.13676] — hybrid-head architecture: every layer runs a
sliding-window GQA attention branch and a Mamba-style selective-SSM branch in
*parallel* over the same input, fusing their (per-branch normalised) outputs.

Simplifications vs the released checkpoint (noted in DESIGN §4): all layers
use SWA (the 3 full-attention layers of the release are dropped to keep the
layer stack scan-homogeneous — required for the long_500k sub-quadratic
claim anyway); meta-tokens and the Mamba depthwise conv are omitted.

SSM recurrence (state N = 16 per channel):
    h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t x_t) ⊗ B_t
    y_t = C_t · h_t + D_skip ⊙ x_t
evaluated chunk-parallel with an associative scan inside chunks of 256.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ModelConfig
from repro.models import layers as L
from repro.models import kvcache

Array = jax.Array
SSM_CHUNK = 256


def _layer_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f, n = cfg.d_model, cfg.d_ff, cfg.ssm_state
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        # attention branch
        "wq": (d, h * dh), "wk": (d, kv * dh), "wv": (d, kv * dh),
        "wo_attn": (h * dh, d),
        # mamba branch (d_inner = d)
        "w_in": (d, 2 * d),                       # -> (x_m, z)
        "w_dt": (d, d), "b_dt": (d,),
        "w_B": (d, n), "w_C": (d, n),
        "a_log": (d, n), "d_skip": (d,),
        "w_out": (d, d),
        # fusion norms
        "fuse_attn_scale": (d,), "fuse_ssm_scale": (d,),
        # pre-norms + mlp
        "ln1_scale": (d,), "ln2_scale": (d,),
        "w1": (d, f), "w2": (d, f), "w3": (f, d),
    }


def param_specs(cfg: ModelConfig, opts) -> dict:
    pd = opts.param_dtype
    lp = {k: jax.ShapeDtypeStruct((cfg.n_layers,) + s, pd)
          for k, s in _layer_param_shapes(cfg).items()}
    return {
        "layers": lp,
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd),
        "final_norm_scale": jax.ShapeDtypeStruct((cfg.d_model,), pd),
        "lm_head": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd),
    }


def init_params(cfg: ModelConfig, key: Array, opts) -> dict:
    specs = param_specs(cfg, opts)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for (path, spec), kk in zip(flat, keys):
        name = path[-1].key
        if "scale" in name:
            arr = jnp.ones(spec.shape, spec.dtype)
        elif name in ("b_dt", "d_skip"):
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif name == "a_log":
            arr = jnp.log(jnp.broadcast_to(
                jnp.arange(1, spec.shape[-1] + 1, dtype=jnp.float32),
                spec.shape)).astype(spec.dtype)
        else:
            arr = L.dense_init(kk, spec.shape, spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(jax.tree.structure(specs), out)


# ---------------------------------------------------------------------------
# Selective SSM (chunked associative scan)
# ---------------------------------------------------------------------------
def ssm_scan(xm: Array, dt: Array, b_in: Array, c_in: Array, a_log: Array,
             d_skip: Array, h0: Array, chunk: int = SSM_CHUNK):
    """xm/dt: (B,T,D); b_in/c_in: (B,T,N); h0: (B,D,N) f32.

    Returns (y (B,T,D), h_fin).
    """
    b, t, d = xm.shape
    n = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))                  # (D, N) < 0
    chunk = min(chunk, t)
    nc = t // chunk
    tm = nc * chunk

    dt32 = dt.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)                        # (B,T,D,N) decay
    dbx = (dt32 * xm.astype(jnp.float32))[..., :, None] * \
        b_in.astype(jnp.float32)[..., None, :]               # (B,T,D,N)

    def chunk_step(h, inp):
        da_c, dbx_c, c_c = inp                               # (B,C,D,N),(B,C,N)
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        aa, bb = jax.lax.associative_scan(combine, (da_c, dbx_c), axis=1)
        h_all = aa * h[:, None] + bb                         # (B,C,D,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_c.astype(jnp.float32))
        return h_all[:, -1], y

    da_s = jnp.moveaxis(da[:, :tm].reshape(b, nc, chunk, d, n), 1, 0)
    dbx_s = jnp.moveaxis(dbx[:, :tm].reshape(b, nc, chunk, d, n), 1, 0)
    c_s = jnp.moveaxis(c_in[:, :tm].reshape(b, nc, chunk, n), 1, 0)
    h_fin, ys = jax.lax.scan(chunk_step, h0, (da_s, dbx_s, c_s))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tm, d)
    if tm < t:  # remainder chunk
        h_fin, y_rem = chunk_step(h_fin, (da[:, tm:], dbx[:, tm:], c_in[:, tm:]))
        y = jnp.concatenate([y, y_rem], axis=1)
    y = y + d_skip.astype(jnp.float32) * xm.astype(jnp.float32)
    return y.astype(xm.dtype), h_fin


def ssm_step(xm, dt, b_in, c_in, a_log, d_skip, h):
    """Single token. xm/dt: (B,1,D); b_in/c_in: (B,1,N); h: (B,D,N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dt32 = dt[:, 0].astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)
    dbx = (dt32 * xm[:, 0].astype(jnp.float32))[..., None] * \
        b_in[:, 0].astype(jnp.float32)[:, None, :]
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0].astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32) * xm[:, 0].astype(jnp.float32)
    return y[:, None].astype(xm.dtype), h


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------
def _mamba_branch(cfg, w, x, h0, mode, opts=None):
    xz = jnp.einsum("btd,de->bte", x, w["w_in"])
    xm, z = jnp.split(xz, 2, axis=-1)
    xm = L.constrain(xm, opts, ("B", None, "M"))
    dt = jax.nn.softplus(jnp.einsum("btd,de->bte", xm, w["w_dt"]) + w["b_dt"])
    b_in = jnp.einsum("btd,dn->btn", xm, w["w_B"])
    c_in = jnp.einsum("btd,dn->btn", xm, w["w_C"])
    if mode == "decode":
        y, h = ssm_step(xm, dt, b_in, c_in, w["a_log"], w["d_skip"], h0)
    else:
        y, h = ssm_scan(xm, dt, b_in, c_in, w["a_log"], w["d_skip"], h0)
    out = jnp.einsum("btd,de->bte", y * jax.nn.silu(z), w["w_out"])
    return out, h


def _attn_branch(cfg, w, x, kv_cache, t, mode, opts):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if mode == "decode":
        positions = t[None]
    else:
        positions = jnp.arange(s)
    q = jnp.einsum("bsd,dh->bsh", x, w["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,dh->bsh", x, w["wk"]).reshape(b, s, kv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, w["wv"]).reshape(b, s, kv, dh)
    q = L.constrain(q, opts, ("B", None, "M", None))
    k = L.constrain(k, opts, ("B", None, "M", None))
    v = L.constrain(v, opts, ("B", None, "M", None))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if mode == "decode":
        kc, vc = kv_cache
        wsize = kc.shape[1]
        slot = t % wsize
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        o = L.decode_ring_attention(q, kc, vc, t=t, window=cfg.window)
        new_kv = (kc, vc)
    else:
        o = L.chunked_attention(q, k, v, causal=True, window=cfg.window,
                                chunk=opts.attn_chunk)
        new_kv = (k, v)
    o = o.reshape(b, s, h * dh)
    return jnp.einsum("bsh,hd->bsd", o, w["wo_attn"]), new_kv


def layer(cfg, w, x, state, mode, opts):
    hpre = L.rms_norm(x, w["ln1_scale"])
    attn_out, new_kv = _attn_branch(cfg, w, hpre, state.get("kv"), state.get("t"),
                                    mode, opts)
    ssm_out, h_fin = _mamba_branch(cfg, w, hpre, state["ssm"], mode, opts)
    fused = 0.5 * (L.rms_norm(attn_out, w["fuse_attn_scale"]) +
                   L.rms_norm(ssm_out, w["fuse_ssm_scale"]))
    x = L.constrain(x + fused, opts, ("B", None, None))
    h2 = L.rms_norm(x, w["ln2_scale"])
    x = L.constrain(x + L.swiglu_mlp(h2, w["w1"], w["w2"], w["w3"]),
                    opts, ("B", None, None))
    return x, new_kv, h_fin


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int, opts) -> dict:
    kv, dh, d, n = cfg.n_kv_heads, cfg.d_head, cfg.d_model, cfg.ssm_state
    ls = cfg.n_layers
    w = kvcache.cache_len(cfg, max_len, "window")
    return {
        "t": jax.ShapeDtypeStruct((), jnp.int32),
        "k": jax.ShapeDtypeStruct((ls, batch, w, kv, dh), opts.act_dtype),
        "v": jax.ShapeDtypeStruct((ls, batch, w, kv, dh), opts.act_dtype),
        "ssm": jax.ShapeDtypeStruct((ls, batch, d, n), jnp.float32),
    }


def init_cache(cfg, batch, max_len, opts):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, opts))


def _stack(cfg, params, x, cache, mode, opts):
    t = cache["t"] if mode == "decode" else None

    def body(x, scanned):
        w, kc, vc, ssm = scanned
        def run(x, w, kc, vc, ssm):
            state = {"kv": (kc, vc), "ssm": ssm, "t": t}
            return layer(cfg, w, x, state, mode, opts)
        if opts.remat == "full" and mode != "decode":
            run = jax.checkpoint(run,
                                 policy=jax.checkpoint_policies.nothing_saveable)
        x, (nk, nv), h_fin = run(x, w, kc, vc, ssm)
        return x, (nk, nv, h_fin)

    xs = (params["layers"], cache["k"], cache["v"], cache["ssm"])
    x, (ks, vs, ssm) = jax.lax.scan(body, x, xs)
    return x, {"k": ks, "v": vs, "ssm": ssm}


def forward(cfg, params, tokens, prefix_embeds=None, opts=None, mode="train",
            cache=None):
    b, s = tokens.shape
    x = L.constrain(params["embed"][tokens].astype(opts.act_dtype),
                    opts, ("B", None, None))
    if cache is None:
        cache = init_cache(cfg, b, s, opts)
        # full-seq path writes fresh k/v; ring packing happens below
        cache["k"] = jnp.zeros((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head),
                               opts.act_dtype)
        cache["v"] = cache["k"]
    x, new_state = _stack(cfg, params, x, cache, "full_seq", opts)
    x = L.rms_norm(x, params["final_norm_scale"])
    if mode == "hidden":
        return x, 0.0
    if mode == "train":
        logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)
        return logits, 0.0
    # prefill: pack ring caches (last W positions at slots pos % W)
    w = kvcache.cache_len(cfg, s, "window")
    ks, vs = new_state["k"], new_state["v"]     # (L, B, S, KV, DH)
    if w < s:
        ks = ks[:, :, s - w:]
        vs = vs[:, :, s - w:]
        shift = (s - w) % w
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_cache = {"t": jnp.asarray(s, jnp.int32), "k": ks, "v": vs,
                 "ssm": new_state["ssm"]}
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, opts):
    x = params["embed"][tokens[:, :1]].astype(opts.act_dtype)
    x, new_state = _stack(cfg, params, x, cache, "decode", opts)
    x = L.rms_norm(x, params["final_norm_scale"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_state["t"] = cache["t"] + 1
    return logits[:, 0], new_state


def lm_loss(cfg, params, tokens, labels, prefix_embeds=None, opts=None):
    from repro.models.transformer import chunked_lm_loss
    x, _ = forward(cfg, params, tokens, None, opts, "hidden")
    return chunked_lm_loss(x, params["lm_head"], labels, opts)
