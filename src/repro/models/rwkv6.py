"""RWKV6 "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.

Time-mix uses the WKV6 recurrence per head (K = V = 64):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (decay then write)
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)      (read pre-update + bonus)

with per-channel data-dependent decay  w_t = exp(-exp(w0 + lora_w(x_t))).

Prefill/train evaluates the recurrence chunk-parallel (chunk = 32): the
intra-chunk term is computed with an explicit (t, s, k) pair tensor so decay
differences stay in log space (numerically safe — no exp(+big)); the
inter-chunk term and the state update are matmuls.  The Pallas kernel
(`repro.kernels.rwkv6`) implements the same math with VMEM tiling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ModelConfig
from repro.models import layers as L

Array = jax.Array
LORA_MIX = 32       # rank of the ddlerp mix lora
LORA_DECAY = 64     # rank of the decay lora
WKV_CHUNK = 32


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _layer_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f = cfg.d_model, cfg.d_ff
    h, k = cfg.n_heads, cfg.d_head
    return {
        # time-mix
        "mu_x": (d,), "mu_rkvwg": (5, d),
        "wmix_a": (d, 5 * LORA_MIX), "wmix_b": (5, LORA_MIX, d),
        "w0": (d,), "wdec_a": (d, LORA_DECAY), "wdec_b": (LORA_DECAY, d),
        "u": (h, k),
        "wr": (d, d), "wk": (d, d), "wv": (d, d), "wg": (d, d), "wo": (d, d),
        "ln1_scale": (d,), "ln1_bias": (d,),
        "gn_scale": (d,), "gn_bias": (d,),
        # channel-mix
        "mu_ck": (d,), "mu_cr": (d,),
        "wck": (d, f), "wcv": (f, d), "wcr": (d, d),
        "ln2_scale": (d,), "ln2_bias": (d,),
    }


def param_specs(cfg: ModelConfig, opts) -> dict:
    pd = opts.param_dtype
    lp = {k: jax.ShapeDtypeStruct((cfg.n_layers,) + s, pd)
          for k, s in _layer_param_shapes(cfg).items()}
    return {
        "layers": lp,
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd),
        "final_norm_scale": jax.ShapeDtypeStruct((cfg.d_model,), pd),
        "final_norm_bias": jax.ShapeDtypeStruct((cfg.d_model,), pd),
        "lm_head": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd),
    }


def init_params(cfg: ModelConfig, key: Array, opts) -> dict:
    specs = param_specs(cfg, opts)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for (path, spec), kk in zip(flat, keys):
        name = path[-1].key
        if "scale" in name:
            arr = jnp.ones(spec.shape, spec.dtype)
        elif "bias" in name or name.startswith("mu") or name == "w0":
            arr = jnp.zeros(spec.shape, spec.dtype)
            if name == "w0":   # decay init ~ -5..-0.5 pre-double-exp
                arr = jnp.full(spec.shape, -1.0, spec.dtype)
        elif name == "u":
            arr = jnp.zeros(spec.shape, spec.dtype)
        else:
            arr = L.dense_init(kk, spec.shape, spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(jax.tree.structure(specs), out)


# ---------------------------------------------------------------------------
# WKV6 chunked recurrence
# ---------------------------------------------------------------------------
def wkv6_chunked(r, k, v, lw, u, s0, chunk: int = WKV_CHUNK):
    """Chunk-parallel WKV6.

    r/k/v: (B, T, H, K); lw: (B, T, H, K) log-decay (<= 0); u: (H, K);
    s0: (B, H, K, V) f32.  Returns (y (B,T,H,K_v), s_final).
    """
    b, t, h, kd = r.shape
    chunk = min(chunk, t)
    nc = t // chunk
    tm = nc * chunk           # main part; remainder handled after the scan
    rs = r[:, :tm].reshape(b, nc, chunk, h, kd)
    ks = k[:, :tm].reshape(b, nc, chunk, h, kd)
    vs = v[:, :tm].reshape(b, nc, chunk, h, kd)
    lws = lw[:, :tm].reshape(b, nc, chunk, h, kd).astype(jnp.float32)

    def chunk_step(s, inp):
        rc, kc, vc, lwc = inp               # (B, C, H, K)
        rc32 = rc.astype(jnp.float32)
        kc32 = kc.astype(jnp.float32)
        vc32 = vc.astype(jnp.float32)
        cum = jnp.cumsum(lwc, axis=1)       # inclusive Σ_{τ<=t} lw
        cum_prev = cum - lwc                # Σ_{τ<=t-1}
        # inter-chunk: y_t += (r_t * exp(cum_prev_t)) @ S
        r_dec = rc32 * jnp.exp(cum_prev)
        y = jnp.einsum("bchk,bhkv->bchv", r_dec, s)
        # intra-chunk (t > s): pairwise log-space decay differences
        ddiff = cum_prev[:, :, None] - cum[:, None, :]      # (B, C, C, H, K)
        att = jnp.einsum("bthk,bshk,btshk->btsh",
                         rc32, kc32, jnp.exp(jnp.clip(ddiff, -60.0, 0.0)))
        c = rc.shape[1]
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(mask[None, :, :, None], att, 0.0)
        y = y + jnp.einsum("btsh,bshv->bthv", att, vc32)
        # diagonal bonus term: r_t (u ⊙ k_t) v_t
        diag = jnp.einsum("bthk,hk,bthk->bth", rc32, u.astype(jnp.float32), kc32)
        y = y + diag[..., None] * vc32
        # state update: S' = diag(exp(cum_C)) S + Σ_s exp(cum_C - cum_s) k_s v_s
        tail = cum[:, -1:, :] - cum                          # (B, C, H, K) >= 0? no: <=0
        k_dec = kc32 * jnp.exp(tail)
        s = s * jnp.exp(cum[:, -1])[:, :, :, None] + jnp.einsum(
            "bshk,bshv->bhkv", k_dec, vc32)
        return s, y

    xs = (jnp.moveaxis(rs, 1, 0), jnp.moveaxis(ks, 1, 0),
          jnp.moveaxis(vs, 1, 0), jnp.moveaxis(lws, 1, 0))
    s_fin, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tm, h, kd)
    if tm < t:  # remainder chunk
        s_fin, y_rem = chunk_step(
            s_fin, (r[:, tm:], k[:, tm:], v[:, tm:],
                    lw[:, tm:].astype(jnp.float32)))
        y = jnp.concatenate([y, y_rem], axis=1)
    return y.astype(r.dtype), s_fin


def wkv6_step(r, k, v, lw, u, s):
    """Single decode step.  r/k/v/lw: (B, 1, H, K); s: (B, H, K, V)."""
    r1 = r[:, 0].astype(jnp.float32)
    k1 = k[:, 0].astype(jnp.float32)
    v1 = v[:, 0].astype(jnp.float32)
    w1 = jnp.exp(lw[:, 0].astype(jnp.float32))
    kv = k1[..., :, None] * v1[..., None, :]                # (B, H, K, V)
    y = jnp.einsum("bhk,bhkv->bhv", r1, s + u.astype(jnp.float32)[None, :, :, None] * kv)
    s = s * w1[..., None] + kv
    return y[:, None].astype(r.dtype), s


# ---------------------------------------------------------------------------
# Layer pieces
# ---------------------------------------------------------------------------
def _ddlerp(w, x, x_prev):
    """Data-dependent lerp → (xr, xk, xv, xw, xg)."""
    xx = x_prev - x
    base = x + xx * w["mu_x"]
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", base, w["wmix_a"]))
    lora = lora.reshape(*lora.shape[:-1], 5, LORA_MIX)
    mix = w["mu_rkvwg"] + jnp.einsum("btir,ird->btid", lora, w["wmix_b"])
    out = x[..., None, :] + xx[..., None, :] * mix          # (B, T, 5, D)
    return [out[..., i, :] for i in range(5)]


def time_mix(cfg, w, x, x_prev, s0, opts=None):
    """x: (B,T,D); x_prev: same (shifted).  Returns (out, s_fin)."""
    b, t, d = x.shape
    h, kd = cfg.n_heads, cfg.d_head
    xr, xk, xv, xw, xg = _ddlerp(w, x, x_prev)
    r = jnp.einsum("btd,de->bte", xr, w["wr"]).reshape(b, t, h, kd)
    k = jnp.einsum("btd,de->bte", xk, w["wk"]).reshape(b, t, h, kd)
    v = jnp.einsum("btd,de->bte", xv, w["wv"]).reshape(b, t, h, kd)
    r = L.constrain(r, opts, ("B", None, "M", None))
    k = L.constrain(k, opts, ("B", None, "M", None))
    v = L.constrain(v, opts, ("B", None, "M", None))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, w["wg"]))
    wlog = w["w0"] + jnp.einsum(
        "btd,dr->btr", jnp.tanh(jnp.einsum("btd,dr->btr", xw, w["wdec_a"])),
        w["wdec_b"])
    lw = -jnp.exp(jnp.clip(wlog.astype(jnp.float32), -20.0, 4.0))  # log decay <= 0
    lw = lw.reshape(b, t, h, kd)
    u = w["u"]
    use_kernel = bool(opts and opts.use_kernels)
    if t == 1:
        y, s_fin = wkv6_step(r, k, v, lw, u, s0)
    elif use_kernel:
        from repro.kernels.rwkv6 import ops as rwkv_ops
        y, s_fin = rwkv_ops.wkv6(r, k, v, lw, u, s0)
    else:
        y, s_fin = wkv6_chunked(r, k, v, lw, u, s0)
    # per-head group norm
    y = y.reshape(b, t, h, kd)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(b, t, d) * w["gn_scale"] + w["gn_bias"]
    out = jnp.einsum("btd,de->bte", (y * g).astype(x.dtype), w["wo"])
    return out, s_fin


def channel_mix(cfg, w, x, x_prev):
    xx = x_prev - x
    xk = x + xx * w["mu_ck"]
    xr = x + xx * w["mu_cr"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, w["wck"])))
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, w["wcr"])) * jnp.einsum(
        "btf,fd->btd", kk, w["wcv"])
    return out


def _shift(x, prev_last):
    """x: (B,T,D); prev_last: (B,D) — previous token of position 0."""
    return jnp.concatenate([prev_last[:, None, :], x[:, :-1]], axis=1)


def layer_full(cfg, w, x, state, opts):
    """state: dict(wkv (B,H,K,V), tm (B,D), cm (B,D))."""
    h1 = L.layer_norm(x, w["ln1_scale"], w["ln1_bias"])
    tm_out, s_fin = time_mix(cfg, w, h1, _shift(h1, state["tm"]), state["wkv"],
                             opts=opts)
    x = L.constrain(x + tm_out, opts, ("B", None, None))
    h2 = L.layer_norm(x, w["ln2_scale"], w["ln2_bias"])
    x = L.constrain(x + channel_mix(cfg, w, h2, _shift(h2, state["cm"])),
                    opts, ("B", None, None))
    new_state = {"wkv": s_fin, "tm": h1[:, -1], "cm": h2[:, -1]}
    return x, new_state


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int, opts) -> dict:
    h, kd, d = cfg.n_heads, cfg.d_head, cfg.d_model
    ls = cfg.n_layers
    return {
        "t": jax.ShapeDtypeStruct((), jnp.int32),
        "wkv": jax.ShapeDtypeStruct((ls, batch, h, kd, kd), jnp.float32),
        "tm": jax.ShapeDtypeStruct((ls, batch, d), opts.act_dtype),
        "cm": jax.ShapeDtypeStruct((ls, batch, d), opts.act_dtype),
    }


def init_cache(cfg, batch, max_len, opts):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, opts))


def _stack(cfg, params, x, cache, opts):
    def body(x, scanned):
        w, wkv, tm, cm = scanned
        fn = layer_full
        if opts.remat == "full":
            fn = jax.checkpoint(layer_full,
                                policy=jax.checkpoint_policies.nothing_saveable,
                                static_argnums=(0, 4))
        x, ns = fn(cfg, w, x, {"wkv": wkv, "tm": tm, "cm": cm}, opts)
        return x, (ns["wkv"], ns["tm"], ns["cm"])

    xs = (params["layers"], cache["wkv"], cache["tm"], cache["cm"])
    x, (wkv, tm, cm) = jax.lax.scan(body, x, xs)
    return x, {"wkv": wkv, "tm": tm, "cm": cm}


def forward(cfg, params, tokens, prefix_embeds=None, opts=None, mode="train",
            cache=None):
    b, s = tokens.shape
    x = L.constrain(params["embed"][tokens].astype(opts.act_dtype),
                    opts, ("B", None, None))
    if cache is None:
        cache = init_cache(cfg, b, s, opts)
    x, new_state = _stack(cfg, params, x, cache, opts)
    x = L.layer_norm(x, params["final_norm_scale"], params["final_norm_bias"])
    if mode == "hidden":
        return x, 0.0
    if mode == "train":
        logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                            preferred_element_type=jnp.float32)
        return logits, 0.0
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_state["t"] = cache["t"] + s if "t" in cache else jnp.asarray(s, jnp.int32)
    return logits, new_state


def decode_step(cfg, params, cache, tokens, opts):
    x = params["embed"][tokens[:, :1]].astype(opts.act_dtype)
    x, new_state = _stack(cfg, params, x, cache, opts)
    x = L.layer_norm(x, params["final_norm_scale"], params["final_norm_bias"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_state["t"] = cache["t"] + 1
    return logits[:, 0], new_state


def lm_loss(cfg, params, tokens, labels, prefix_embeds=None, opts=None):
    from repro.models.transformer import chunked_lm_loss
    x, _ = forward(cfg, params, tokens, None, opts, "hidden")
    return chunked_lm_loss(x, params["lm_head"], labels, opts)
