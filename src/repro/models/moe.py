"""Capacity+gather Mixture-of-Experts FFN (dropless-ish, FLOP-exact).

Instead of the GShard one-hot dispatch einsum — whose (T, E, C) dispatch
tensor and T*E*C*D einsum FLOPs dominate at long sequence — we route with a
sort + gather:

  1. top-k experts per token (router in fp32),
  2. stable-sort the (token, expert) assignments by expert,
  3. compute each assignment's position inside its expert group,
  4. gather tokens into a dense (E, C, D) buffer (C = capacity), dropping
     overflow (capacity_factor controls drops, as in GShard),
  5. batched per-expert GEMMs (E,C,D)x(E,D,F),
  6. scatter-add results back weighted by the (renormalised) gate values.

All ops are differentiable (sort/gather/scatter-add carry gradients; routing
indices are piecewise-constant as usual).  Expert GEMM FLOPs are exactly
capacity_factor * active-expert FLOPs — no E-times dense waste.

Routing granularity is a "row": a batch element for train/prefill (so routing
stays local under batch sharding) and the whole flattened batch for decode
(tiny activations; the all-gather is nanoscale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def capacity(tokens_per_row: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = int(np.ceil(tokens_per_row * top_k / n_experts * capacity_factor))
    return max(1, min(c, tokens_per_row * top_k))


def route(x: Array, router_w: Array, n_experts: int, top_k: int,
          cap: int) -> tuple[Array, Array, Array, Array]:
    """x: (R, T, D) rows of tokens.  Returns (idx, valid, gate, aux_loss).

    idx:   (R, E, C) int32 — token index (within row) feeding each expert slot
    valid: (R, E, C) bool  — slot occupied
    gate:  (R, E, C) f32   — combine weight for that slot
    """
    r, t, d = x.shape
    logits = jnp.einsum("rtd,de->rte", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    g_vals, e_idx = jax.lax.top_k(probs, top_k)          # (R, T, K)
    g_vals = g_vals / jnp.maximum(g_vals.sum(-1, keepdims=True), 1e-9)

    # flatten assignments: (R, T*K)
    flat_e = e_idx.reshape(r, t * top_k)
    flat_tok = jnp.broadcast_to(jnp.arange(t)[:, None], (t, top_k)).reshape(-1)
    flat_g = g_vals.reshape(r, t * top_k)

    # stable sort by expert id per row
    order = jnp.argsort(flat_e, axis=-1, stable=True)     # (R, T*K)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_tok = flat_tok[order]
    sorted_g = jnp.take_along_axis(flat_g, order, axis=-1)

    # position within expert group = rank - group start
    counts = jax.vmap(lambda e: jnp.bincount(e, length=n_experts))(flat_e)
    starts = jnp.cumsum(counts, axis=-1) - counts          # (R, E) exclusive
    pos = jnp.arange(t * top_k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)                         # (R, T*K)
    keep = pos < cap

    # scatter into (R, E*C) slot tables
    slot = jnp.where(keep, sorted_e * cap + pos, n_experts * cap)  # overflow bin
    idx_tbl = jnp.full((r, n_experts * cap + 1), 0, jnp.int32)
    idx_tbl = jax.vmap(lambda tb, s, v: tb.at[s].set(v))(
        idx_tbl, slot, sorted_tok.astype(jnp.int32))
    val_tbl = jnp.zeros((r, n_experts * cap + 1), bool)
    val_tbl = jax.vmap(lambda tb, s: tb.at[s].set(True))(val_tbl, slot)
    gate_tbl = jnp.zeros((r, n_experts * cap + 1), jnp.float32)
    gate_tbl = jax.vmap(lambda tb, s, g: tb.at[s].set(g))(gate_tbl, slot, sorted_g)

    idx = idx_tbl[:, :-1].reshape(r, n_experts, cap)
    valid = val_tbl[:, :-1].reshape(r, n_experts, cap)
    gate = gate_tbl[:, :-1].reshape(r, n_experts, cap)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    frac = counts.astype(jnp.float32) / (t * top_k)
    mean_p = probs.mean(axis=1)
    aux = n_experts * jnp.mean(jnp.sum(frac * mean_p, axis=-1))
    return idx, valid, gate, aux


def moe_ffn(x: Array, router_w: Array, w1: Array, w2: Array, w3: Array,
            *, n_experts: int, top_k: int, capacity_factor: float,
            opts=None) -> tuple[Array, Array]:
    """x: (R, T, D); w1/w2: (E, D, F); w3: (E, F, D).  Returns (out, aux_loss).

    Sharding constraints keep the expert buffers row-sharded and the hidden
    F-dim TP-sharded: without them GSPMD all-reduces the *unsharded* f32
    (R,E,C,F) hidden — 10.7 GB x n_layers at mixtral train_4k (§Perf)."""
    from repro.models.layers import constrain
    r, t, d = x.shape
    cap = capacity(t, n_experts, top_k, capacity_factor)
    idx, valid, gate, aux = route(x, router_w, n_experts, top_k, cap)

    # gather tokens into expert slots: (R, E, C, D)
    xe = jnp.take_along_axis(
        x[:, None, :, :],                        # (R, 1, T, D)
        idx[..., None].astype(jnp.int32),        # (R, E, C, 1)
        axis=2)
    xe = jnp.where(valid[..., None], xe, 0).astype(x.dtype)
    # constraints only for the TP-within-expert layout (E % 16 != 0):
    # for EP-sharded experts GSPMD's own schedule is better (measured —
    # forcing E-sharded h on llama4 added 50% collective time)
    ep = n_experts % 16 == 0
    if not ep:
        xe = constrain(xe, opts, ("B", None, None, None))
    h = jnp.einsum("recd,edf->recf", xe, w1) * jax.nn.silu(
        jnp.einsum("recd,edf->recf", xe, w2))
    if not ep:
        h = constrain(h, opts, ("B", None, None, "M"))
    ye = jnp.einsum("recf,efd->recd", h, w3)     # (R, E, C, D)
    if not ep:
        ye = constrain(ye, opts, ("B", None, None, None))
    ye = ye * gate[..., None].astype(ye.dtype)
    ye = jnp.where(valid[..., None], ye, 0)

    # scatter-add back to tokens
    out = jnp.zeros((r, t, d), ye.dtype)
    flat_idx = idx.reshape(r, -1)
    flat_ye = ye.reshape(r, -1, d)
    out = jax.vmap(lambda o, i, y: o.at[i].add(y))(out, flat_idx, flat_ye)
    return out.astype(x.dtype), aux
