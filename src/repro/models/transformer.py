"""Decoder-only transformer covering the dense / moe / vlm / audio families.

Layer stacking uses ``lax.scan`` over *macro blocks* so that HLO size is
depth-independent even for heterogeneous stacks: an arch with
``global_every = N`` (llama4: 3 chunked-local layers then 1 global layer)
scans over L/N macro blocks whose bodies unroll the N sub-layers, each with
its own attention kind and its own KV-cache geometry.

Modes:
  * train   — full-sequence logits + LM loss (no cache).
  * prefill — forward over the prompt, KV caches written, last-token logits.
  * decode  — one token against the cache.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ModelConfig
from repro.models import layers as L
from repro.models import kvcache, moe

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RunOptions:
    """Runtime (not architecture) options — the perf knobs of §Perf."""
    attn_chunk: int = 1024
    remat: str = "full"            # full | none
    param_dtype: Any = jnp.bfloat16
    act_dtype: Any = jnp.bfloat16
    use_kernels: bool = False      # Pallas kernels (TPU) vs jnp oracle
    causal_pair_scan: bool = False # triangular chunk-pair attention (§Perf)
    logit_chunk: int = 512         # CE loss seq-chunking (memory control)
    explicit_tp_ffn: bool = False  # shard_map FFN w/ bf16 collectives (P5)
    shard_constraints: bool = False  # emit with_sharding_constraint (pjit runs)
    dp_spec: Any = ("data",)       # mesh axes carrying the batch
    tp_name: str = "model"
    sharding_mode: str = "auto"    # auto | 2d | dp_only (see shardings.py)
    seq_shard_decode: bool = True  # shard_map flash-decoding (§Perf)
    mesh: Any = None               # concrete mesh for shard_map paths


constrain = L.constrain


def chunked_lm_loss(x: Array, head: Array, labels: Array,
                    opts: RunOptions) -> Array:
    """Cross-entropy without materialising full-sequence logits.

    Scans over sequence chunks; per chunk the (B, C, V) logits are built,
    reduced and discarded.  Under pjit the vocab dim is constrained to the
    'model' axis so GSPMD never all-gathers the unembedding (the naive form
    emitted a full-vocab (B,S,V) all-reduce — 24 GB/device at train_4k)."""
    b, s, d = x.shape
    c = min(opts.logit_chunk, s)
    nc = s // c
    tm = nc * c

    def chunk_loss(xc, lc):
        logits = jnp.einsum("bsd,vd->bsv", xc, head,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, opts, ("B", None, "M"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0), axis=-1)
        return jnp.sum(logz - ll)

    def body(acc, inp):
        xc, lc = inp
        return acc + chunk_loss(xc, lc), None

    xs = jnp.moveaxis(x[:, :tm].reshape(b, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels[:, :tm].reshape(b, nc, c), 1, 0)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    if tm < s:
        total = total + chunk_loss(x[:, tm:], labels[:, tm:])
    return total / (b * s)


# ---------------------------------------------------------------------------
# Layer geometry
# ---------------------------------------------------------------------------
def macro_shape(cfg: ModelConfig) -> tuple[int, int, list[str]]:
    """(n_macro, macro_size, kinds) — kinds[j] in {full, window, local, global}."""
    if cfg.global_every:
        m = cfg.global_every
        kinds = ["local"] * (m - 1) + ["global"]
        return cfg.n_layers // m, m, kinds
    kind = "window" if cfg.window else "full"
    return cfg.n_layers, 1, [kind]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _layer_param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f = cfg.d_model, cfg.d_ff
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    shapes: dict[str, tuple] = {
        "wq": (d, h * dh), "wk": (d, kv * dh), "wv": (d, kv * dh),
        "wo": (h * dh, d),
        "ln1_scale": (d,), "ln2_scale": (d,),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (h * dh,), "bk": (kv * dh,), "bv": (kv * dh,)})
    if cfg.norm == "layernorm":
        shapes.update({"ln1_bias": (d,), "ln2_bias": (d,)})
    if cfg.n_experts:
        e = cfg.n_experts
        shapes.update({
            "router": (d, e),
            "moe_w1": (e, d, f), "moe_w2": (e, d, f), "moe_w3": (e, f, d),
        })
    elif cfg.mlp == "swiglu":
        shapes.update({"w1": (d, f), "w2": (d, f), "w3": (f, d)})
    else:
        shapes.update({"w1": (d, f), "b1": (f,), "w3": (f, d), "b3": (d,)})
    return shapes


def param_specs(cfg: ModelConfig, opts: RunOptions = RunOptions()) -> dict:
    n_macro, m, _ = macro_shape(cfg)
    pd = opts.param_dtype
    lp = {k: jax.ShapeDtypeStruct((n_macro, m) + s, pd)
          for k, s in _layer_param_shapes(cfg).items()}
    top = {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd),
        "final_norm_scale": jax.ShapeDtypeStruct((cfg.d_model,), pd),
    }
    if cfg.norm == "layernorm":
        top["final_norm_bias"] = jax.ShapeDtypeStruct((cfg.d_model,), pd)
    if not cfg.tie_embeddings:
        top["lm_head"] = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), pd)
    return {"layers": lp, **top}


def init_params(cfg: ModelConfig, key: Array,
                opts: RunOptions = RunOptions()) -> dict:
    specs = param_specs(cfg, opts)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(flat))
    out = []
    for (path, spec), k in zip(flat, keys):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if "norm" in name or name.startswith("ln"):
            arr = (jnp.ones if "scale" in name else jnp.zeros)(spec.shape, spec.dtype)
        elif name.startswith("b"):
            arr = jnp.zeros(spec.shape, spec.dtype)
        else:
            arr = L.dense_init(k, spec.shape, spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(jax.tree.structure(specs), out)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                opts: RunOptions = RunOptions()) -> dict:
    n_macro, m, kinds = macro_shape(cfg)
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    ad = opts.act_dtype
    specs: dict[str, Any] = {"t": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.global_every:
        wl = kvcache.cache_len(cfg, max_len, "local")
        specs["k_local"] = jax.ShapeDtypeStruct(
            (n_macro, m - 1, batch, wl, kvh, dh), ad)
        specs["v_local"] = specs["k_local"]
        specs["k_global"] = jax.ShapeDtypeStruct(
            (n_macro, 1, batch, max_len, kvh, dh), ad)
        specs["v_global"] = specs["k_global"]
    else:
        w = kvcache.cache_len(cfg, max_len, kinds[0])
        specs["k"] = jax.ShapeDtypeStruct((n_macro, m, batch, w, kvh, dh), ad)
        specs["v"] = specs["k"]
    return specs


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               opts: RunOptions = RunOptions()) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, opts))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _norm(cfg, w, prefix, x):
    if cfg.norm == "rmsnorm":
        return L.rms_norm(x, w[f"{prefix}_scale"])
    return L.layer_norm(x, w[f"{prefix}_scale"], w[f"{prefix}_bias"])


def _use_explicit_tp(opts, mode="full_seq"):
    return (opts is not None and getattr(opts, "explicit_tp_ffn", False)
            and opts.mesh is not None and mode != "decode"
            and opts.tp_name not in tuple(opts.dp_spec or ()))


def _qkv(cfg, w, x, positions, opts=None, mode="full_seq"):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if _use_explicit_tp(opts, mode):
        q = L.explicit_tp_matmul(x, w["wq"], opts, row=False)
        k = L.explicit_tp_matmul(x, w["wk"], opts, row=False)
        v = L.explicit_tp_matmul(x, w["wv"], opts, row=False)
    else:
        q = jnp.einsum("bsd,dh->bsh", x, w["wq"])
        k = jnp.einsum("bsd,dh->bsh", x, w["wk"])
        v = jnp.einsum("bsd,dh->bsh", x, w["wv"])
    if cfg.qkv_bias:
        q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kv, dh)
    v = v.reshape(b, s, kv, dh)
    if opts is not None:
        q = constrain(q, opts, ("B", None, "M", None))
        k = constrain(k, opts, ("B", None, "M", None))
        v = constrain(v, opts, ("B", None, "M", None))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp(cfg, w, x, opts=None):
    """Returns (out, aux_loss)."""
    if cfg.n_experts:
        b, s, d = x.shape
        # decode (S == 1): route the flattened batch as one row so capacity
        # tracks the true token count instead of E-per-token waste.
        xr = x.reshape(1, b, d) if s == 1 else x
        out, aux = moe.moe_ffn(
            xr, w["router"], w["moe_w1"], w["moe_w2"], w["moe_w3"],
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            opts=None if s == 1 else opts)
        return out.reshape(b, s, d), aux
    if cfg.mlp == "swiglu":
        if opts is not None and getattr(opts, "explicit_tp_ffn", False) \
                and opts.mesh is not None \
                and opts.tp_name not in tuple(opts.dp_spec or ()):
            return L.explicit_tp_swiglu(x, w["w1"], w["w2"], w["w3"],
                                        opts), 0.0
        return L.swiglu_mlp(x, w["w1"], w["w2"], w["w3"]), 0.0
    return L.gelu_mlp(x, w["w1"], w["b1"], w["w3"], w["b3"]), 0.0


def _attn_full_seq(cfg, w, x, kind, opts, q_offset=0):
    """Attention over a full sequence (train / prefill). Returns (out, k, v)."""
    b, s, _ = x.shape
    positions = q_offset + jnp.arange(s)
    q, k, v = _qkv(cfg, w, x, positions, opts)
    window = cfg.window if kind == "window" else None
    local = cfg.chunk_attn if kind == "local" else None
    if opts.use_kernels:
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                   local_block=local, q_offset=q_offset)
    else:
        o = L.chunked_attention(q, k, v, causal=True, window=window,
                                local_block=local, chunk=opts.attn_chunk,
                                q_offset=q_offset)
    o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
    if _use_explicit_tp(opts):
        out = L.explicit_tp_matmul(o, w["wo"], opts, row=True)
    else:
        out = jnp.einsum("bsh,hd->bsd", o, w["wo"],
                         preferred_element_type=o.dtype)
    return out, k, v


def _seq_shard_decode(cfg, opts, q, k_new, v_new, k_cache, v_cache, t, kind):
    """Flash-decoding over the sequence-sharded cache via shard_map.

    Baseline GSPMD turns the one-token cache write (dynamic-update-slice on
    the 'model'-sharded seq dim) into a full cache all-gather per layer --
    1 GB x n_layers at decode_32k (EXPERIMENTS §Perf).  Here each seq shard:
      * writes the new token only if it owns slot t (masked local DUS),
      * computes partial attention over its slice (all heads local),
      * combines via a logsumexp pmax/psum -- KBs on the wire per layer.
    """
    from jax.sharding import PartitionSpec as P
    axis = opts.tp_name
    mesh = opts.mesh
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    bspec = tuple(opts.dp_spec) if opts.dp_spec else None
    n_rep = cfg.n_heads // cfg.n_kv_heads
    window = cfg.window if kind == "window" else None
    local_block = cfg.chunk_attn if kind == "local" else None
    scale = 1.0 / np.sqrt(cfg.d_head)

    def local_fn(q, kn, vn, kc, vc, t):
        idx = jax.lax.axis_index(axis)
        s_loc = kc.shape[1]
        w_total = s_loc * n_shards
        slot = t if kind in ("full", "global") else t % w_total
        lo = idx * s_loc
        in_range = jnp.logical_and(slot >= lo, slot < lo + s_loc)
        loc = jnp.clip(slot - lo, 0, s_loc - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, loc, 1, 1)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, loc, 1, 1)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, jnp.where(in_range, kn, cur_k), loc, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, jnp.where(in_range, vn, cur_v), loc, 1)

        slots = lo + jnp.arange(s_loc)
        if kind in ("full", "global"):
            pos = slots
            valid = pos <= t
        else:
            pos = t - ((t - slots) % w_total)
            valid = pos >= 0
            if window is not None:
                valid &= (t - pos) < window
            if local_block is not None:
                valid &= pos >= (t // local_block) * local_block

        k = L._expand_kv(kc, n_rep)
        v = L._expand_kv(vc, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None, None, :], s, L.NEG_INF)
        m_loc = s.max(axis=-1)                           # (B, H, 1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, axis)
        acc_g = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_g / jnp.maximum(l_g[..., None], 1e-30)
        out = jnp.moveaxis(out, 1, 2).astype(q.dtype)    # (B, 1, H, D)
        return out, kc, vc

    cspec = P(bspec, axis, None, None)
    fn = L.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), cspec, cspec, P()),
        out_specs=(P(bspec), cspec, cspec))
    return fn(q, k_new, v_new, k_cache, v_cache, t)


def _attn_decode(cfg, w, x, k_cache, v_cache, t, kind, opts):
    """One-token attention. x: (B,1,D). Returns (out, k_cache', v_cache')."""
    b = x.shape[0]
    q, k_new, v_new = _qkv(cfg, w, x, t[None] if t.ndim == 0 else t, opts,
                           mode="decode")
    if opts.seq_shard_decode and opts.mesh is not None:
        o, k_cache, v_cache = _seq_shard_decode(
            cfg, opts, q, k_new, v_new, k_cache, v_cache, t, kind)
        o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
        return jnp.einsum("bsh,hd->bsd", o, w["wo"]), k_cache, v_cache
    wsize = k_cache.shape[1]
    if kind in ("full", "global"):
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, t, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, t, axis=1)
        if opts.use_kernels:
            # flash-decoding kernel with the position delivered via
            # scalar prefetch: the same compiled executable serves every
            # decode step (a static t would recompile per token, which
            # the serving executor's compile cache must never see)
            from repro.kernels.flash_decode import ops as fd_ops
            o = fd_ops.flash_decode_at(q[:, 0], k_cache, v_cache, t)[:, None]
        else:
            o = L.decode_attention(q, k_cache, v_cache, length=t + 1)
    else:
        slot = t % wsize
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, slot, axis=1)
        window = cfg.window if kind == "window" else None
        local = cfg.chunk_attn if kind == "local" else None
        o = L.decode_ring_attention(q, k_cache, v_cache, t=t,
                                    window=window, local_block=local)
    o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
    return jnp.einsum("bsh,hd->bsd", o, w["wo"]), k_cache, v_cache


def _sublayer(cfg, w, x, kind, opts, mode, cache_kv=None, t=None, q_offset=0):
    """One transformer layer.  Returns (x, aux, new_kv)."""
    h = _norm(cfg, w, "ln1", x)
    if mode == "decode":
        a, k_c, v_c = _attn_decode(cfg, w, h, cache_kv[0], cache_kv[1], t, kind, opts)
        new_kv = (k_c, v_c)
    else:
        a, k, v = _attn_full_seq(cfg, w, h, kind, opts, q_offset)
        new_kv = (k, v)
    x = constrain(x + a, opts, ("B", None, None))
    h = _norm(cfg, w, "ln2", x)
    mlp_out, aux = _mlp(cfg, w, h, opts)
    return constrain(x + mlp_out, opts, ("B", None, None)), aux, new_kv


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _embed(cfg, params, tokens, prefix_embeds, opts):
    x = params["embed"][tokens].astype(opts.act_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(opts.act_dtype), x], axis=1)
    return constrain(x, opts, ("B", None, None))


def forward(cfg: ModelConfig, params: dict, tokens: Array,
            prefix_embeds: Optional[Array] = None,
            opts: RunOptions = RunOptions(),
            mode: str = "train",
            cache: Optional[dict] = None,
            max_len: Optional[int] = None):
    """mode='train': (logits, aux).  mode='prefill': (last_logits, cache)."""
    n_macro, m, kinds = macro_shape(cfg)
    x = _embed(cfg, params, tokens, prefix_embeds, opts)
    b, s, _ = x.shape

    want_cache = mode == "prefill"

    def block(x, block_w):
        auxes = 0.0
        kvs = []
        for j in range(m):
            wj = {k: v[j] for k, v in block_w.items()}
            x, aux, kv = _sublayer(cfg, wj, x, kinds[j], opts, "full_seq")
            auxes = auxes + aux
            kvs.append(kv)
        return x, auxes, kvs

    def scan_body(x, block_w):
        if opts.remat == "full":
            bl = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
        else:
            bl = block
        x, aux, kvs = bl(x, block_w)
        if want_cache:
            ks = jnp.stack([kv[0] for kv in kvs])  # (m, B, S, KV, DH)
            vs = jnp.stack([kv[1] for kv in kvs])
            return x, (aux, ks, vs)
        return x, (aux, None, None)

    x, (auxes, ks, vs) = jax.lax.scan(scan_body, x, params["layers"])
    x = _norm(cfg, params, "final_norm", x)
    aux = jnp.sum(auxes) if cfg.n_experts else 0.0

    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if mode == "hidden":
        return x, aux
    if mode == "train":
        logits = jnp.einsum("bsd,vd->bsv", x, head,
                            preferred_element_type=jnp.float32)
        return logits, aux

    # prefill: build the cache
    last = x[:, -1:, :]
    logits = jnp.einsum("bsd,vd->bsv", last, head,
                        preferred_element_type=jnp.float32)
    new_cache = _fill_cache(cfg, ks, vs, s, opts, max_len)
    return logits[:, 0], new_cache


def _fill_cache(cfg, ks, vs, s, opts, max_len=None):
    """ks/vs: (n_macro, m, B, S, KV, DH) fresh keys — pack into cache layout."""
    n_macro, m, kinds = macro_shape(cfg)
    max_len = max_len if max_len is not None else s
    cache: dict[str, Any] = {"t": jnp.asarray(s, jnp.int32)}

    def pad_to(arr, width):
        if arr.shape[-3] >= width:
            return arr
        pad = [(0, 0)] * arr.ndim
        pad[-3] = (0, width - arr.shape[-3])
        return jnp.pad(arr, pad)

    def pack_ring(k_part, v_part, width):
        # keep last ``width`` positions, arranged at ring slots (pos % width)
        w = min(width, s)
        k_last = k_part[..., s - w:, :, :]
        v_last = v_part[..., s - w:, :, :]
        if w < width:  # context shorter than the ring — pad tail slots
            pad = [(0, 0)] * k_last.ndim
            pad[-3] = (0, width - w)
            k_last = jnp.pad(k_last, pad)
            v_last = jnp.pad(v_last, pad)
            return k_last.astype(opts.act_dtype), v_last.astype(opts.act_dtype)
        # roll so that physical slot i holds position with pos % width == i
        shift = (s - w) % width
        k_last = jnp.roll(k_last, shift, axis=-3)
        v_last = jnp.roll(v_last, shift, axis=-3)
        return k_last.astype(opts.act_dtype), v_last.astype(opts.act_dtype)

    if cfg.global_every:
        wl = kvcache.cache_len(cfg, max_len, "local")
        cache["k_local"], cache["v_local"] = pack_ring(
            ks[:, : m - 1], vs[:, : m - 1], wl)
        cache["k_global"] = pad_to(ks[:, m - 1:].astype(opts.act_dtype), max_len)
        cache["v_global"] = pad_to(vs[:, m - 1:].astype(opts.act_dtype), max_len)
    else:
        w = kvcache.cache_len(cfg, max_len, kinds[0])
        if w == s and max_len == s:
            cache["k"], cache["v"] = ks.astype(opts.act_dtype), vs.astype(opts.act_dtype)
        elif kinds[0] == "full":
            cache["k"] = pad_to(ks.astype(opts.act_dtype), max_len)
            cache["v"] = pad_to(vs.astype(opts.act_dtype), max_len)
        else:
            cache["k"], cache["v"] = pack_ring(ks, vs, w)
    return cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: Array,
                opts: RunOptions = RunOptions()):
    """tokens: (B, 1) int32.  Returns (logits (B, V), new_cache)."""
    n_macro, m, kinds = macro_shape(cfg)
    t = cache["t"]
    x = params["embed"][tokens[:, :1]].astype(opts.act_dtype)

    if cfg.global_every:
        xs = (params["layers"], cache["k_local"], cache["v_local"],
              cache["k_global"], cache["v_global"])

        def body(x, scanned):
            block_w, kl, vl, kg, vg = scanned
            new_kl, new_vl, new_kg, new_vg = [], [], [], []
            for j in range(m):
                wj = {k: v[j] for k, v in block_w.items()}
                if kinds[j] == "local":
                    x, _, (nk, nv) = _sublayer(cfg, wj, x, "local", opts,
                                               "decode", (kl[j], vl[j]), t)
                    new_kl.append(nk); new_vl.append(nv)
                else:
                    x, _, (nk, nv) = _sublayer(cfg, wj, x, "global", opts,
                                               "decode", (kg[0], vg[0]), t)
                    new_kg.append(nk); new_vg.append(nv)
            return x, (jnp.stack(new_kl), jnp.stack(new_vl),
                       jnp.stack(new_kg), jnp.stack(new_vg))

        x, (kl, vl, kg, vg) = jax.lax.scan(body, x, xs)
        new_cache = {"t": t + 1, "k_local": kl, "v_local": vl,
                     "k_global": kg, "v_global": vg}
    else:
        xs = (params["layers"], cache["k"], cache["v"])

        def body(x, scanned):
            block_w, kc, vc = scanned
            nks, nvs = [], []
            for j in range(m):
                wj = {k: v[j] for k, v in block_w.items()}
                x, _, (nk, nv) = _sublayer(cfg, wj, x, kinds[j], opts,
                                           "decode", (kc[j], vc[j]), t)
                nks.append(nk); nvs.append(nv)
            return x, (jnp.stack(nks), jnp.stack(nvs))

        x, (ks, vs) = jax.lax.scan(body, x, xs)
        new_cache = {"t": t + 1, "k": ks, "v": vs}

    x = _norm(cfg, params, "final_norm", x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(cfg: ModelConfig, params: dict, tokens: Array, labels: Array,
            prefix_embeds: Optional[Array] = None,
            opts: RunOptions = RunOptions()):
    """Chunked cross-entropy (vocab stays sharded; see chunked_lm_loss)."""
    x, aux = forward(cfg, params, tokens, prefix_embeds, opts, "hidden")
    if prefix_embeds is not None:           # loss only over token positions
        x = x[:, prefix_embeds.shape[1]:]
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    loss = chunked_lm_loss(x, head, labels, opts)
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss
