"""Deterministic synthetic-token data pipeline.

Batches are a pure function of (seed, step, host_slice), so training is
exactly replayable after a checkpoint restart and each host materialises
only its slice of the global batch — no data redistribution on restore,
and an elastic rescale just changes the slicing (same global stream).

The token stream is a mixture of Zipfian unigrams and short repeated
motifs, so small models have actual structure to learn in the examples
(loss drops well below the uniform-entropy floor).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


def _zipf_logits(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = 1.0 / ranks ** cfg.zipf_a
    return np.log(p / p.sum()).astype(np.float32)


class TokenStream:
    """Stateless batch factory: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._logits = jnp.asarray(_zipf_logits(cfg))

    def batch(self, step: int, host_slice: slice | None = None) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        b = cfg.global_batch
        k1, k2, k3 = jax.random.split(key, 3)
        toks = jax.random.categorical(
            k1, self._logits, shape=(b, cfg.seq_len + 1))
        # overwrite random spans with repeated motifs (learnable structure)
        motif = jax.random.randint(
            k2, (b, cfg.motif_len), 0, cfg.vocab, jnp.int32)
        reps = (cfg.seq_len + 1 + cfg.motif_len - 1) // cfg.motif_len
        tiled = jnp.tile(motif, (1, reps))[:, : cfg.seq_len + 1]
        use_motif = jax.random.bernoulli(
            k3, cfg.motif_prob, (b, 1))
        toks = jnp.where(use_motif, tiled, toks).astype(jnp.int32)
        if host_slice is not None:
            toks = toks[host_slice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_slice(self, host_id: int, n_hosts: int) -> slice:
        per = self.cfg.global_batch // n_hosts
        return slice(host_id * per, (host_id + 1) * per)
