"""Elastic scaling: re-mesh + reshard a training/serving state.

When nodes join or fail permanently, the job restarts on a new mesh shape;
``reshard_state`` moves the checkpointed state onto the new mesh via
``jax.device_put`` with the new NamedShardings (the checkpoint layer
already restores through the same path, so scale-up/down = restore with a
different mesh — no format change).

``shrink_mesh`` models node failure: drop a data-parallel slice and rebuild
(the global batch is re-split by the deterministic data pipeline, so the
training stream is preserved).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def make_mesh_from_devices(devices, shape: tuple[int, ...],
                           axes: tuple[str, ...]):
    devs = np.asarray(devices[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def shrink_mesh(mesh, axis: str, new_size: int):
    """Drop trailing slices along ``axis`` (simulated node failure)."""
    names = list(mesh.axis_names)
    idx = names.index(axis)
    devs = mesh.devices
    sl = [slice(None)] * devs.ndim
    sl[idx] = slice(0, new_size)
    return jax.sharding.Mesh(devs[tuple(sl)], mesh.axis_names)


def reshard_state(state, pspecs, new_mesh):
    """Move every leaf onto ``new_mesh`` with its PartitionSpec."""
    def move(leaf, spec):
        return jax.device_put(leaf, NamedSharding(new_mesh, spec))
    return jax.tree.map(
        move, state, pspecs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
