"""Fault-tolerant training driver.

Step-functional loop around (params, opt_state, step) with:
  * auto-resume from the latest checkpoint (crash / preemption restart),
  * periodic atomic checkpoints (``checkpoint.save`` publishes via rename),
  * deterministic data replay (batch = f(seed, step), see data/pipeline.py),
  * optional fault injection (``fail_at_step``) used by the integration
    tests to prove restart-equivalence: a run that crashes and resumes
    produces bit-identical losses to an uninterrupted one,
  * optional int8 gradient compression with error feedback (optim/adamw).

On a real multi-pod deployment the same loop runs under
``jax.distributed.initialize`` with the production mesh; here the examples
drive it single-host.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointing as ckpt
from repro.configs.registry import ModelConfig
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.model import Model, RunOptions, get_model
from repro.optim import adamw


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    fail_at_step: Optional[int] = None    # fault injection (tests)
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig,
                 opts: RunOptions = RunOptions(remat="none"),
                 opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                 log_fn: Callable[[str], None] = print):
        self.model = get_model(cfg, opts)
        self.data = TokenStream(data_cfg)
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.log = log_fn
        self.losses: list[float] = []

        def train_step(params, opt_state, err_fb, batch):
            loss, grads = jax.value_and_grad(self.model.loss)(params, batch)
            params, opt_state, err_fb, metrics = adamw.update(
                opt_cfg, params, grads, opt_state, err_fb)
            return params, opt_state, err_fb, {"loss": loss, **metrics}

        self._step = jax.jit(train_step, donate_argnums=(0, 1, 2))

    def _init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = adamw.init(params)
        err_fb = (adamw.init_error_feedback(params)
                  if self.opt_cfg.compress_grads else None)
        return {"params": params, "opt": opt_state, "err_fb": err_fb}

    def run(self) -> dict:
        tcfg = self.tcfg
        state = self._init_state()
        start = 0
        if ckpt.latest_step(tcfg.ckpt_dir) is not None:
            state, start = ckpt.restore(tcfg.ckpt_dir, state)
            self.log(f"[trainer] resumed from step {start}")
        t0 = time.time()
        for step in range(start, tcfg.steps):
            if tcfg.fail_at_step is not None and step == tcfg.fail_at_step \
                    and start <= tcfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.data.batch(step)
            state["params"], state["opt"], state["err_fb"], m = self._step(
                state["params"], state["opt"], state["err_fb"], batch)
            loss = float(m["loss"])
            self.losses.append(loss)
            if step % tcfg.log_every == 0:
                self.log(f"[trainer] step {step:5d} loss {loss:.4f} "
                         f"gnorm {float(m['grad_norm']):.3f} "
                         f"({(time.time()-t0):.1f}s)")
            if (step + 1) % tcfg.ckpt_every == 0 or step + 1 == tcfg.steps:
                ckpt.save(tcfg.ckpt_dir, step + 1, state)
        return {"final_loss": self.losses[-1] if self.losses else None,
                "losses": self.losses, "steps_run": tcfg.steps - start}
