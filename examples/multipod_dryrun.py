"""Multi-pod launch example: compile internlm2-20b's train step on the
2x16x16 production mesh (512 fake devices) and print the memory/cost
analysis — the per-cell version of ``python -m repro.launch.dryrun --all``.

  PYTHONPATH=src python examples/multipod_dryrun.py [--arch X --shape Y]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402
from repro.models.model import RunOptions  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_20b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    out = run_cell(args.arch, args.shape, multi_pod=True,
                   opts=RunOptions(), save=False)
    assert out["status"] == "ok", out.get("error")
    r = out["roofline"]
    print(f"{args.arch} x {args.shape} on 2x16x16 (512 chips):")
    print(f"  compile: {out['compile_s']:.1f}s; "
          f"per-device peak mem {out['memory']['peak_bytes_est']/1e9:.2f} GB")
    print(f"  roofline: compute {r['compute_s']*1e3:.1f}ms | "
          f"memory {r['memory_s']*1e3:.1f}ms | "
          f"collective {r['collective_s']*1e3:.1f}ms  "
          f"-> {r['dominant']}-bound")
    print(f"  collectives: {out['collectives']['by_op']}")
