"""Train a ~100M-class reduced LM for a few hundred steps with the full
substrate: deterministic data pipeline, AdamW, checkpointing + auto-resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import shutil

import jax.numpy as jnp

from repro.configs.registry import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models.model import RunOptions
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    # widen the reduced config toward ~100M params
    cfg = dataclasses.replace(
        reduced(get_config("internlm2_1_8b")),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
        d_ff=1024, vocab=8192)
    print(f"training {cfg.name} (reduced): "
          f"{cfg.n_params/1e6:.1f}M params")
    if args.fresh:
        shutil.rmtree("/tmp/repro_example_ckpt", ignore_errors=True)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=100,
                         ckpt_dir="/tmp/repro_example_ckpt", log_every=20)
    opts = RunOptions(remat="none", attn_chunk=128,
                      param_dtype=jnp.float32, act_dtype=jnp.float32)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=30,
                                total_steps=args.steps)
    out = Trainer(cfg, data_cfg, tcfg, opts, opt_cfg).run()
    print(f"done: final loss {out['final_loss']:.4f} "
          f"(uniform floor would be {jnp.log(cfg.vocab):.2f})")
