"""Serve LM *pipelines* (DAGs over the assigned architectures) on the
emulated 16-host TPU cluster, scheduled by ESG vs a baseline.

This is the paper's end-to-end scenario with the model zoo as the
serverless functions: per-arch latency lattices come from the v5e roofline
model (calibrated by the dry-run artifacts when present).

  PYTHONPATH=src python examples/serve_pipeline.py
"""
from repro.launch.serve import ZOO_APPS, emulate

if __name__ == "__main__":
    print("workflows:", {k: [s.split(':')[1] for s in v.stages]
                         for k, v in ZOO_APPS.items()})
    for setting in ("strict-light", "relaxed-heavy"):
        print(f"--- {setting} ---")
        esg = emulate(setting=setting, n=150, scheduler="esg")
        inf = emulate(setting=setting, n=150, scheduler="infless")
        gain = esg["slo_hit_rate"] - inf["slo_hit_rate"]
        save = (inf["total_cost"] / esg["total_cost"] - 1) * 100
        print(f"    ESG vs INFless: hit {gain:+.2f}, cost saving {save:.0f}%")
