"""Online serving scenarios — demo of the ``repro.serving`` stack.

Drives the model-zoo cluster emulation through three scenario/policy
combinations and prints the telemetry each produces:

  1. a diurnal day/night load curve under the default EWMA pre-warmer;
  2. a flash crowd with no pre-warming at all (every burst pays cold
     starts) vs the HAS-GPU-style fine-grained autoscaler vs its
     vertical variant (fractional vGPU resizing of running pools) — the
     cold-start column is the whole story;
  3. a heavy-tailed (Azure-like) trace with a tight SLO so the gateway's
     load shedding engages.

Run:  PYTHONPATH=src python examples/serve_scenarios.py
"""
from repro.launch.serve import emulate
from repro.serving import format_table

N = 80
SEED = 0


def main():
    rows = []
    print("== diurnal, EWMA pre-warm (default policy) ==")
    rows.append(emulate(scenario="diurnal", n=N, seed=SEED, log=print))

    print("\n== flash crowd: no pre-warm vs fine-grained autoscaler ==")
    rows.append(emulate(scenario="flash-crowd", n=N, seed=SEED,
                        autoscaler="none", log=print))
    rows.append(emulate(scenario="flash-crowd", n=N, seed=SEED,
                        autoscaler="finegrained", log=print))
    rows.append(emulate(scenario="flash-crowd", n=N, seed=SEED,
                        autoscaler="vertical", log=print))

    print("\n== heavy-tailed arrivals, strict SLO (shedding engages) ==")
    rows.append(emulate(scenario="azure-tail", n=N, seed=SEED,
                        slo_mult=0.8, log=print))

    print("\n" + format_table(rows))


if __name__ == "__main__":
    main()
