"""Quickstart: serve a small model with ESG-batched requests (real compute).

Requests arrive on an AFW queue; ESG_1Q picks batch sizes from a measured
profile lattice; real JAX prefill+decode steps serve each dispatched batch.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.launch.serve import serve_real

if __name__ == "__main__":
    out = serve_real(arch="internlm2_1_8b", n_requests=24, slo_ms=30_000,
                     mean_interval_ms=30.0, gen_len=4, prompt_len=32)
    print(f"served {out['n']} requests: hit={out['hit_rate']:.2f} "
          f"p50={out['p50_ms']:.0f}ms p95={out['p95_ms']:.0f}ms")
