"""Quickstart: serve a small model through the full control plane
(real compute).

Scenario arrivals enter via the Gateway, ESG_1Q plans batch sizes from a
measured profile lattice, and every dispatched batch runs real Pallas
prefill + scalar-prefetch decode via the compile-cached RealExecutor.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.launch.serve import serve_real

if __name__ == "__main__":
    out = serve_real(arch="internlm2_1_8b", n_requests=24,
                     batches=(1, 2, 4), quotas=(1.0,),
                     gen_len=4, prompt_len=32, reps=1)
    ex = out["executor"]
    print(f"served {out['n_requests']} requests: "
          f"executed={ex['executed']} batches, "
          f"compile-cache hit rate={ex['post_warmup_hit_rate']:.2f}, "
          f"predicted-vs-measured err={out['mean_abs_err']:.1%}")
