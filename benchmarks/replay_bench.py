"""Flagship day-scale benchmark: million-invocation Azure replay,
sharded.

Three committed claims, all in ``BENCH_replay.json``:

1. **Fidelity** — a 1-shard sharded run is *bit-identical* to the
   legacy single-process emulator (schedule digests compared) on every
   scenario the planner bench covers, so the day-scale machinery
   (streaming arrivals, pooled tasks, streaming telemetry) changed no
   arithmetic.
2. **Scaling** — the shard-count curve (1/2/4/8 shards, one worker
   process per shard) over a peak-compressed slice of the day trace.
   The win is algorithmic, not just parallelism: partitioning divides
   the per-event scan breadth (non-empty queues, placement probes) that
   grows superlinearly in one big sim, so the curve holds even on a
   single core — multi-core machines multiply it further.
3. **Scale** — the full synthetic Azure-2019-shaped day
   (``make_day_trace.py``, checksum-pinned): >=1M invocations, >=200
   apps, replayed at 14x compression (a peak-stress setting: the
   gateway sheds hard, which is the point of a stress replay) on 8
   shards, with wall-clock, arrivals/sec and per-shard peak RSS.

Usage::

    python benchmarks/replay_bench.py            # guard vs baseline
    python benchmarks/replay_bench.py --update   # rewrite baseline
    python benchmarks/replay_bench.py --smoke    # CI: 2 shards, 3-min
                                                 # fixture, ratio guard,
                                                 # export merged obs
                                                 # artifacts

Guards are machine-independent: digest equality plus *ratios* measured
within one process on one box (4-shard speedup vs 1-shard, smoke
throughput ratio), never absolute wall-clock.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import Optional, Sequence

HERE = pathlib.Path(__file__).resolve().parent
ROOT = HERE.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(HERE / "traces"))

from convert_azure import convert, load_counts  # noqa: E402

from repro.cluster.emulator import ClusterSim  # noqa: E402
from repro.cluster.shard import (ReplayConfig, merge_results,  # noqa: E402
                                 paper_tables, run_shard, run_sharded)
from repro.core.profiles import PAPER_FUNCTIONS  # noqa: E402
from repro.core.scheduler import ESGScheduler  # noqa: E402
from repro.core.workflows import PAPER_APPS  # noqa: E402
from repro.serving import Gateway, get_autoscaler, get_scenario  # noqa: E402

BASELINE = ROOT / "BENCH_replay.json"
AZURE_FIXTURE = ROOT / "tests" / "fixtures" / "azure_2019_3min_sample.csv"
DAY_TRACE = HERE / "traces" / "azure_2019_day_synth.csv.gz"

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "trace-replay"]

# flagship configuration (committed — changing it invalidates baselines)
DAY_APPS = 240
DAY_SPEEDUP = 14.0       # compress the day: peak-stress replay
DAY_SHARDS = 8
CURVE_SHARDS = (1, 2, 4, 8)
CURVE_N = 150_000        # scaling curve runs a slice of the day
SEED = 3

# guards (ratios and identities only — no absolute wall-clock)
GUARDS = {
    "four_shard_speedup_min": 2.0,   # curve: 4 shards vs 1 shard
    "smoke_ratio_min": 0.25,         # smoke: 2-shard vs 1-shard inv/s
    "min_day_arrivals": 1_000_000,
    "min_day_apps": 200,
}


def _scenario_cfg(name: str, n: int, seed: int) -> ReplayConfig:
    kw: dict = {}
    if name == "trace-replay":
        rows = convert(load_counts(str(AZURE_FIXTURE)), seed=seed)
        kw = {"rows": rows, "speedup": 100.0}
    return ReplayConfig(scenario=name, scenario_kw=kw, n=n, seed=seed)


def legacy_digest(cfg: ReplayConfig) -> tuple[str, dict]:
    """The pre-sharding path: materialized arrivals, full retention,
    no pooling — the reference the 1-shard engine must reproduce."""
    tables = paper_tables()
    sched = ESGScheduler(dict(PAPER_APPS), tables,
                         plan_cache=cfg.fast_planner,
                         vectorized=cfg.fast_planner)
    sim = ClusterSim(dict(PAPER_APPS), tables, PAPER_FUNCTIONS, sched,
                     n_invokers=cfg.n_invokers, vcpus=cfg.vcpus,
                     vgpus=cfg.vgpus, noise_sigma=cfg.noise_sigma,
                     seed=cfg.seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), sparse=cfg.sparse,
                     track_digest=True)
    gw = Gateway(sim, shed_doomed=cfg.shed_doomed,
                 backlog_aware=cfg.backlog_aware)
    sc = get_scenario(cfg.scenario, app_names=list(PAPER_APPS),
                      **dict(cfg.scenario_kw))
    gw.inject(sc, cfg.n, seed=cfg.seed + 1, slo_mult=cfg.slo_mult)
    sim.run()
    gw.telemetry.collect(sim)
    return sim.run_digest(), sim.summary()


def verify_digests(n: int, seed: int) -> dict:
    """Claim 1: 1-shard sharded == legacy on every scenario."""
    out: dict = {}
    for name in SCENARIO_NAMES:
        cfg = _scenario_cfg(name, n, seed)
        r = run_shard(cfg, 0, 1)
        ld, ls = legacy_digest(cfg)
        out[name] = {
            "identical": r.digest == ld,
            "digest": r.digest,
            "completed": r.summary["completed"],
            "legacy_completed": ls["completed"],
        }
        status = "OK" if r.digest == ld else "MISMATCH"
        print(f"[replay-bench] digest {name}: {status} "
              f"({r.summary['completed']} completed)")
    return out


def _day_cfg(n: int) -> ReplayConfig:
    return ReplayConfig(
        scenario="trace-replay",
        scenario_kw={"csv_path": str(DAY_TRACE), "presorted": True,
                     "speedup": DAY_SPEEDUP},
        n=n, n_apps=DAY_APPS, seed=SEED)


def ensure_day_trace() -> None:
    import make_day_trace
    if not DAY_TRACE.exists():
        print("[replay-bench] generating day trace "
              "(make_day_trace.py defaults)...")
        make_day_trace.main([])
    rc = make_day_trace.main(["--verify"])
    if rc != 0:
        raise SystemExit("[replay-bench] day-trace checksum mismatch — "
                         "regenerate with make_day_trace.py")


def scaling_curve(n: int) -> dict:
    """Claim 2: shard-count scaling on a peak slice of the day."""
    cfg = _day_cfg(n)
    curve: dict = {}
    base_wall = None
    for s in CURVE_SHARDS:
        m = run_sharded(cfg, s, workers=s)
        wall = m["wall_s"]
        if base_wall is None:
            base_wall = wall
        curve[str(s)] = {
            "wall_s": wall,
            "inv_per_sec": n / wall,
            "speedup_vs_1shard": base_wall / wall,
            "slo_attainment": m["slo_attainment"],
            "cost_per_1k": m["cost_per_1k"],
            "utilization": m["utilization"],
            "completed": m["completed"],
            "shed": m["shed"],
            "peak_rss_mb_per_shard": [p["peak_rss_mb"]
                                      for p in m["per_shard"]],
            "digest": m["digest"],
        }
        print(f"[replay-bench] curve shards={s}: wall={wall:.1f}s "
              f"({n / wall:.0f} inv/s, {base_wall / wall:.2f}x), "
              f"slo={m['slo_attainment']:.3f}", flush=True)
    return curve


def flagship(n_day: int) -> dict:
    """Claim 3: the full day at the best shard count."""
    cfg = _day_cfg(n_day)
    m = run_sharded(cfg, DAY_SHARDS, workers=DAY_SHARDS)
    out = {
        "arrivals": m["arrivals"],
        "apps": DAY_APPS,
        "shards": DAY_SHARDS,
        "speedup": DAY_SPEEDUP,
        "wall_s": m["wall_s"],
        "inv_per_sec": m["arrivals"] / m["wall_s"],
        "completed": m["completed"],
        "shed": m["shed"],
        "slo_attainment": m["slo_attainment"],
        "cost_per_1k": m["cost_per_1k"],
        "utilization": m["utilization"],
        "latency": m["latency"],
        "digest": m["digest"],
        "per_shard": m["per_shard"],
    }
    print(f"[replay-bench] flagship: {m['arrivals']} arrivals on "
          f"{DAY_SHARDS} shards in {m['wall_s']:.1f}s "
          f"({out['inv_per_sec']:.0f} inv/s)", flush=True)
    return out


def smoke(export_dir: Optional[str]) -> dict:
    """CI job: 3-minute fixture, 2 shards — digest fidelity, exact
    merge, parallel==sequential, throughput ratio, merged obs exports."""
    rows = convert(load_counts(str(AZURE_FIXTURE)), seed=SEED)
    n = min(len(rows) * 3, 6000)
    kw = {"rows": rows, "speedup": 100.0}
    cfg = ReplayConfig(scenario="trace-replay", scenario_kw=kw,
                       n=n, n_apps=24, seed=SEED)

    t0 = time.perf_counter()
    one = run_sharded(cfg, 1, workers=1)
    wall1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    two = run_sharded(cfg, 2, workers=2)
    wall2 = time.perf_counter() - t0
    two_seq = run_sharded(cfg, 2, workers=1)

    # digest fidelity vs legacy (paper apps, same scenario family)
    dv = verify_digests(n=800, seed=SEED)

    # obs artifacts ride the full-retention recorder path
    exports = {}
    if export_dir:
        rec_cfg = ReplayConfig(scenario="trace-replay", scenario_kw=kw,
                               n=min(n, 1500), n_apps=24, seed=SEED,
                               retain="full", record=True)
        rec = run_sharded(rec_cfg, 2, workers=1, export_dir=export_dir)
        exports = rec.get("exports", {})

    ratio = (n / wall2) / (n / wall1)
    return {
        "n": n,
        "arrivals_accounted": two["completed"] + two["shed"] == n
                              and one["completed"] + one["shed"] == n,
        "merge_exact": two["completed"] == sum(
            p["completed"] for p in two["per_shard"]),
        "parallel_eq_sequential": two["digest"] == two_seq["digest"],
        "digests": dv,
        "throughput_ratio_2v1": ratio,
        "exports": exports,
    }


def check_guards(doc: dict, smoke_mode: bool) -> list[str]:
    fails: list[str] = []
    digests = doc.get("smoke", {}).get("digests") if smoke_mode \
        else doc.get("digest_verification")
    for name, d in (digests or {}).items():
        if not d["identical"]:
            fails.append(f"digest mismatch vs legacy on {name}")
    if smoke_mode:
        s = doc["smoke"]
        if not s["arrivals_accounted"]:
            fails.append("smoke: arrivals not fully accounted")
        if not s["merge_exact"]:
            fails.append("smoke: merged totals != sum of shards")
        if not s["parallel_eq_sequential"]:
            fails.append("smoke: parallel run != sequential run")
        if s["throughput_ratio_2v1"] < GUARDS["smoke_ratio_min"]:
            fails.append(
                f"smoke: 2-shard throughput ratio "
                f"{s['throughput_ratio_2v1']:.2f} < "
                f"{GUARDS['smoke_ratio_min']}")
        return fails
    curve = doc["scaling_curve"]
    if curve["4"]["speedup_vs_1shard"] < GUARDS["four_shard_speedup_min"]:
        fails.append(f"curve: 4-shard speedup "
                     f"{curve['4']['speedup_vs_1shard']:.2f}x < "
                     f"{GUARDS['four_shard_speedup_min']}x")
    day = doc["flagship"]
    if day["arrivals"] < GUARDS["min_day_arrivals"]:
        fails.append(f"flagship: {day['arrivals']} arrivals < "
                     f"{GUARDS['min_day_arrivals']}")
    if day["apps"] < GUARDS["min_day_apps"]:
        fails.append(f"flagship: {day['apps']} apps < "
                     f"{GUARDS['min_day_apps']}")
    return fails


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 2 shards over the 3-minute fixture")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baseline")
    ap.add_argument("--export-dir", default=None,
                    help="(smoke) directory for merged obs artifacts")
    ap.add_argument("--curve-n", type=int, default=CURVE_N)
    args = ap.parse_args(argv)

    doc: dict = {
        "meta": {
            "seed": SEED,
            "smoke": args.smoke,
            "day_trace": DAY_TRACE.name,
            "scenarios": SCENARIO_NAMES,
            "note": "wall-clock gains are algorithmic (partitioned "
                    "per-event state), measured on a single core; "
                    "multi-core parallelism multiplies them",
        },
        "guards": GUARDS,
    }
    if args.smoke:
        doc["smoke"] = smoke(args.export_dir)
    else:
        ensure_day_trace()
        doc["digest_verification"] = verify_digests(n=2000, seed=SEED)
        doc["scaling_curve"] = scaling_curve(args.curve_n)
        import csv
        import gzip
        with gzip.open(DAY_TRACE, "rt") as f:
            n_day = sum(1 for _ in f) - 1
        doc["flagship"] = flagship(n_day)

    fails = check_guards(doc, args.smoke)
    for f in fails:
        print(f"[replay-bench] GUARD FAIL: {f}")
    if args.smoke:
        print(json.dumps(doc["smoke"], indent=1, default=str)[:2000])
        if args.update:
            # smoke-scale baseline: meta.smoke records the scale, and the
            # guard-mode drift check only compares keys the baseline has
            BASELINE.write_text(json.dumps(doc, indent=1, sort_keys=True,
                                           default=str) + "\n")
            print(f"[replay-bench] smoke-scale baseline written "
                  f"-> {BASELINE}")
        return 1 if fails else 0
    if args.update:
        BASELINE.write_text(json.dumps(doc, indent=1, sort_keys=True,
                                       default=str) + "\n")
        print(f"[replay-bench] baseline written -> {BASELINE}")
        return 1 if fails else 0
    # guard mode: recompute digests must match the committed baseline
    if BASELINE.exists():
        base = json.loads(BASELINE.read_text())
        for name, d in doc["digest_verification"].items():
            bd = base.get("digest_verification", {}).get(name, {})
            if bd.get("digest") and bd["digest"] != d["digest"]:
                fails.append(f"digest drift vs baseline on {name}: "
                             f"{bd['digest']} -> {d['digest']}")
                print(f"[replay-bench] GUARD FAIL: {fails[-1]}")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
