"""Weight-locality sweep: memory-blind vs memory-aware placement, with
and without shared read-only weights, under finite HBM.

For each serving scenario the same trace runs through the same ESG
scheduler and warm-pool policy under three weight-residency regimes:

  * ``blind``       — PR-2 defaults: paper-§3.4 locality placement,
                      per-container weight copies (Torpor's thrash case);
  * ``memory``      — ``placement="memory"``: the fallback leg of
                      placement ranks invokers hot > warm > cold by the
                      restart penalty their warm state implies, and the
                      ESG planner prices the predicted swap-in into its
                      A* search; still per-container copies;
  * ``mem+shared``  — ``memory`` plus ``shared_weights=True``: all
                      containers of one function on a device map a single
                      refcounted checkpoint, so N containers charge
                      ``model_mb`` once (Torpor's pool-density win).

Invokers carry finite HBM (``--hbm-mb`` per vGPU) so the hot/warm tiers
matter.  The point of the figure: ``mem+shared`` must *strictly* reduce
swap-ins vs ``blind`` and improve SLO attainment or $/1k requests — the
acceptance bar the differential test harness also enforces.

    PYTHONPATH=src python benchmarks/locality_sweep.py --smoke
    PYTHONPATH=src python benchmarks/locality_sweep.py --seed 7 \
        --scenarios mmpp azure-tail --hbm-mb 384

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse

import scenario_sweep
from common import write_csv
from repro.serving import format_table

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "trace-replay"]
# mode -> (ESG placement, shared_weights)
MODES = {"blind": ("locality", False),
         "memory": ("memory", False),
         "mem+shared": ("memory", True)}

CSV_COLS = ["scenario", "mode", "placement", "shared_weights",
            "slo_attainment", "cost_per_1k", "completed", "shed",
            "cold_starts", "swap_ins", "swap_in_ms", "demotions",
            "hot_hits", "shared_hits", "hbm_peak_mb", "utilization",
            "p95_ms"]

EXTRA_TABLE_COLS = [("mode", "mode", "{}"),
                    ("swap_ins", "swaps", "{}"),
                    ("demotions", "demo", "{}"),
                    ("shared_hits", "shrd", "{}")]


def run_cell(scenario_name: str, mode: str, n: int, seed: int,
             slo_mult: float, hbm_mb: float, autoscaler: str,
             trace_csv: str | None = None) -> dict:
    placement, shared = MODES[mode]
    s = scenario_sweep.run_cell(scenario_name, "ESG", autoscaler, n, seed,
                                slo_mult, hbm_mb=hbm_mb,
                                trace_csv=trace_csv, shared_weights=shared,
                                sched_kw={"placement": placement})
    s["mode"] = mode
    s["placement"] = placement
    s["shared_weights"] = shared
    for k in ("swap_ins", "swap_in_ms", "demotions", "hot_hits",
              "shared_hits", "hbm_peak_mb"):
        s[k] = s["gpu"][k]
    return s


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n / scenario subset for CI")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--hbm-mb", type=float, default=512.0,
                    help="HBM per vGPU slice-unit (MB); finite so weight "
                         "residency is a real constraint")
    ap.add_argument("--autoscaler", default="ewma",
                    choices=["ewma", "finegrained", "vertical", "none"])
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--trace-csv", default=None,
                    help="CSV for trace-replay (default: built-in sample)")
    args = ap.parse_args()

    scenarios = args.scenarios or SCENARIO_NAMES
    n = args.n
    if args.smoke:
        scenarios = args.scenarios or ["mmpp", "azure-tail"]
        n = n or 40
    n = n or 200

    rows, by_cell = [], {}
    for sc in scenarios:
        for mode in MODES:
            s = run_cell(sc, mode, n, args.seed, args.slo_mult,
                         args.hbm_mb, args.autoscaler, args.trace_csv)
            rows.append(s)
            by_cell[(sc, mode)] = s
    print(format_table(rows, extra_cols=EXTRA_TABLE_COLS))

    wins = []
    for sc in scenarios:
        b, m = by_cell[(sc, "blind")], by_cell[(sc, "mem+shared")]
        fewer_swaps = m["swap_ins"] < b["swap_ins"]
        better_slo = m["slo_attainment"] > b["slo_attainment"] + 1e-9
        cheaper = m["cost_per_1k"] < b["cost_per_1k"] - 1e-9
        win = fewer_swaps and (better_slo or cheaper)
        if win:
            wins.append(sc)
        print(f"[locality-sweep] {sc:14s} mem+shared vs blind: "
              f"swaps {m['swap_ins']} vs {b['swap_ins']}, "
              f"slo {m['slo_attainment']:.3f} vs {b['slo_attainment']:.3f}, "
              f"$/1k {m['cost_per_1k']:.4f} vs {b['cost_per_1k']:.4f} "
              f"{'WIN' if win else '-'}")
    verdict = (f"mem+shared beats blind on {len(wins)}/{len(scenarios)} "
               f"scenarios: {wins}" if wins else
               "mem+shared did not beat blind anywhere (unexpected)")
    print(f"[locality-sweep] {verdict}")

    path = write_csv("locality_sweep", CSV_COLS,
                     scenario_sweep.rows_to_csv(rows, CSV_COLS))
    print(f"[locality-sweep] n={n} seed={args.seed} "
          f"hbm={args.hbm_mb:.0f}MB/vGPU -> {path}")
    return 0 if len(wins) == len(scenarios) else 1


if __name__ == "__main__":
    raise SystemExit(main())
