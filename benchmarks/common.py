"""Shared benchmark plumbing: scheduler zoo + emulation runs + CSV out."""
from __future__ import annotations

import csv
import pathlib
import time

from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.workflows import PAPER_APPS
from repro.cluster.emulator import ClusterSim
from repro.cluster.workload import generate
from repro.core.scheduler import ESGScheduler
from repro.core.baselines.infless import INFlessScheduler
from repro.core.baselines.fastgshare import FaSTGShareScheduler
from repro.core.baselines.orion import OrionScheduler
from repro.core.baselines.aquatope import AquatopeScheduler

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
N_DEFAULT = 200
SETTINGS = ["strict-light", "moderate-normal", "relaxed-heavy"]


def paper_tables() -> dict[str, ProfileTable]:
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def make_scheduler(name: str, tables, **kw):
    factories = {
        "ESG": lambda: ESGScheduler(PAPER_APPS, tables, **kw),
        "INFless": lambda: INFlessScheduler(PAPER_APPS, tables),
        "FaST-GShare": lambda: FaSTGShareScheduler(PAPER_APPS, tables),
        "Orion": lambda: OrionScheduler(PAPER_APPS, tables, **kw),
        "Aquatope": lambda: AquatopeScheduler(PAPER_APPS, tables),
    }
    return factories[name]()


def run_setting(name: str, setting: str, n: int = N_DEFAULT, seed: int = 0,
                tables=None, sched=None, scenario: str | None = None,
                **sim_kw) -> dict:
    """One (scheduler, SLO-setting) emulation run.

    ``scenario`` swaps the paper's uniform-interval arrival process for a
    named ``repro.serving.traces`` scenario (diurnal, mmpp, flash-crowd,
    azure-tail, trace-replay, ...) while keeping the setting's SLO
    multiplier — so every paper figure can be regenerated per scenario."""
    tables = tables or paper_tables()
    sched = sched or make_scheduler(name, tables)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, **sim_kw)
    if scenario is None:
        generate(sim, setting, n, PAPER_FUNCTIONS, seed=seed + 1)
    else:
        from repro.cluster.workload import SETTINGS, SLO_MULT, \
            min_config_latency
        from repro.serving import get_scenario
        mult = SLO_MULT[SETTINGS[setting][0]]
        slos = {a: mult * min_config_latency(sim.apps[a], PAPER_FUNCTIONS)
                for a in sim.apps}
        sc = get_scenario(scenario, app_names=list(sim.apps))
        for arr in sc.arrivals(list(sim.apps), n, seed=seed + 1):
            sim.add_arrival(arr.app, arr.t_ms, slos[arr.app], arr.uid)
    t0 = time.time()
    sim.run()
    out = sim.summary()
    out["setting"] = setting
    out["scenario"] = scenario or "uniform"
    out["wall_s"] = time.time() - t0
    out["per_app"] = per_app_stats(sim)
    return out


def per_app_stats(sim: ClusterSim) -> dict:
    stats: dict[str, dict] = {}
    for inst in sim.completed:
        d = stats.setdefault(inst.app.name, {"lat": [], "hit": 0, "n": 0})
        lat = inst.finish_ms - inst.arrival_ms
        d["lat"].append(lat)
        d["n"] += 1
        d["hit"] += int(lat <= inst.slo_ms)
    out = {}
    for app, d in stats.items():
        lats = sorted(d["lat"])
        out[app] = {
            "n": d["n"],
            "hit_rate": d["hit"] / d["n"],
            "mean_ms": sum(lats) / len(lats),
            "p95_ms": lats[int(0.95 * (len(lats) - 1))],
        }
    return out


def app_costs(sim: ClusterSim) -> dict[str, float]:
    out: dict[str, float] = {}
    for t in sim.tasks:
        app = t.jobs[0].inst.app.name
        out[app] = out.get(app, 0.0) + t.cost
    return out


def write_csv(name: str, header: list[str], rows: list[list]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path
