"""vGPU granularity sweep: container-granularity vs fractional vertical
scaling on the shareable-GPU device model.

For each serving scenario (the PR-1 library + trace replay) the sweep
runs the same trace through the same scheduler under three warm-pool /
quota regimes:

  * ``ewma``        — paper-§4 EWMA pre-warming, whole containers only;
  * ``container``   — HAS-GPU-style fine-grained pool sizing
                      (``finegrained``), still whole containers;
  * ``fractional``  — ``vertical``: same pool sizing *plus* fractional
                      vGPU resizing of running pools (grow into idle
                      slices, shrink under congestion).

Invokers carry finite HBM (``--hbm-mb`` per vGPU) so the two-tier warm
state matters: the table reports swap-ins and demotions next to SLO
attainment, $/1k requests and resize counts.  The point of the figure:
``fractional`` should beat ``container`` on SLO attainment and/or $-cost
on at least the bursty scenarios — the vertical lever converts idle
slices into early finishes and converts queued bursts into admissible
work.

    PYTHONPATH=src python benchmarks/vgpu_sweep.py --smoke
    PYTHONPATH=src python benchmarks/vgpu_sweep.py --seed 7 \
        --scenarios flash-crowd mmpp --scheduler ESG

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse

import scenario_sweep
from common import write_csv
from repro.serving import format_table

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "trace-replay"]
MODES = {"ewma": "ewma", "container": "finegrained", "fractional": "vertical"}

CSV_COLS = ["scenario", "mode", "autoscaler", "slo_attainment", "cost_per_1k",
            "completed", "shed", "cold_starts", "swap_ins", "demotions",
            "resizes_up", "resizes_down", "utilization", "p95_ms"]

EXTRA_TABLE_COLS = [("mode", "mode", "{}"),
                    ("swaps", "swaps", "{}"),
                    ("resizes", "resz", "{}")]


def run_cell(scenario_name: str, mode: str, scheduler: str, n: int,
             seed: int, slo_mult: float, hbm_mb: float,
             trace_csv: str | None = None) -> dict:
    s = scenario_sweep.run_cell(scenario_name, scheduler, MODES[mode],
                                n, seed, slo_mult, hbm_mb=hbm_mb,
                                trace_csv=trace_csv)
    s["mode"] = mode
    for k in ("swap_ins", "demotions", "resizes_up", "resizes_down"):
        s[k] = s["gpu"][k]
    s["swaps"] = s["swap_ins"]
    s["resizes"] = s["resizes_up"] + s["resizes_down"]
    return s


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n / scenario subset for CI")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--hbm-mb", type=float, default=1024.0,
                    help="HBM per vGPU slice-unit (MB); finite so the "
                         "hot/warm swap tiers are exercised")
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--scheduler", default="ESG")
    ap.add_argument("--trace-csv", default=None,
                    help="CSV for trace-replay (default: built-in sample)")
    args = ap.parse_args()

    scenarios = args.scenarios or SCENARIO_NAMES
    n = args.n
    if args.smoke:
        scenarios = args.scenarios or ["flash-crowd", "mmpp"]
        n = n or 40
    n = n or 200

    rows, by_cell = [], {}
    for sc in scenarios:
        for mode in MODES:
            s = run_cell(sc, mode, args.scheduler, n, args.seed,
                         args.slo_mult, args.hbm_mb, args.trace_csv)
            rows.append(s)
            by_cell[(sc, mode)] = s
    print(format_table(rows, extra_cols=EXTRA_TABLE_COLS))

    wins = []
    for sc in scenarios:
        f, c = by_cell[(sc, "fractional")], by_cell[(sc, "container")]
        better_slo = f["slo_attainment"] > c["slo_attainment"] + 1e-9
        cheaper = f["cost_per_1k"] < c["cost_per_1k"] - 1e-9
        if better_slo or cheaper:
            wins.append(sc)
        print(f"[vgpu-sweep] {sc:14s} fractional vs container: "
              f"slo {f['slo_attainment']:.3f} vs {c['slo_attainment']:.3f}, "
              f"$/1k {f['cost_per_1k']:.4f} vs {c['cost_per_1k']:.4f} "
              f"{'WIN' if better_slo or cheaper else '-'}")
    verdict = (f"fractional beats container on {len(wins)}/{len(scenarios)} "
               f"scenarios: {wins}" if wins else
               "fractional did not beat container anywhere (unexpected)")
    print(f"[vgpu-sweep] {verdict}")

    path = write_csv("vgpu_sweep", CSV_COLS,
                     scenario_sweep.rows_to_csv(rows, CSV_COLS))
    print(f"[vgpu-sweep] n={n} seed={args.seed} hbm={args.hbm_mb:.0f}MB/vGPU "
          f"-> {path}")
    return 0 if wins else 1


if __name__ == "__main__":
    raise SystemExit(main())
