"""Fig 11: sensitivity to K (solutions kept in the config priority queue),
strict-light; cost normalised to K=5."""
from __future__ import annotations

from benchmarks import common


def run(n: int = 150, seed: int = 0, log=print):
    rows = []
    base_cost = None
    for k in (1, 5, 20, 80):
        tables = common.paper_tables()
        sched = common.make_scheduler("ESG", tables, k=k)
        r = common.run_setting("ESG", "strict-light", n=n, seed=seed,
                               tables=tables, sched=sched)
        if k == 5:
            base_cost = r["total_cost"]
    # second pass so normalisation has the K=5 reference
    for k in (1, 5, 20, 80):
        tables = common.paper_tables()
        sched = common.make_scheduler("ESG", tables, k=k)
        r = common.run_setting("ESG", "strict-light", n=n, seed=seed,
                               tables=tables, sched=sched)
        rows.append([k, f"{r['slo_hit_rate']:.4f}",
                     f"{r['total_cost']/base_cost:.3f}",
                     f"{r['mean_sched_overhead_ms']:.3f}",
                     f"{r['mean_latency_ms']:.1f}"])
        log(f"  K={k:3d} hit={r['slo_hit_rate']:.3f} "
            f"cost(K5=1)={r['total_cost']/base_cost:.3f} "
            f"ovh={r['mean_sched_overhead_ms']:.2f}ms")
    common.write_csv("fig11_k_sensitivity",
                     ["K", "slo_hit_rate", "cost_norm_k5",
                      "mean_overhead_ms", "mean_latency_ms"], rows)
    return rows


if __name__ == "__main__":
    run()
