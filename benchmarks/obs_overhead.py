"""Flight-recorder overhead benchmark: recorder on vs off, same schedule.

Replays the 3-minute Azure 2019 fixture
(``tests/fixtures/azure_2019_3min_sample.csv`` through ``convert_azure``)
twice — once bare, once with the full ``repro.obs.Recorder`` (spans +
metrics bus + planner audit) attached — and checks the recorder's two
contracts:

  * **invisibility** — the schedule digest (placement, pricing, timing,
    GPU ledger) is bit-identical with the recorder on: observing a run
    must not change it;
  * **cheapness** — end-to-end wall-clock overhead of recording stays
    under ``OVERHEAD_MAX`` (15%, the ISSUE-6 acceptance bar).  All arms
    are timed round-robin in one interleaved loop and compared by
    best-of-``--repeat``: the workload is deterministic, so scheduling
    noise, frequency scaling and cache pollution are strictly additive
    — the minimum is the least-contaminated estimate of each arm's true
    cost (the ``timeit`` convention), and interleaving keeps a drifting
    host from biasing whichever arm happens to run last.

The recorded arm also exports trace/metrics/audit to a temp dir and
runs ``repro.obs.validate`` over them, so the benchmark doubles as an
end-to-end smoke of the export pipeline.

A third, **closed-loop** arm re-times the recorded run with the online
profile calibrator and the SLO health engine attached (ISSUE-7): the
feedback layer may legitimately *change* the schedule, so it is held to
the same <15% wall-clock bar but not to the digest check, and its
health-alert export is validated alongside the passive artifacts.
Results land in ``benchmarks/results/obs_overhead.json``.

    PYTHONPATH=src python benchmarks/obs_overhead.py
    PYTHONPATH=src python benchmarks/obs_overhead.py --n 120 --repeat 5
"""
from __future__ import annotations

import argparse
import gc
import json
import pathlib
import sys
import tempfile
import time

HERE = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(HERE / "traces"))

from common import PAPER_APPS, ClusterSim, paper_tables  # noqa: E402
from convert_azure import convert, load_counts  # noqa: E402
from planner_bench import AZURE_FIXTURE, schedule_digest  # noqa: E402
from repro.core.profiles import PAPER_FUNCTIONS  # noqa: E402
from repro.core.scheduler import ESGScheduler  # noqa: E402
from repro.obs import HealthEngine, ProfileCalibrator, Recorder  # noqa: E402
from repro.obs.validate import validate_health, validate_metrics, \
    validate_nesting, validate_trace  # noqa: E402
from repro.serving import Gateway, get_autoscaler  # noqa: E402
from repro.serving.traces import TraceReplayScenario  # noqa: E402

OUT = HERE / "results" / "obs_overhead.json"
OVERHEAD_MAX = 0.15            # ISSUE-6 acceptance bar


def run_once(rows, n: int, seed: int, recorder=None, calibrate=False):
    sched = ESGScheduler(PAPER_APPS, paper_tables())
    if calibrate and recorder is not None:
        sched.calibrator = ProfileCalibrator().attach(recorder.audit)
    sim = ClusterSim(PAPER_APPS, sched.tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), recorder=recorder)
    gw = Gateway(sim, health=recorder.health if recorder else None)
    gw.inject(TraceReplayScenario(rows=rows, speedup=1.0), n, seed=seed + 1,
              slo_mult=1.0)
    # CPU time, not wall-clock: the overhead ratio must survive noisy
    # neighbours on shared CI runners, and recording burns cycles, not I/O
    t0 = time.process_time()
    gw.run()
    return sim, time.process_time() - t0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=200,
                    help="requests replayed from the fixture")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=5,
                    help="interleaved timing pairs (median-of)")
    ap.add_argument("--out", default=str(OUT))
    args = ap.parse_args()

    rows = convert(load_counts(str(AZURE_FIXTURE)), seed=args.seed)

    # one recorded run kept for the digest + export checks ...
    recorder = Recorder()
    sim_on, _ = run_once(rows, args.n, args.seed, recorder=recorder)
    sim_off, _ = run_once(rows, args.n, args.seed)
    identical = schedule_digest(sim_on) == schedule_digest(sim_off)

    # ... then round-robin best-of-N timing for the ratios.  The third,
    # closed-loop arm (ISSUE-7) re-times the recorded run with the
    # online calibrator and the health engine attached: feedback may
    # legitimately change the schedule, so it skips the digest check but
    # is held to the same wall-clock bar against the same bare baseline.
    wall_off, wall_on, wall_closed = [], [], []
    for _ in range(max(args.repeat, 1)):
        gc.collect()
        wall_off.append(run_once(rows, args.n, args.seed)[1])
        gc.collect()
        wall_on.append(run_once(rows, args.n, args.seed,
                                recorder=Recorder())[1])
        gc.collect()
        wall_closed.append(run_once(
            rows, args.n, args.seed, calibrate=True,
            recorder=Recorder(health=HealthEngine()))[1])
    off = min(wall_off)
    on = min(wall_on)
    overhead = on / off - 1.0
    closed = min(wall_closed)
    closed_overhead = closed / off - 1.0
    rec_closed = Recorder(health=HealthEngine())
    sim_closed, _ = run_once(rows, args.n, args.seed, recorder=rec_closed,
                             calibrate=True)
    cal_state = sim_closed.sched.calibrator.summary()
    cal_state.pop("per_stage", None)

    # export + validate the observed run's artifacts
    with tempfile.TemporaryDirectory() as td:
        td = pathlib.Path(td)
        recorder.export(str(td / "trace.json"), str(td / "metrics.json"),
                        str(td / "audit.jsonl"))
        trace = json.loads((td / "trace.json").read_text())
        cats = validate_trace(trace)
        validate_nesting(trace)
        metrics = json.loads((td / "metrics.json").read_text())
        validate_metrics(metrics)
        audit_lines = [json.loads(l) for l in
                       (td / "audit.jsonl").read_text().splitlines()]
        rec_closed.export(health_path=str(td / "health.jsonl"))
        alerts = [json.loads(l) for l in
                  (td / "health.jsonl").read_text().splitlines()]
        validate_health(alerts, str(td / "health.jsonl"))

    cal = recorder.calibration()
    cal.pop("per_stage", None)
    report = {
        "meta": {"n": args.n, "seed": args.seed, "repeat": args.repeat,
                 "fixture": AZURE_FIXTURE.name},
        "identical": identical,
        "wall_s_off": off, "wall_s_on": on, "overhead_frac": overhead,
        "wall_s_closed_loop": closed,
        "closed_loop_overhead_frac": closed_overhead,
        "overhead_max": OVERHEAD_MAX,
        "trace_spans": cats,
        "metrics_series": len(metrics["series"]),
        "audit_records": len(audit_lines),
        "health_alerts": len(alerts),
        "calibration": cal,
        "calibrator": cal_state,
    }
    print(f"[obs-overhead] azure 3-min fixture (n={args.n}): "
          f"off {off:.2f}s vs on {on:.2f}s -> +{overhead:.1%} "
          f"(bar {OVERHEAD_MAX:.0%})  identical={identical}")
    print(f"[obs-overhead] closed loop (calibrate+health): {closed:.2f}s "
          f"-> +{closed_overhead:.1%} (same bar); "
          f"{cal_state['observations']} obs, "
          f"{cal_state['updates']} factor updates, {len(alerts)} alerts")
    print(f"[obs-overhead] exports: {sum(cats.values())} spans "
          f"({cats}), {len(metrics['series'])} metric series, "
          f"{len(audit_lines)} audit records, calibration n={cal.get('n')}")

    failures = []
    if not identical:
        failures.append("recorder changed the schedule "
                        "(digest mismatch on vs off)")
    if overhead > OVERHEAD_MAX:
        failures.append(f"recording overhead {overhead:.1%} > "
                        f"{OVERHEAD_MAX:.0%} bar")
    if closed_overhead > OVERHEAD_MAX:
        failures.append(f"closed-loop overhead {closed_overhead:.1%} > "
                        f"{OVERHEAD_MAX:.0%} bar")
    if not audit_lines:
        failures.append("audit log empty")

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    print(f"[obs-overhead] report -> {out}")
    for f in failures:
        print(f"[obs-overhead] FAIL: {f}")
    if not failures:
        print("[obs-overhead] OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
