"""Scenario sweep: ESG vs baselines across the serving-scenario library.

Runs every (scenario, scheduler) pair through the online serving stack
(``repro.serving``: trace engine -> gateway admission -> emulator with a
pluggable warm-pool autoscaler) and prints the telemetry table the paper's
uniform settings cannot produce: SLO attainment under diurnal swings,
MMPP bursts, flash crowds and heavy-tailed arrivals, with $/1k requests,
cold-start and shed counts.

    PYTHONPATH=src python benchmarks/scenario_sweep.py --smoke
    PYTHONPATH=src python benchmarks/scenario_sweep.py --seed 7 \
        --schedulers ESG INFless Orion --autoscaler finegrained

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse

from common import PAPER_APPS, ClusterSim, make_scheduler, paper_tables, \
    write_csv
from repro.core.profiles import PAPER_FUNCTIONS
from repro.serving import Gateway, format_table, get_autoscaler, get_scenario

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "skewed-mix"]
SCHEDULERS = ["ESG", "INFless", "FaST-GShare", "Orion", "Aquatope"]

CSV_COLS = ["scenario", "scheduler", "autoscaler", "injected", "admitted",
            "shed", "completed", "slo_attainment", "cost_per_1k",
            "cold_starts", "utilization", "p95_ms"]


def run_cell(scenario_name: str, scheduler: str, autoscaler: str,
             n: int, seed: int, slo_mult: float,
             count_overhead: bool = False) -> dict:
    tables = paper_tables()
    # count_overhead folds *measured wall-clock* search time into simulated
    # latency (the Fig 9/10 methodology) — off by default here so the sweep
    # is bit-deterministic under --seed
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     make_scheduler(scheduler, tables), seed=seed,
                     autoscaler=get_autoscaler(autoscaler),
                     count_overhead=count_overhead)
    gw = Gateway(sim)
    sc = get_scenario(scenario_name, app_names=list(PAPER_APPS))
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario_name
    return tel.summary()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n / scenario subset for CI")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--schedulers", nargs="*", default=None)
    ap.add_argument("--autoscaler", default="ewma",
                    choices=["ewma", "finegrained", "none"])
    ap.add_argument("--count-overhead", action="store_true",
                    help="fold measured scheduler wall time into latency "
                         "(Fig 9/10 methodology; breaks bit-determinism)")
    args = ap.parse_args()

    scenarios = args.scenarios or SCENARIO_NAMES
    schedulers = args.schedulers or SCHEDULERS
    n = args.n
    if args.smoke:
        scenarios = args.scenarios or ["diurnal", "mmpp", "flash-crowd",
                                       "azure-tail"]
        schedulers = args.schedulers or ["ESG", "INFless", "Orion"]
        n = n or 40
    n = n or 200

    rows = []
    for sc in scenarios:
        for sched in schedulers:
            s = run_cell(sc, sched, args.autoscaler, n, args.seed,
                         args.slo_mult, count_overhead=args.count_overhead)
            rows.append(s)
    print(format_table(rows))
    csv_rows = [[r.get(c, r["latency"]["p95_ms"] if c == "p95_ms" else "")
                 for c in CSV_COLS] for r in rows]
    path = write_csv("scenario_sweep", CSV_COLS, csv_rows)
    print(f"\n[scenario-sweep] n={n} seed={args.seed} "
          f"autoscaler={args.autoscaler} -> {path}")


if __name__ == "__main__":
    main()
