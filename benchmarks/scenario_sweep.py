"""Scenario sweep: ESG vs baselines across the serving-scenario library.

Runs every (scenario, scheduler) pair through the online serving stack
(``repro.serving``: trace engine -> gateway admission -> emulator with a
pluggable warm-pool autoscaler) and prints the telemetry table the paper's
uniform settings cannot produce: SLO attainment under diurnal swings,
MMPP bursts, flash crowds and heavy-tailed arrivals, with $/1k requests,
cold-start and shed counts.

    PYTHONPATH=src python benchmarks/scenario_sweep.py --smoke
    PYTHONPATH=src python benchmarks/scenario_sweep.py --seed 7 \
        --schedulers ESG INFless Orion --autoscaler finegrained

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse

from common import PAPER_APPS, ClusterSim, make_scheduler, paper_tables, \
    write_csv
from repro.core.profiles import PAPER_FUNCTIONS
from repro.serving import Gateway, format_table, get_autoscaler, get_scenario

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "skewed-mix", "trace-replay"]
SCHEDULERS = ["ESG", "INFless", "FaST-GShare", "Orion", "Aquatope"]

CSV_COLS = ["scenario", "scheduler", "autoscaler", "injected", "admitted",
            "shed", "completed", "slo_attainment", "cost_per_1k",
            "cold_starts", "utilization", "p95_ms"]


def run_cell(scenario_name: str, scheduler: str, autoscaler: str,
             n: int, seed: int, slo_mult: float,
             count_overhead: bool = False, hbm_mb: float | None = None,
             trace_csv: str | None = None, shared_weights: bool = False,
             sched_kw: dict | None = None) -> dict:
    tables = paper_tables()
    # count_overhead folds *measured wall-clock* search time into simulated
    # latency (the Fig 9/10 methodology) — off by default here so the sweep
    # is bit-deterministic under --seed
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     make_scheduler(scheduler, tables, **(sched_kw or {})),
                     seed=seed,
                     autoscaler=get_autoscaler(autoscaler),
                     count_overhead=count_overhead,
                     hbm_per_vgpu_mb=hbm_mb, shared_weights=shared_weights)
    gw = Gateway(sim)
    kw = {"csv_path": trace_csv} if (
        scenario_name == "trace-replay" and trace_csv) else {}
    sc = get_scenario(scenario_name, app_names=list(PAPER_APPS), **kw)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario_name
    return tel.summary()


def rows_to_csv(rows: list[dict], cols: list[str]) -> list[list]:
    """Flatten telemetry summary dicts into CSV cells (``p95_ms`` is
    pulled out of the nested latency histogram)."""
    return [[r.get(c, r["latency"]["p95_ms"] if c == "p95_ms" else "")
             for c in cols] for r in rows]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n / scenario subset for CI")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--schedulers", nargs="*", default=None)
    ap.add_argument("--autoscaler", default="ewma",
                    choices=["ewma", "finegrained", "vertical", "none"])
    ap.add_argument("--hbm-mb", type=float, default=None,
                    help="finite HBM per vGPU (MB) to exercise the "
                         "hot/warm swap tiers; default unbounded")
    ap.add_argument("--trace-csv", default=None,
                    help="CSV for trace-replay (default: built-in sample)")
    ap.add_argument("--count-overhead", action="store_true",
                    help="fold measured scheduler wall time into latency "
                         "(Fig 9/10 methodology; breaks bit-determinism)")
    args = ap.parse_args()

    scenarios = args.scenarios or SCENARIO_NAMES
    schedulers = args.schedulers or SCHEDULERS
    n = args.n
    if args.smoke:
        scenarios = args.scenarios or ["diurnal", "mmpp", "flash-crowd",
                                       "azure-tail"]
        schedulers = args.schedulers or ["ESG", "INFless", "Orion"]
        n = n or 40
    n = n or 200

    rows = []
    for sc in scenarios:
        for sched in schedulers:
            s = run_cell(sc, sched, args.autoscaler, n, args.seed,
                         args.slo_mult, count_overhead=args.count_overhead,
                         hbm_mb=args.hbm_mb, trace_csv=args.trace_csv)
            rows.append(s)
    print(format_table(rows))
    path = write_csv("scenario_sweep", CSV_COLS, rows_to_csv(rows, CSV_COLS))
    print(f"\n[scenario-sweep] n={n} seed={args.seed} "
          f"autoscaler={args.autoscaler} -> {path}")


if __name__ == "__main__":
    main()
