"""Fig 6 + Fig 8: SLO hit rate and cost (normalised to ESG) per setting,
overall and per application, for all five schedulers."""
from __future__ import annotations

import time

from benchmarks import common

SCHEDULERS = ["ESG", "INFless", "FaST-GShare", "Orion", "Aquatope"]


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print) -> list[dict]:
    rows, out = [], []
    for setting in common.SETTINGS:
        tables = common.paper_tables()
        esg_cost = None
        for name in SCHEDULERS:
            r = common.run_setting(name, setting, n=n, seed=seed,
                                   tables=tables)
            if name == "ESG":
                esg_cost = r["total_cost"]
            r["norm_cost"] = r["total_cost"] / esg_cost if esg_cost else 0.0
            out.append(r)
            log(f"  {setting:16s} {name:12s} hit={r['slo_hit_rate']:.3f} "
                f"cost(norm)={r['norm_cost']:.2f} "
                f"ovh={r['mean_sched_overhead_ms']:.2f}ms")
            rows.append([setting, name, f"{r['slo_hit_rate']:.4f}",
                         f"{r['total_cost']:.6f}", f"{r['norm_cost']:.3f}",
                         f"{r['mean_latency_ms']:.1f}",
                         f"{r['mean_sched_overhead_ms']:.3f}"])
            # Fig 8 per-app detail
            for app, st in r["per_app"].items():
                rows.append([f"{setting}/app:{app}", name,
                             f"{st['hit_rate']:.4f}", "", "",
                             f"{st['mean_ms']:.1f}", ""])
    common.write_csv("fig6_fig8_endtoend",
                     ["setting", "scheduler", "slo_hit_rate", "total_cost",
                      "cost_norm_to_esg", "mean_latency_ms",
                      "mean_sched_overhead_ms"], rows)
    return out


if __name__ == "__main__":
    run()
