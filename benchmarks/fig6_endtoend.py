"""Fig 6 + Fig 8: SLO hit rate and cost (normalised to ESG) per setting,
overall and per application, for all five schedulers.

``--scenario`` regenerates the figure under any serving scenario from
``repro.serving.traces`` (diurnal, mmpp, flash-crowd, azure-tail,
trace-replay, ...) instead of the paper's uniform arrivals; the CSV is
suffixed with the scenario name so per-scenario figures coexist."""
from __future__ import annotations

import argparse

try:
    from benchmarks import common
except ImportError:              # script-style: benchmarks/ is sys.path[0]
    import common

SCHEDULERS = ["ESG", "INFless", "FaST-GShare", "Orion", "Aquatope"]


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print,
        scenario: str | None = None) -> list[dict]:
    rows, out = [], []
    for setting in common.SETTINGS:
        tables = common.paper_tables()
        esg_cost = None
        for name in SCHEDULERS:
            r = common.run_setting(name, setting, n=n, seed=seed,
                                   tables=tables, scenario=scenario)
            if name == "ESG":
                esg_cost = r["total_cost"]
            r["norm_cost"] = r["total_cost"] / esg_cost if esg_cost else 0.0
            out.append(r)
            log(f"  {setting:16s} {name:12s} hit={r['slo_hit_rate']:.3f} "
                f"cost(norm)={r['norm_cost']:.2f} "
                f"ovh={r['mean_sched_overhead_ms']:.2f}ms")
            rows.append([setting, name, f"{r['slo_hit_rate']:.4f}",
                         f"{r['total_cost']:.6f}", f"{r['norm_cost']:.3f}",
                         f"{r['mean_latency_ms']:.1f}",
                         f"{r['mean_sched_overhead_ms']:.3f}"])
            # Fig 8 per-app detail
            for app, st in r["per_app"].items():
                rows.append([f"{setting}/app:{app}", name,
                             f"{st['hit_rate']:.4f}", "", "",
                             f"{st['mean_ms']:.1f}", ""])
    suffix = f"_{scenario}" if scenario else ""
    common.write_csv(f"fig6_fig8_endtoend{suffix}",
                     ["setting", "scheduler", "slo_hit_rate", "total_cost",
                      "cost_norm_to_esg", "mean_latency_ms",
                      "mean_sched_overhead_ms"], rows)
    return out


def main():
    from repro.serving.traces import SCENARIOS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=common.N_DEFAULT)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="serving scenario; omit for the paper's uniform "
                         "arrivals")
    args = ap.parse_args()
    run(n=args.n, seed=args.seed, scenario=args.scenario)


if __name__ == "__main__":
    main()
