"""Spot sweep: preemptible fleets vs on-demand-only across scenarios.

Runs every serving scenario twice through the online stack — once on the
homogeneous on-demand fleet and once on a spot-heavy heterogeneous fleet
with mid-task reclamation, retry/backoff and drain-and-migrate enabled —
and reports the economics: $/1k requests, SLO attainment, reclamations,
preemptions, retries and shed counts per arm.

The claim under test (and the --smoke CI gate): with same-silicon spot
capacity (``a100-spot``, billed at the spot discount but reclaimable),
the retry + migration machinery holds SLO attainment within a few points
of the on-demand baseline while strictly winning on $/1k.

    PYTHONPATH=src python benchmarks/spot_sweep.py --smoke
    PYTHONPATH=src python benchmarks/spot_sweep.py --seed 7 --n 200 \
        --fleet a100 a100-spot a100-spot --storm-mult 4.0

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse
import sys

from common import PAPER_APPS, ClusterSim, make_scheduler, paper_tables, \
    write_csv
from repro.core.profiles import PAPER_FUNCTIONS
from repro.serving import Gateway, format_table, get_autoscaler, get_scenario

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "skewed-mix", "spot-storm", "hetero-mix"]
SMOKE_SCENARIOS = ["diurnal", "mmpp", "flash-crowd", "azure-tail"]

# same-silicon spot mix: 2/3 of the fleet is reclaimable a100 capacity at
# the spot discount — the arm the $/1k claim is made for
SPOT_FLEET = ["a100", "a100-spot", "a100-spot"]

CSV_COLS = ["scenario", "arm", "injected", "completed", "shed",
            "slo_attainment", "cost_per_1k", "reclamations", "preemptions",
            "retries", "p95_ms"]

# --smoke gate: spot arm must stay within this many SLO-attainment points
# of the on-demand baseline while strictly undercutting its $/1k
SLO_TOLERANCE = 0.05


def run_arm(scenario_name: str, fleet: list[str] | None, n: int, seed: int,
            slo_mult: float, autoscaler: str, storm_mult: float,
            max_retries: int, retry_backoff_ms: float) -> dict:
    tables = paper_tables()
    kw: dict = {}
    if fleet:
        kw["fleet"] = fleet
        if storm_mult > 1.0:
            kw["reclaim_storms"] = [(0.0, 1e12, storm_mult)]
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     make_scheduler("ESG", tables),
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler(autoscaler),
                     max_retries=max_retries,
                     retry_backoff_ms=retry_backoff_ms, **kw)
    gw = Gateway(sim)
    sc = get_scenario(scenario_name, app_names=list(PAPER_APPS))
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario_name
    s = tel.summary()
    s["arm"] = "spot+retry" if fleet else "on-demand"
    s["reclamations"] = sim.reclaims
    s["preemptions"] = sim.preemptions
    s["retries"] = sim.retries
    return s


def rows_to_csv(rows: list[dict], cols: list[str]) -> list[list]:
    return [[r.get(c, r["latency"]["p95_ms"] if c == "p95_ms" else "")
             for c in cols] for r in rows]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="4-scenario subset + assert the spot arm wins "
                         "$/1k at equal SLO (CI gate)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--fleet", nargs="*", default=None,
                    help=f"SKU cycle for the spot arm "
                         f"(default {SPOT_FLEET})")
    ap.add_argument("--autoscaler", default="ewma",
                    choices=["ewma", "finegrained", "vertical", "none"])
    ap.add_argument("--storm-mult", type=float, default=1.0,
                    help="reclamation-rate multiplier over the whole "
                         "horizon (>1 injects a storm on the spot arm)")
    ap.add_argument("--max-retries", type=int, default=4)
    ap.add_argument("--retry-backoff-ms", type=float, default=250.0)
    args = ap.parse_args()

    scenarios = args.scenarios or (
        SMOKE_SCENARIOS if args.smoke else SCENARIO_NAMES)
    n = args.n or (40 if args.smoke else 200)
    fleet = args.fleet or SPOT_FLEET

    rows, wins, held = [], 0, 0
    for sc in scenarios:
        base = run_arm(sc, None, n, args.seed, args.slo_mult,
                       args.autoscaler, 1.0, args.max_retries,
                       args.retry_backoff_ms)
        spot = run_arm(sc, fleet, n, args.seed, args.slo_mult,
                       args.autoscaler, args.storm_mult, args.max_retries,
                       args.retry_backoff_ms)
        rows += [base, spot]
        cheaper = spot["cost_per_1k"] < base["cost_per_1k"]
        slo_ok = spot["slo_attainment"] >= base["slo_attainment"] \
            - SLO_TOLERANCE
        wins += cheaper
        held += slo_ok
        print(f"[spot-sweep] {sc}: $/1k {base['cost_per_1k']:.4f} -> "
              f"{spot['cost_per_1k']:.4f} "
              f"({'win' if cheaper else 'LOSS'}), SLO "
              f"{base['slo_attainment']:.3f} -> "
              f"{spot['slo_attainment']:.3f} "
              f"({'held' if slo_ok else 'DROPPED'})")

    print()
    print(format_table(rows))
    path = write_csv("spot_sweep", CSV_COLS, rows_to_csv(rows, CSV_COLS))
    print(f"\n[spot-sweep] n={n} seed={args.seed} fleet={fleet} "
          f"storm_mult={args.storm_mult} -> {path}")

    if args.smoke:
        if wins < len(scenarios) or held < len(scenarios):
            print(f"[spot-sweep] FAIL: $/1k wins on {wins}/{len(scenarios)}"
                  f" scenarios, SLO held on {held}/{len(scenarios)} "
                  f"(need all)", file=sys.stderr)
            return 1
        print(f"[spot-sweep] OK: spot+retry wins $/1k on all "
              f"{len(scenarios)} scenarios with SLO within "
              f"{SLO_TOLERANCE:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
