"""Real-compute benchmark: the execution bridge's committed claims.

``BENCH_realcompute.json`` holds one replay of a scenario trace through
both paths — the emulator's predicted stage latencies (from the
measured-profile table) and the real Pallas execution wall times from
the compile-cached ``serving.executor.RealExecutor`` — plus the
compile-cache stats and the roofline/quota cross-checks from
``launch/profile_kernels``.

Committed claims, all machine-independent ratios or identities (the
absolute latencies in the file are informational — they depend on the
host backend and are not guarded):

1. **Zero recompiles after warmup** — the post-warmup compile-cache hit
   rate is exactly 1.0: batch-lattice bucketing means steady-state
   serving never sees a shape warmup didn't compile.
2. **Calibration** — mean absolute predicted-vs-measured stage-latency
   error <= 15% across the executed (batch, quota) cells.
3. **Provenance** — the planner ran against ``"measured"`` profiles
   (threaded through Telemetry and the planner audit log).

Usage::

    python benchmarks/realcompute_bench.py           # guard committed file
    python benchmarks/realcompute_bench.py --smoke   # CI: fresh tiny run
                                                     # + committed guards
    python benchmarks/realcompute_bench.py --update  # regenerate baseline
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Optional, Sequence

HERE = pathlib.Path(__file__).resolve().parent
ROOT = HERE.parent
sys.path.insert(0, str(ROOT / "src"))

BASELINE = ROOT / "BENCH_realcompute.json"

# flagship configuration (committed — changing it invalidates baselines)
ARCH = "internlm2_1_8b"
N_REQUESTS = 48
BATCHES = (1, 2, 4, 8)
QUOTAS = (1.0, 0.5)
PROMPT_LEN = 32
GEN_LEN = 4
REPS = 5
SEED = 0

GUARDS = {
    "post_warmup_hit_rate": 1.0,     # exact: zero recompiles after warmup
    "max_mean_abs_err": 0.15,        # predicted vs measured stage latency
}


def run(n_requests: int = N_REQUESTS, batches: tuple = BATCHES,
        quotas: tuple = QUOTAS, prompt_len: int = PROMPT_LEN,
        gen_len: int = GEN_LEN, reps: int = REPS, seed: int = SEED,
        out: Optional[str] = None) -> dict:
    from repro.launch.serve import serve_real
    return serve_real(arch=ARCH, n_requests=n_requests, scenario="mmpp",
                      seed=seed, gen_len=gen_len, prompt_len=prompt_len,
                      batches=batches, quotas=quotas, reps=reps,
                      bench_out=out)


def check_guards(doc: dict, fresh: bool = False) -> list[str]:
    """Machine-independent checks on one benchmark document.

    ``fresh=True`` relaxes the error guard: a tiny CI run measures
    millisecond-scale cells whose wall-clock noise floor is above 15%,
    so only the deterministic invariants (hit rate, provenance,
    lattice) gate fresh runs — the error ratio gates the *committed*
    document, which is produced at full scale.
    """
    fails: list[str] = []
    where = "fresh" if fresh else "baseline"
    ex = doc.get("executor", {})
    if ex.get("post_warmup_hit_rate") != GUARDS["post_warmup_hit_rate"]:
        fails.append(f"{where}: post-warmup compile-cache hit rate "
                     f"{ex.get('post_warmup_hit_rate')} != 1.0 "
                     f"(recompile after warmup)")
    if not ex.get("executed", 0):
        fails.append(f"{where}: no batches executed")
    if not fresh and doc.get("mean_abs_err", 1.0) > \
            GUARDS["max_mean_abs_err"]:
        fails.append(f"{where}: mean abs predicted-vs-measured error "
                     f"{doc.get('mean_abs_err'):.3f} > "
                     f"{GUARDS['max_mean_abs_err']}")
    prov = doc.get("telemetry", {}).get("profile_provenance", {})
    if prov.get(doc.get("arch")) != "measured":
        fails.append(f"{where}: planner profile provenance is "
                     f"{prov.get(doc.get('arch'))!r}, not 'measured'")
    lattice = set(doc.get("profile", {}).get("batch_lattice", []))
    for c in doc.get("cells", []):
        if c["batch"] not in lattice:
            fails.append(f"{where}: executed bucket {c['batch']} is off "
                         f"the measured lattice {sorted(lattice)}")
    qc = doc.get("quota_check", {})
    if qc.get("n_points") and qc.get("measured_exponent") is not None:
        # sublinear sharing model sanity: the measured quota slowdown
        # exponent must at least be positive (more quota never slower)
        if qc["measured_exponent"] <= 0:
            fails.append(f"{where}: measured quota exponent "
                         f"{qc['measured_exponent']:.3f} <= 0")
    return fails


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fresh tiny run (hit-rate guard) plus "
                         "the committed baseline's ratio guards")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baseline (full scale)")
    ap.add_argument("--n", type=int, default=N_REQUESTS)
    args = ap.parse_args(argv)

    fails: list[str] = []
    if args.smoke:
        doc = run(n_requests=8, batches=(1, 2), quotas=(1.0,),
                  prompt_len=16, gen_len=2, reps=1)
        fails += check_guards(doc, fresh=True)
        if BASELINE.exists():
            fails += check_guards(json.loads(BASELINE.read_text()))
        else:
            print("[realcompute-bench] note: no committed baseline "
                  "to guard")
    elif args.update:
        doc = run(n_requests=args.n, out=str(BASELINE))
        fails += check_guards(doc)
        print(f"[realcompute-bench] baseline written -> {BASELINE}")
    else:
        if not BASELINE.exists():
            print(f"[realcompute-bench] missing {BASELINE}; run --update")
            return 1
        fails += check_guards(json.loads(BASELINE.read_text()))

    for f in fails:
        print(f"[realcompute-bench] GUARD FAIL: {f}")
    if not fails:
        print("[realcompute-bench] all guards passed")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
