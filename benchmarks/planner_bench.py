"""Planner/emulator fast-path benchmark: plans/sec + trace-replay wall.

Measures the three PR-5 levers against the pre-optimization reference
(plan cache off, per-config ESG_1Q loop, full-scan emulator):

  * **plans/sec** — the scheduler's ``plan()`` replayed over the call
    stream recorded from a real Azure-fixture run: warm plan-cache path
    vs the vectorized engine (cache off) vs the legacy per-config loop;
  * **end-to-end wall-clock** — the 3-minute Azure 2019 fixture
    (``tests/fixtures/azure_2019_3min_sample.csv`` through
    ``convert_azure``) replayed at ``speedup=1``, fast vs legacy;
  * **per-scenario wall-clock** — every serving scenario, fast vs
    legacy, with a bit-identical schedule digest check on each cell;
  * **peak RSS** — ``getrusage`` high-water mark of the bench process,
    so cache/memoization memory growth shows up in the trajectory.

Results land in ``BENCH_planner.json`` (repo root, committed) so later
PRs have a perf trajectory.  The regression guard compares *ratios*
(fast/legacy speedups), which are machine-independent, never absolute
times: the run fails if the cached plans/sec speedup or the Azure
replay wall speedup drops below ``REGRESSION_FRAC`` of the checked-in
baseline, or below the absolute acceptance floors (10x plans/sec, 3x
wall).  ``--update`` rewrites the baseline after an intentional change.

    PYTHONPATH=src python benchmarks/planner_bench.py --smoke
    PYTHONPATH=src python benchmarks/planner_bench.py --seed 3 --update
"""
from __future__ import annotations

import argparse
import json
import pathlib
import resource
import sys
import time

HERE = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(HERE / "traces"))

from common import PAPER_APPS, ClusterSim, paper_tables  # noqa: E402
from convert_azure import convert, load_counts  # noqa: E402
from repro.core.profiles import PAPER_FUNCTIONS  # noqa: E402
from repro.core.scheduler import ESGScheduler  # noqa: E402
from repro.serving import Gateway, get_autoscaler, get_scenario  # noqa: E402
from repro.serving.traces import TraceReplayScenario  # noqa: E402

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "trace-replay"]
AZURE_FIXTURE = HERE.parent / "tests" / "fixtures" / \
    "azure_2019_3min_sample.csv"
BASELINE = HERE.parent / "BENCH_planner.json"

# acceptance floors (ISSUE 5) and the loose trajectory guard
CACHED_SPEEDUP_MIN = 10.0
WALL_SPEEDUP_MIN = 3.0
REGRESSION_FRAC = 0.7          # fail when a ratio drops >30% vs baseline


class _RecordingESG(ESGScheduler):
    """ESG scheduler that records its ``plan()`` call stream so the
    plans/sec micro-bench replays a *real* workload's queries."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.calls: list[tuple] = []
        self.recording = True

    def plan(self, sim, app, stage, jobs, now):
        if self.recording:
            self.calls.append((sim, app, stage, list(jobs), now))
        return super().plan(sim, app, stage, jobs, now)


def schedule_digest(sim: ClusterSim) -> tuple:
    """Everything observable about a run's schedule (matches the
    differential tests' timeline): any placement/pricing/timing drift
    between fast and legacy shows up here."""
    tasks = tuple((t.start_ms, t.end_ms, t.exec_start_ms, t.invoker,
                   t.stage, t.func, t.config, t.tier, t.cold, t.cost,
                   t.quota_slices, t.penalty_ms, t.full_penalty_ms)
                  for t in sim.tasks)
    done = tuple((i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed)
    return (tasks, done, sim.total_cost, sim.cold_starts,
            sim.remote_transfers, tuple(sorted(sim.gpu_summary().items())))


def run_once(scenario, n: int, seed: int, fast: bool, tables,
             record: bool = False):
    cls = _RecordingESG if record else ESGScheduler
    sched = cls(PAPER_APPS, tables, plan_cache=fast, vectorized=fast)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), sparse=fast)
    gw = Gateway(sim)
    gw.inject(scenario, n, seed=seed + 1, slo_mult=1.0)
    t0 = time.perf_counter()
    gw.run()
    wall = time.perf_counter() - t0
    return sim, sched, wall


def rss_now_mb() -> tuple[float, float]:
    """(current VmRSS, process-lifetime VmHWM) in MB.

    ``getrusage().ru_maxrss`` only exposes the lifetime high-watermark,
    so sampling it per phase silently attributes every earlier phase's
    peak to whichever phase reads it.  Per-phase attribution needs the
    *current* RSS (/proc/self/status VmRSS) read at phase boundaries;
    the HWM is still reported once, as the whole-process figure it is.
    """
    rss = hwm = 0.0
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS"):
                    rss = float(line.split()[1]) / 1024.0
                elif line.startswith("VmHWM"):
                    hwm = float(line.split()[1]) / 1024.0
    except OSError:  # non-Linux: fall back to the high-watermark only
        hwm = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
        rss = hwm
    return rss, hwm


def time_replay(sched, calls, min_s: float = 0.2) -> float:
    """plans/sec of ``sched.plan`` over the recorded call stream."""
    done, t0 = 0, time.perf_counter()
    while True:
        for sim, app, stage, jobs, now in calls:
            sched.plan(sim, app, stage, jobs, now)
        done += len(calls)
        if time.perf_counter() - t0 >= min_s:
            break
    return done / (time.perf_counter() - t0)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scenario subset / smaller n for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n", type=int, default=None,
                    help="requests per scenario cell")
    ap.add_argument("--azure-n", type=int, default=200,
                    help="requests for the Azure-fixture replay")
    ap.add_argument("--update", action="store_true",
                    help="rewrite BENCH_planner.json instead of "
                         "guarding against it")
    ap.add_argument("--out", default=str(BASELINE))
    args = ap.parse_args()

    scenarios = ["mmpp", "azure-tail"] if args.smoke else SCENARIO_NAMES
    n = args.n or (24 if args.smoke else 60)
    tables = paper_tables()
    rss_phases: dict[str, float] = {"start": rss_now_mb()[0]}

    # ---- end-to-end: the 3-min Azure fixture at speedup=1 ----------------
    rows = convert(load_counts(str(AZURE_FIXTURE)), seed=args.seed)
    make_sc = lambda: TraceReplayScenario(rows=rows, speedup=1.0)  # noqa: E731
    sim_f, sched_f, wall_f = run_once(make_sc(), args.azure_n, args.seed,
                                      True, tables, record=True)
    sim_l, _, wall_l = run_once(make_sc(), args.azure_n, args.seed,
                                False, tables)
    azure_identical = schedule_digest(sim_f) == schedule_digest(sim_l)
    azure = {
        "n": args.azure_n, "tasks": len(sim_f.tasks),
        "plans": len(sim_f.sched_overheads_ms),
        "wall_s_fast": wall_f, "wall_s_legacy": wall_l,
        "wall_speedup": wall_l / wall_f, "identical": azure_identical,
    }
    print(f"[planner-bench] azure 3-min fixture (n={args.azure_n}): "
          f"fast {wall_f:.2f}s vs legacy {wall_l:.2f}s -> "
          f"{azure['wall_speedup']:.1f}x  identical={azure_identical}")
    rss_phases["azure_replay"] = rss_now_mb()[0]

    # ---- plans/sec over the recorded call stream -------------------------
    sched_f.recording = False
    # the real run's cache behaviour, snapshotted *before* the replay
    # loops below hammer the same cache with micro-bench lookups
    run_cache_stats = sched_f.cache.stats.as_dict()
    # every engine times the same call subset so the ratios are
    # apples-to-apples (the stream is not homogeneous: early calls hit
    # cold caches and different suffixes than late ones)
    calls = list(sched_f.calls)[:120]
    cached = time_replay(sched_f, calls)             # warm plan cache
    vec = time_replay(ESGScheduler(PAPER_APPS, tables, plan_cache=False,
                                   vectorized=True), calls)
    legacy = time_replay(ESGScheduler(PAPER_APPS, tables, plan_cache=False,
                                      vectorized=False), calls)
    plans = {
        "cached": cached, "vectorized": vec, "legacy": legacy,
        "cached_speedup": cached / legacy,
        "vectorized_speedup": vec / legacy,
        "recorded_calls": len(sched_f.calls), "timed_calls": len(calls),
    }
    print(f"[planner-bench] plans/sec: cached {cached:,.0f} | vectorized "
          f"{vec:,.0f} | legacy {legacy:,.0f}  (cached {plans['cached_speedup']:.0f}x, "
          f"vectorized {plans['vectorized_speedup']:.1f}x)")
    rss_phases["plans_per_sec"] = rss_now_mb()[0]

    # ---- per-scenario sweep ----------------------------------------------
    per_scenario = {}
    all_identical = azure_identical
    for name in scenarios:
        sc = get_scenario(name, app_names=list(PAPER_APPS))
        sf, schedf, wf = run_once(sc, n, args.seed, True, tables)
        sc = get_scenario(name, app_names=list(PAPER_APPS))
        sl, _, wl = run_once(sc, n, args.seed, False, tables)
        same = schedule_digest(sf) == schedule_digest(sl)
        all_identical &= same
        cs = schedf.cache.stats
        per_scenario[name] = {
            "wall_s_fast": wf, "wall_s_legacy": wl, "speedup": wl / wf,
            "identical": same, "sparse_skips": sf.sparse_skips,
            "plans": len(sf.sched_overheads_ms),
            "cache_hit_rate": cs.hits / cs.lookups if cs.lookups else 0.0,
        }
        print(f"[planner-bench] {name:14s} n={n}: {wl:.2f}s -> {wf:.2f}s "
              f"({wl / wf:.1f}x)  hit-rate {per_scenario[name]['cache_hit_rate']:.2f} "
              f"identical={same}")
        rss_phases[f"scenario:{name}"] = rss_now_mb()[0]

    # current-RSS trajectory at phase boundaries (attributable growth:
    # plan cache, vectorized engine, replay state) + the single honest
    # whole-process high-watermark
    peak_rss_mb = rss_now_mb()[1]
    print(f"[planner-bench] peak RSS {peak_rss_mb:.0f} MB "
          f"(phase RSS: " +
          ", ".join(f"{k} {v:.0f}" for k, v in rss_phases.items()) + ")")

    report = {
        "meta": {"seed": args.seed, "smoke": args.smoke, "n": n,
                 "scenarios": scenarios},
        "azure_replay": azure,
        "plans_per_sec": plans,
        "peak_rss_mb": peak_rss_mb,
        "rss_phases_mb": rss_phases,
        "cache": run_cache_stats,
        "scenarios": per_scenario,
        "guards": {"cached_speedup_min": CACHED_SPEEDUP_MIN,
                   "wall_speedup_min": WALL_SPEEDUP_MIN,
                   "regression_frac": REGRESSION_FRAC},
    }

    # ---- guards ----------------------------------------------------------
    failures = []
    if not all_identical:
        failures.append("fast path diverged from the legacy schedule")
    if plans["cached_speedup"] < CACHED_SPEEDUP_MIN:
        failures.append(f"cached plans/sec speedup "
                        f"{plans['cached_speedup']:.1f}x < "
                        f"{CACHED_SPEEDUP_MIN}x floor")
    if azure["wall_speedup"] < WALL_SPEEDUP_MIN:
        failures.append(f"azure replay wall speedup "
                        f"{azure['wall_speedup']:.1f}x < "
                        f"{WALL_SPEEDUP_MIN}x floor")
    out = pathlib.Path(args.out)
    if args.update or not out.exists():
        out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
        print(f"[planner-bench] baseline written -> {out}")
    else:
        base = json.loads(out.read_text())
        for label, ours, theirs in [
                ("cached plans/sec speedup", plans["cached_speedup"],
                 base["plans_per_sec"]["cached_speedup"]),
                ("azure wall speedup", azure["wall_speedup"],
                 base["azure_replay"]["wall_speedup"])]:
            if ours < REGRESSION_FRAC * theirs:
                failures.append(
                    f"{label} regressed: {ours:.1f}x vs baseline "
                    f"{theirs:.1f}x (floor {REGRESSION_FRAC:.0%})")
        print(f"[planner-bench] baseline {out} holds "
              f"(regression floor {REGRESSION_FRAC:.0%})"
              if not failures else
              f"[planner-bench] REGRESSION vs {out}")
    for f in failures:
        print(f"[planner-bench] FAIL: {f}")
    if not failures:
        print("[planner-bench] OK")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
