"""Table 4: pre-planned configuration miss rate (Orion, Aquatope).

A miss = the statically planned batch size exceeds the queue length when
the stage is actually scheduled."""
from __future__ import annotations

from benchmarks import common


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print):
    rows = []
    paper = {"strict-light": (9.6, 85.5), "moderate-normal": (27.32, 59.85),
             "relaxed-heavy": (51.68, 58.72)}
    for setting in common.SETTINGS:
        for name in ("Orion", "Aquatope"):
            tables = common.paper_tables()
            r = common.run_setting(name, setting, n=n, seed=seed,
                                   tables=tables)
            miss = (100.0 * r["config_misses"] / r["plan_uses"]
                    if r["plan_uses"] else 0.0)
            ref = paper[setting][0 if name == "Orion" else 1]
            rows.append([setting, name, f"{miss:.2f}", f"{ref}"])
            log(f"  {setting:16s} {name:9s} miss={miss:6.2f}% "
                f"(paper: {ref}%)")
    common.write_csv("table4_missrate",
                     ["setting", "scheduler", "miss_rate_pct",
                      "paper_miss_rate_pct"], rows)
    return rows


if __name__ == "__main__":
    run()
