"""§5.4: function-group size vs ESG_1Q search time (the 5-stage app).

The paper: group size 3 (default) searches in <10ms; size 4 jumps to
1201ms with 256 configs per function — exponential growth."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core.astar import esg_1q
from repro.core.dominator import distribute_slo
from repro.core.profiles import Config, PAPER_FUNCTIONS, ProfileTable
from repro.core.workflows import PAPER_APPS


def run(log=print):
    app = PAPER_APPS["expanded_image_classification"]
    tables = {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}
    rows = []
    for g in (1, 2, 3, 4, 5):
        groups = distribute_slo(app, tables, group_size=g)
        # time a search over the largest group
        sg = max({id(v): v for v in groups.values()}.values(),
                 key=lambda s: len(s.stages))
        seq = [tables[app.func_of[s]] for s in sg.stages]
        slo = sum(t.fn.exec_ms(Config(1, 1, 1)) for t in seq) * 1.0
        t0 = time.perf_counter()
        esg_1q(seq, slo, k=5)
        dt = (time.perf_counter() - t0) * 1e3
        rows.append([g, len(sg.stages), f"{dt:.2f}"])
        log(f"  group_size={g} (largest group {len(sg.stages)} stages): "
            f"search={dt:.1f}ms")
    common.write_csv("groupsize_sensitivity",
                     ["group_size", "largest_group_stages", "search_ms"],
                     rows)
    return rows


if __name__ == "__main__":
    run()
