"""Fig 10: ESG scheduling-overhead distribution per setting (+ brute-force
comparison, §5.3: "the search time is 7258ms for 256 configurations")."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.astar import brute_force, esg_1q
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable, Config


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print):
    rows = []
    for setting in common.SETTINGS:
        r = common.run_setting("ESG", setting, n=n, seed=seed)
        o = r  # summary carries the distribution stats
        rows.append([setting, f"{o['mean_sched_overhead_ms']:.3f}",
                     f"{o['p95_sched_overhead_ms']:.3f}"])
        log(f"  {setting:16s} mean={o['mean_sched_overhead_ms']:.2f}ms "
            f"p95={o['p95_sched_overhead_ms']:.2f}ms (paper: <10ms avg)")

    # brute force vs ESG_1Q on a 3-stage app, 256 configs each
    tables = [ProfileTable.build(PAPER_FUNCTIONS[f]) for f in
              ("super_resolution", "segmentation", "classification")]
    l0 = sum(t.fn.exec_ms(Config(1, 1, 1)) for t in tables)
    t0 = time.perf_counter()
    esg_1q(tables, l0, k=5)
    t_astar = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    brute_force(tables, l0, k=5)
    t_brute = (time.perf_counter() - t0) * 1e3
    rows.append(["astar_vs_brute_ms", f"{t_astar:.2f}", f"{t_brute:.1f}"])
    log(f"  ESG_1Q={t_astar:.1f}ms vs brute-force={t_brute:.0f}ms "
        f"(paper: brute 7258ms)")
    common.write_csv("fig10_overhead",
                     ["setting", "mean_ms", "p95_ms"], rows)
    return rows


if __name__ == "__main__":
    run()
