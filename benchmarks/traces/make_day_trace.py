"""Generate the flagship full-day replay trace (deterministic, checked).

The public Azure Functions 2019 dataset is too large to vendor, so the
day-scale benchmark fixture is *generated* by this script and pinned by
the committed checksum (``azure_2019_day_synth.sha256``): same script,
same default flags => byte-identical ``azure_2019_day_synth.csv.gz``,
which is why the multi-megabyte artifact itself stays out of git.

The synthesis follows the shape the dataset's own paper (Shahrad et
al., ATC'20) reports: a heavy-tailed per-function rate distribution
(lognormal — a few functions dominate total traffic), a diurnal
day-curve with per-function phase jitter, Poisson minute counts, and
uniform intra-minute jitter (the dataset quantises at minutes, exactly
what ``convert_azure`` reconstructs from the real CSVs).  Default
output: 1440 minutes, 240 functions, ~1.05M arrivals, emitted
minute-major (time-sorted) and streamed straight into the gzip writer
— constant memory, no materialized trace.

    python benchmarks/traces/make_day_trace.py           # write + checksum
    python benchmarks/traces/make_day_trace.py --verify  # re-hash existing
"""
from __future__ import annotations

import argparse
import hashlib
import pathlib
import sys
from typing import Iterator, Optional, Sequence

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(HERE))
from convert_azure import MS_PER_MINUTE, write_trace_stream  # noqa: E402

DEFAULT_OUT = HERE / "azure_2019_day_synth.csv.gz"
DEFAULT_MINUTES = 1440
DEFAULT_FUNCS = 240
DEFAULT_TARGET = 1_050_000   # expected arrivals over the day
DEFAULT_SEED = 2019


def synth_day(minutes: int = DEFAULT_MINUTES,
              funcs: int = DEFAULT_FUNCS,
              target: int = DEFAULT_TARGET,
              seed: int = DEFAULT_SEED) -> Iterator[tuple[float, str]]:
    """Yield time-sorted ``(t_ms, func_hash)`` arrivals for one day."""
    rng = np.random.default_rng(seed)
    names = [hashlib.blake2b(f"fn{i}".encode(), digest_size=8).hexdigest()
             for i in range(funcs)]
    # heavy-tail base rates: lognormal, normalised to the target volume
    base = rng.lognormal(mean=0.0, sigma=1.8, size=funcs)
    # per-function diurnal phase/depth (apps peak at different hours)
    phase = rng.uniform(0.0, 1.0, size=funcs)
    depth = rng.uniform(0.2, 0.8, size=funcs)
    day_curve = 1.0 + depth[:, None] * np.sin(
        2.0 * np.pi * (np.arange(minutes)[None, :] / minutes
                       - 0.3 - phase[:, None]))
    rate = base[:, None] * day_curve                  # funcs x minutes
    rate *= target / rate.sum()
    for m in range(minutes):
        counts = rng.poisson(rate[:, m])
        burst: list[tuple[float, str]] = []
        for i in np.flatnonzero(counts):
            jitter = rng.random(int(counts[i]))
            burst.extend(((m + float(u)) * MS_PER_MINUTE, names[i])
                         for u in jitter)
        burst.sort(key=lambda r: (r[0], r[1]))
        yield from burst


def sha256_of(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--minutes", type=int, default=DEFAULT_MINUTES)
    ap.add_argument("--funcs", type=int, default=DEFAULT_FUNCS)
    ap.add_argument("--target", type=int, default=DEFAULT_TARGET)
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument("--verify", action="store_true",
                    help="hash the existing output file against the "
                         "committed .sha256 instead of regenerating")
    args = ap.parse_args(argv)
    out = pathlib.Path(args.out)
    sumfile = out.with_suffix("").with_suffix("")  # strip .csv.gz
    sumfile = sumfile.parent / (sumfile.name + ".sha256")

    if args.verify:
        want = sumfile.read_text().split()[0]
        got = sha256_of(str(out))
        ok = want == got
        print(f"[make-day-trace] {out.name}: "
              f"{'OK' if ok else f'MISMATCH (want {want}, got {got})'}")
        return 0 if ok else 1

    n = write_trace_stream(
        synth_day(minutes=args.minutes, funcs=args.funcs,
                  target=args.target, seed=args.seed), str(out))
    digest = sha256_of(str(out))
    is_default = (args.minutes, args.funcs, args.target, args.seed) == \
        (DEFAULT_MINUTES, DEFAULT_FUNCS, DEFAULT_TARGET, DEFAULT_SEED) \
        and str(out) == str(DEFAULT_OUT)
    if is_default:
        sumfile.write_text(f"{digest}  {out.name}\n")
    print(f"[make-day-trace] {n} arrivals, {args.funcs} functions, "
          f"{args.minutes} min -> {out} (sha256 {digest[:16]}...)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
