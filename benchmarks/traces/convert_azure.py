"""Convert public Azure Functions invocation-count traces to ``(t_ms, app)``.

The Azure Functions 2019 dataset (and the 2021 refresh of the same
schema) ships per-function *minute-bucketed invocation counts*::

    HashOwner,HashApp,HashFunction,Trigger,1,2,...,1440

one row per function, one numbered column per minute of the day.  Our
scenario engine replays point-process traces (``t_ms,app`` rows —
``repro.serving.traces.TraceReplayScenario``), so this converter

  * selects the top ``--apps`` functions by total invocations (ties
    broken by id so the choice is deterministic),
  * truncates to the first ``--minutes`` minute columns,
  * scales each bucket's count by ``--scale`` (fractional expectations
    are realised with a seeded draw, so 0.1 of a 7-count bucket is not
    silently dropped),
  * spreads every bucket's arrivals uniformly inside its minute with
    seeded intra-minute jitter (the dataset quantises away sub-minute
    timing; uniform jitter is the max-entropy reconstruction),

and writes the merged, time-sorted ``t_ms,app`` CSV.  Function hash ids
are kept verbatim — ``TraceReplayScenario`` deterministically remaps
unknown app names onto whatever app set a run serves, so no information
is destroyed here.  Same seed + same flags => identical output file.

    python benchmarks/traces/convert_azure.py \
        invocations_per_function_md.anon.d01.csv \
        --apps 6 --minutes 60 --scale 0.01 --out azure_d01_1h.csv

Day-scale path: the dataset ships one file per day (``...d01.csv`` ..
``...d14.csv``).  Pass several inputs and select with ``--day 3`` or
``--days 2-4`` (1-based, in input order); selected days are
concatenated on the time axis (day *k* offset by ``k*1440`` minutes).
Multi-day conversion goes through the **streaming** converter: two
passes over each file (totals, then kept rows only), minute-major
emission straight to disk — peak memory is O(kept functions x minutes
per day), never O(total arrivals), and a ``.gz`` ``--out`` is written
compressed.  The streaming path draws jitter in minute-major order, so
its output is its own deterministic family (same seed + flags =>
identical file) but not byte-identical to the in-memory ``convert``.
"""
from __future__ import annotations

import argparse
import csv
import gzip
import pathlib
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

MS_PER_MINUTE = 60_000.0
MINUTES_PER_DAY = 1440
# id-column preference: function-level first, then coarser groupings
ID_COLUMNS = ("HashFunction", "HashApp", "HashOwner")


def load_counts(path: str) -> dict[str, list[int]]:
    """Parse an Azure minute-count CSV into ``id -> per-minute counts``.

    Minute columns are the integer-named ones, taken in numeric order;
    the row id is the finest hash column present (see ``ID_COLUMNS``).
    Rows sharing an id (a function appearing under several triggers)
    are summed.  Raises ``ValueError`` naming the file when the schema
    has no id or no minute columns."""
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        fields = reader.fieldnames or []
        id_col = next((c for c in ID_COLUMNS if c in fields), None)
        minute_cols = sorted((c for c in fields if c.strip().isdigit()),
                             key=lambda c: int(c))
        if id_col is None or not minute_cols:
            raise ValueError(
                f"{path}: expected an Azure invocation-count CSV with one "
                f"of {ID_COLUMNS} plus numbered minute columns, "
                f"got {fields}")
        out: dict[str, list[int]] = {}
        for row in reader:
            rid = (row.get(id_col) or "").strip()
            if not rid:
                continue
            counts = out.setdefault(rid, [0] * len(minute_cols))
            for i, c in enumerate(minute_cols):
                cell = (row.get(c) or "").strip()
                counts[i] += int(float(cell)) if cell else 0
    return out


def _schema(path: str, fields: Sequence[str]) -> tuple[str, list[str]]:
    """(id column, minute columns in numeric order) for one file, or
    raise the same schema error as ``load_counts``."""
    id_col = next((c for c in ID_COLUMNS if c in fields), None)
    minute_cols = sorted((c for c in fields if c.strip().isdigit()),
                         key=lambda c: int(c))
    if id_col is None or not minute_cols:
        raise ValueError(
            f"{path}: expected an Azure invocation-count CSV with one "
            f"of {ID_COLUMNS} plus numbered minute columns, got {list(fields)}")
    return id_col, minute_cols


def _opener(path: str):
    return gzip.open if str(path).endswith(".gz") else open


def scan_totals(paths: Sequence[str]) -> dict[str, int]:
    """Streaming pass 1: per-function invocation totals across day
    files.  Keeps one integer per function id — never a minute matrix —
    so a 14-day scan stays at megabytes."""
    totals: dict[str, int] = {}
    for path in paths:
        with _opener(path)(path, "rt", newline="") as f:
            reader = csv.DictReader(f)
            id_col, minute_cols = _schema(path, reader.fieldnames or [])
            for row in reader:
                rid = (row.get(id_col) or "").strip()
                if not rid:
                    continue
                s = 0
                for c in minute_cols:
                    cell = (row.get(c) or "").strip()
                    if cell:
                        s += int(float(cell))
                totals[rid] = totals.get(rid, 0) + s
    return totals


def stream_convert(paths: Sequence[str],
                   apps: Optional[int] = None,
                   minutes: Optional[int] = None,
                   scale: float = 1.0,
                   seed: int = 0,
                   minutes_per_day: int = MINUTES_PER_DAY,
                   ) -> Iterator[tuple[float, str]]:
    """Streaming multi-day converter: yields time-sorted ``(t_ms, id)``
    arrivals without ever materializing the trace.

    Two passes per file: ``scan_totals`` picks the ``apps`` busiest
    functions across *all* selected days (same tie-break as
    ``convert``), then each day is re-read keeping only those rows —
    peak state is the kept functions' minute matrix for one day.  Day
    ``k`` (input order) is offset by ``k * minutes_per_day`` minutes.
    Emission is minute-major (all of minute *m* across functions, inner
    jitter sorted), so arrivals stream out in time order; the seeded
    draw order therefore differs from ``convert``'s function-major
    order — deterministic per (seed, flags), not byte-compatible.
    ``minutes`` truncates each day, matching ``convert`` on one file.
    """
    if not scale > 0.0:            # also rejects NaN
        raise ValueError(f"convert_azure: scale must be > 0, got {scale!r}")
    totals = scan_totals(paths)
    keep = sorted(totals, key=lambda k: (-totals[k], k))
    if apps is not None:
        keep = keep[:apps]
    keep_ix = {rid: i for i, rid in enumerate(keep)}
    rng = np.random.default_rng(seed)
    for day, path in enumerate(paths):
        with _opener(path)(path, "rt", newline="") as f:
            reader = csv.DictReader(f)
            id_col, minute_cols = _schema(path, reader.fieldnames or [])
            if minutes is not None:
                minute_cols = minute_cols[:minutes]
            day_counts = np.zeros((len(keep), len(minute_cols)), dtype=np.int64)
            for row in reader:
                rid = (row.get(id_col) or "").strip()
                ix = keep_ix.get(rid)
                if ix is None:
                    continue
                for m, c in enumerate(minute_cols):
                    cell = (row.get(c) or "").strip()
                    if cell:
                        day_counts[ix, m] += int(float(cell))
        base_min = day * minutes_per_day
        for m in range(day_counts.shape[1]):
            burst: list[tuple[float, str]] = []
            for ix, rid in enumerate(keep):   # deterministic draw order
                want = int(day_counts[ix, m]) * scale
                n = int(want) + int(rng.random() < (want - int(want)))
                if n <= 0:
                    continue
                jitter = rng.random(n)
                burst.extend(((base_min + m + float(u)) * MS_PER_MINUTE, rid)
                             for u in jitter)
            burst.sort(key=lambda r: (r[0], r[1]))
            yield from burst


def write_trace_stream(rows: Iterable[tuple[float, str]],
                       out_path: str) -> int:
    """Stream ``(t_ms, app)`` rows to ``out_path`` (gzip when it ends
    in ``.gz``) without buffering; returns the row count.  Gzip output
    pins ``mtime=0`` so the same rows always produce the same bytes —
    the day-fixture checksum depends on it."""
    import contextlib
    import io

    n = 0
    with contextlib.ExitStack() as stack:
        if str(out_path).endswith(".gz"):
            raw = stack.enter_context(open(out_path, "wb"))
            gz = stack.enter_context(
                gzip.GzipFile(fileobj=raw, mode="wb", mtime=0))
            f = stack.enter_context(io.TextIOWrapper(gz, newline=""))
        else:
            f = stack.enter_context(open(out_path, "w", newline=""))
        w = csv.writer(f)
        w.writerow(["t_ms", "app"])
        for t, app in rows:
            w.writerow([f"{t:.3f}", app])
            n += 1
    return n


def parse_days(day: Optional[int], days: Optional[str],
               n_inputs: int) -> list[int]:
    """``--day``/``--days`` -> 0-based input indices (1-based on the
    CLI, ``A-B`` ranges and ``A,B,C`` lists accepted)."""
    if day is not None and days is not None:
        raise ValueError("pass --day or --days, not both")
    if day is None and days is None:
        return list(range(n_inputs))
    picks: list[int] = []
    if day is not None:
        picks = [day]
    else:
        for part in str(days).split(","):
            part = part.strip()
            if "-" in part:
                lo, hi = part.split("-", 1)
                picks.extend(range(int(lo), int(hi) + 1))
            elif part:
                picks.append(int(part))
    for d in picks:
        if not 1 <= d <= n_inputs:
            raise ValueError(f"day {d} out of range (have {n_inputs} "
                             f"input file(s), days are 1-based)")
    return [d - 1 for d in picks]


def convert(counts: dict[str, Sequence[int]],
            apps: Optional[int] = None,
            minutes: Optional[int] = None,
            scale: float = 1.0,
            seed: int = 0) -> list[tuple[float, str]]:
    """Minute-bucketed counts -> time-sorted ``(t_ms, app)`` rows.

    ``apps`` keeps the busiest N functions (all when None), ``minutes``
    truncates the horizon, ``scale`` multiplies every bucket's count
    (the fractional remainder is realised with one seeded draw per
    bucket).  Jitter is uniform inside each minute — seeded, so the
    same call yields the same trace."""
    if not scale > 0.0:            # also rejects NaN
        raise ValueError(f"convert_azure: scale must be > 0, got {scale!r}")
    rng = np.random.default_rng(seed)
    keep = sorted(counts, key=lambda k: (-sum(counts[k]), k))
    if apps is not None:
        keep = keep[:apps]
    rows: list[tuple[float, str]] = []
    for rid in keep:               # deterministic id order drives the rng
        buckets = counts[rid]
        if minutes is not None:
            buckets = buckets[:minutes]
        for m, c in enumerate(buckets):
            want = c * scale
            n = int(want) + int(rng.random() < (want - int(want)))
            if n <= 0:
                continue
            jitter = np.sort(rng.random(n))
            rows.extend(((m + float(u)) * MS_PER_MINUTE, rid)
                        for u in jitter)
    rows.sort(key=lambda r: (r[0], r[1]))
    return rows


def write_trace(rows: list[tuple[float, str]], out_path: str) -> None:
    with open(out_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["t_ms", "app"])
        w.writerows([f"{t:.3f}", app] for t, app in rows)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+",
                    help="Azure invocation-count CSVs, one per day "
                         "(invocations_per_function_md.anon.d01.csv ...)")
    ap.add_argument("--day", type=int, default=None,
                    help="convert only day N (1-based, input order)")
    ap.add_argument("--days", default=None,
                    help="convert a day range/list, e.g. 2-4 or 1,3,5 "
                         "(1-based, input order, concatenated in time)")
    ap.add_argument("--apps", type=int, default=None,
                    help="keep only the N busiest functions")
    ap.add_argument("--minutes", type=int, default=None,
                    help="truncate each day to its first N minutes")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="multiply every bucket's count (0.01 thins a "
                         "production day to benchmark size)")
    ap.add_argument("--seed", type=int, default=0,
                    help="jitter/thinning seed (same seed => same trace)")
    ap.add_argument("--out", default=None,
                    help="output CSV, .gz for compressed (default: "
                         "<first input stem>_trace.csv next to the input)")
    args = ap.parse_args(argv)

    picks = parse_days(args.day, args.days, len(args.inputs))
    paths = [args.inputs[i] for i in picks]
    src = pathlib.Path(paths[0])
    out = args.out or str(src.with_name(src.stem + "_trace.csv"))
    last_t = 0.0
    funcs: set[str] = set()

    def _tap(rows):
        nonlocal last_t
        for t, app in rows:
            last_t = t
            funcs.add(app)
            yield t, app

    n = write_trace_stream(
        _tap(stream_convert(paths, apps=args.apps, minutes=args.minutes,
                            scale=args.scale, seed=args.seed)), out)
    span_min = last_t / MS_PER_MINUTE if n else 0.0
    print(f"[convert-azure] {n} arrivals over {span_min:.1f} min, "
          f"{len(funcs)} functions ({len(paths)} day file(s)) -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
