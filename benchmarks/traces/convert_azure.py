"""Convert public Azure Functions invocation-count traces to ``(t_ms, app)``.

The Azure Functions 2019 dataset (and the 2021 refresh of the same
schema) ships per-function *minute-bucketed invocation counts*::

    HashOwner,HashApp,HashFunction,Trigger,1,2,...,1440

one row per function, one numbered column per minute of the day.  Our
scenario engine replays point-process traces (``t_ms,app`` rows —
``repro.serving.traces.TraceReplayScenario``), so this converter

  * selects the top ``--apps`` functions by total invocations (ties
    broken by id so the choice is deterministic),
  * truncates to the first ``--minutes`` minute columns,
  * scales each bucket's count by ``--scale`` (fractional expectations
    are realised with a seeded draw, so 0.1 of a 7-count bucket is not
    silently dropped),
  * spreads every bucket's arrivals uniformly inside its minute with
    seeded intra-minute jitter (the dataset quantises away sub-minute
    timing; uniform jitter is the max-entropy reconstruction),

and writes the merged, time-sorted ``t_ms,app`` CSV.  Function hash ids
are kept verbatim — ``TraceReplayScenario`` deterministically remaps
unknown app names onto whatever app set a run serves, so no information
is destroyed here.  Same seed + same flags => identical output file.

    python benchmarks/traces/convert_azure.py \
        invocations_per_function_md.anon.d01.csv \
        --apps 6 --minutes 60 --scale 0.01 --out azure_d01_1h.csv
"""
from __future__ import annotations

import argparse
import csv
import pathlib
from typing import Optional, Sequence

import numpy as np

MS_PER_MINUTE = 60_000.0
# id-column preference: function-level first, then coarser groupings
ID_COLUMNS = ("HashFunction", "HashApp", "HashOwner")


def load_counts(path: str) -> dict[str, list[int]]:
    """Parse an Azure minute-count CSV into ``id -> per-minute counts``.

    Minute columns are the integer-named ones, taken in numeric order;
    the row id is the finest hash column present (see ``ID_COLUMNS``).
    Rows sharing an id (a function appearing under several triggers)
    are summed.  Raises ``ValueError`` naming the file when the schema
    has no id or no minute columns."""
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        fields = reader.fieldnames or []
        id_col = next((c for c in ID_COLUMNS if c in fields), None)
        minute_cols = sorted((c for c in fields if c.strip().isdigit()),
                             key=lambda c: int(c))
        if id_col is None or not minute_cols:
            raise ValueError(
                f"{path}: expected an Azure invocation-count CSV with one "
                f"of {ID_COLUMNS} plus numbered minute columns, "
                f"got {fields}")
        out: dict[str, list[int]] = {}
        for row in reader:
            rid = (row.get(id_col) or "").strip()
            if not rid:
                continue
            counts = out.setdefault(rid, [0] * len(minute_cols))
            for i, c in enumerate(minute_cols):
                cell = (row.get(c) or "").strip()
                counts[i] += int(float(cell)) if cell else 0
    return out


def convert(counts: dict[str, Sequence[int]],
            apps: Optional[int] = None,
            minutes: Optional[int] = None,
            scale: float = 1.0,
            seed: int = 0) -> list[tuple[float, str]]:
    """Minute-bucketed counts -> time-sorted ``(t_ms, app)`` rows.

    ``apps`` keeps the busiest N functions (all when None), ``minutes``
    truncates the horizon, ``scale`` multiplies every bucket's count
    (the fractional remainder is realised with one seeded draw per
    bucket).  Jitter is uniform inside each minute — seeded, so the
    same call yields the same trace."""
    if not scale > 0.0:            # also rejects NaN
        raise ValueError(f"convert_azure: scale must be > 0, got {scale!r}")
    rng = np.random.default_rng(seed)
    keep = sorted(counts, key=lambda k: (-sum(counts[k]), k))
    if apps is not None:
        keep = keep[:apps]
    rows: list[tuple[float, str]] = []
    for rid in keep:               # deterministic id order drives the rng
        buckets = counts[rid]
        if minutes is not None:
            buckets = buckets[:minutes]
        for m, c in enumerate(buckets):
            want = c * scale
            n = int(want) + int(rng.random() < (want - int(want)))
            if n <= 0:
                continue
            jitter = np.sort(rng.random(n))
            rows.extend(((m + float(u)) * MS_PER_MINUTE, rid)
                        for u in jitter)
    rows.sort(key=lambda r: (r[0], r[1]))
    return rows


def write_trace(rows: list[tuple[float, str]], out_path: str) -> None:
    with open(out_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["t_ms", "app"])
        w.writerows([f"{t:.3f}", app] for t, app in rows)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("input", help="Azure invocation-count CSV "
                                  "(invocations_per_function_md.anon.*)")
    ap.add_argument("--apps", type=int, default=None,
                    help="keep only the N busiest functions")
    ap.add_argument("--minutes", type=int, default=None,
                    help="truncate to the first N minutes")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="multiply every bucket's count (0.01 thins a "
                         "production day to benchmark size)")
    ap.add_argument("--seed", type=int, default=0,
                    help="jitter/thinning seed (same seed => same trace)")
    ap.add_argument("--out", default=None,
                    help="output CSV (default: <input stem>_trace.csv "
                         "next to the input)")
    args = ap.parse_args(argv)

    rows = convert(load_counts(args.input), apps=args.apps,
                   minutes=args.minutes, scale=args.scale, seed=args.seed)
    src = pathlib.Path(args.input)
    out = args.out or str(src.with_name(src.stem + "_trace.csv"))
    write_trace(rows, out)
    span_min = rows[-1][0] / MS_PER_MINUTE if rows else 0.0
    print(f"[convert-azure] {len(rows)} arrivals over {span_min:.1f} min, "
          f"{len({a for _, a in rows})} functions -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
