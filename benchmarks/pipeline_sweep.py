"""Overlapped-swap-pipeline sweep: additive restart penalties (PR 3)
vs the asynchronous PCIe transfer engine, with and without predictive
prefetch, under finite HBM.

For each serving scenario the same trace runs through the same ESG
scheduler and warm-pool policy under three penalty models:

  * ``additive``    — PR-3 behaviour: every warm/cold restart charged
                      as a synchronous scalar at task start;
  * ``overlap``     — ``ClusterSim(overlap=True)``: swap-ins and cold
                      weight loads become PCIe transfer completions, so
                      they hide behind data transfer and scheduling
                      overhead (``exec_start = max(start, ready)``);
  * ``overlap+pf``  — ``prefetch=True`` on top: when stage ``i``
                      dispatches, the successor stages' weights are
                      enqueued on its invoker as background copies that
                      overlap stage ``i``'s execution — Torpor's
                      predicted-next prefetch.

Invokers carry finite HBM (``--hbm-mb`` per vGPU) under the memory-blind
locality placement, so the warm (host-staged weights) tier is actually
exercised.  The acceptance bar: with overlap+prefetch the warm-tier
penalty *actually charged per task* must sit strictly below the additive
``swap_in_ms`` model on every scenario — and shrink with pipeline depth,
because deeper stages have a predecessor execution to hide behind —
while SLO attainment and/or $/1k improves.

    PYTHONPATH=src python benchmarks/pipeline_sweep.py --smoke
    PYTHONPATH=src python benchmarks/pipeline_sweep.py --seed 7 \
        --scenarios mmpp azure-tail --hbm-mb 384

Deterministic under --seed (same seed => identical table).
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from common import PAPER_APPS, ClusterSim, paper_tables, write_csv
from repro.core.profiles import PAPER_FUNCTIONS
from repro.core.scheduler import ESGScheduler
from repro.gpu import HOT, WARM
from repro.serving import Gateway, format_table, get_autoscaler, get_scenario

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "trace-replay"]
# mode -> (overlap, prefetch)
MODES = {"additive": (False, False),
         "overlap": (True, False),
         "overlap+pf": (True, True)}

CSV_COLS = ["scenario", "mode", "overlap", "prefetch", "slo_attainment",
            "cost_per_1k", "completed", "shed", "cold_starts", "swap_ins",
            "warm_tasks", "warm_charged_ms", "warm_full_ms",
            "warm_charged_per_task", "depth0_ratio", "deep_ratio",
            "penalty_charged_ms", "penalty_hidden_ms", "prefetch_issued",
            "prefetch_hits", "prefetch_wasted", "transfer_busy_ms",
            "utilization", "p95_ms"]

EXTRA_TABLE_COLS = [("mode", "mode", "{}"),
                    ("warm_tasks", "warm", "{}"),
                    ("warm_charged_per_task", "chg/task", "{:.1f}"),
                    ("penalty_hidden_ms", "hidden", "{:.0f}"),
                    ("prefetch_hits", "pf-hit", "{}")]


def warm_stats(sim) -> dict:
    """Warm-restart accounting over a finished run.

    A "warm-equivalent" task is one the additive model would have
    charged a swap-in: tier == warm (demand swap at start) or tier ==
    hot with a nonzero ``full_penalty_ms`` (the swap ran as a prefetch
    and the task consumed/rode it).  ``depth`` is the stage's position
    in its pipeline (stage ids are ``"<i>:<func>"``), the axis along
    which overlap must shrink the charge: depth-0 stages have no
    predecessor execution to hide behind."""
    warm = [t for t in sim.tasks
            if t.tier == WARM or (t.tier == HOT and t.full_penalty_ms > 0)]
    by_depth: dict[int, list] = defaultdict(list)
    for t in warm:
        by_depth[int(t.stage.split(":", 1)[0])].append(t)

    def ratio(tasks):
        full = sum(t.full_penalty_ms for t in tasks)
        return sum(t.penalty_ms for t in tasks) / full if full else None

    deep = [t for d, ts in by_depth.items() if d >= 1 for t in ts]
    return {
        "warm_tasks": len(warm),
        "warm_charged_ms": sum(t.penalty_ms for t in warm),
        "warm_full_ms": sum(t.full_penalty_ms for t in warm),
        "warm_charged_per_task": (sum(t.penalty_ms for t in warm)
                                  / len(warm) if warm else 0.0),
        "depth0_ratio": ratio(by_depth.get(0, [])),
        "deep_ratio": ratio(deep),
        "depth_ratios": {d: ratio(ts) for d, ts in sorted(by_depth.items())},
    }


def run_cell(scenario_name: str, mode: str, n: int, seed: int,
             slo_mult: float, hbm_mb: float, autoscaler: str,
             trace_csv: str | None = None) -> dict:
    overlap, prefetch = MODES[mode]
    tables = paper_tables()
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables),
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler(autoscaler),
                     hbm_per_vgpu_mb=hbm_mb,
                     overlap=overlap, prefetch=prefetch)
    gw = Gateway(sim)
    kw = {"csv_path": trace_csv} if (
        scenario_name == "trace-replay" and trace_csv) else {}
    sc = get_scenario(scenario_name, app_names=list(PAPER_APPS), **kw)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    tel.scenario = scenario_name
    s = tel.summary()
    s["mode"] = mode
    s["overlap"] = overlap
    s["prefetch"] = prefetch
    s.update(warm_stats(sim))
    for k in ("swap_ins", "penalty_charged_ms", "penalty_hidden_ms",
              "prefetch_issued", "prefetch_hits", "prefetch_wasted",
              "transfer_busy_ms"):
        s[k] = s["gpu"][k]
    return s


def rows_to_csv(rows: list[dict], cols: list[str]) -> list[list]:
    def cell(r, c):
        if c == "p95_ms":
            return r["latency"]["p95_ms"]
        v = r.get(c, "")
        return "" if v is None else v
    return [[cell(r, c) for c in cols] for r in rows]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small n / scenario subset for CI")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-mult", type=float, default=1.0)
    ap.add_argument("--hbm-mb", type=float, default=512.0,
                    help="HBM per vGPU (MB); finite so the warm swap "
                         "tier is actually exercised")
    ap.add_argument("--autoscaler", default="ewma",
                    choices=["ewma", "finegrained", "vertical", "none"])
    ap.add_argument("--scenarios", nargs="*", default=None)
    ap.add_argument("--trace-csv", default=None,
                    help="CSV for trace-replay (default: built-in sample)")
    args = ap.parse_args()

    scenarios = args.scenarios or SCENARIO_NAMES
    n = args.n
    if args.smoke:
        scenarios = args.scenarios or ["mmpp", "azure-tail"]
        n = n or 40
    n = n or 200

    rows, by_cell = [], {}
    for sc in scenarios:
        for mode in MODES:
            s = run_cell(sc, mode, n, args.seed, args.slo_mult,
                         args.hbm_mb, args.autoscaler, args.trace_csv)
            rows.append(s)
            by_cell[(sc, mode)] = s
    print(format_table(rows, extra_cols=EXTRA_TABLE_COLS))

    wins = []
    for sc in scenarios:
        a, o = by_cell[(sc, "additive")], by_cell[(sc, "overlap+pf")]
        # the acceptance bar: every warm restart the additive model
        # bills at swap_in_ms must be charged strictly less with the
        # transfer engine + prefetch in the loop...
        below = (o["warm_tasks"] > 0
                 and o["warm_charged_ms"] < o["warm_full_ms"] - 1e-9)
        # ...shrinking with pipeline depth (deeper stages hide behind a
        # predecessor's execution; roots have nothing to hide behind)...
        d0, dd = o["depth0_ratio"], o["deep_ratio"]
        deeper = dd is not None and (d0 is None or dd < d0 - 1e-9)
        # ...and the end-to-end needle moves: better SLO or cheaper
        better_slo = o["slo_attainment"] > a["slo_attainment"] + 1e-9
        same_slo = abs(o["slo_attainment"] - a["slo_attainment"]) <= 1e-9
        cheaper = o["cost_per_1k"] < a["cost_per_1k"] - 1e-9
        win = below and deeper and (better_slo or (same_slo and cheaper)
                                    or cheaper)
        if win:
            wins.append(sc)
        depths = " ".join(f"d{d}={r:.2f}" if r is not None else f"d{d}=-"
                          for d, r in o["depth_ratios"].items())
        print(f"[pipeline-sweep] {sc:14s} overlap+pf vs additive: "
              f"warm chg {o['warm_charged_ms']:.0f}/{o['warm_full_ms']:.0f}ms "
              f"({depths}), slo {o['slo_attainment']:.3f} vs "
              f"{a['slo_attainment']:.3f}, $/1k {o['cost_per_1k']:.4f} vs "
              f"{a['cost_per_1k']:.4f} {'WIN' if win else '-'}")
    verdict = (f"overlap+pf beats additive on {len(wins)}/{len(scenarios)} "
               f"scenarios: {wins}" if wins else
               "overlap+pf did not beat additive anywhere (unexpected)")
    print(f"[pipeline-sweep] {verdict}")

    path = write_csv("pipeline_sweep", CSV_COLS, rows_to_csv(rows, CSV_COLS))
    print(f"[pipeline-sweep] n={n} seed={args.seed} "
          f"hbm={args.hbm_mb:.0f}MB/vGPU -> {path}")
    return 0 if len(wins) == len(scenarios) else 1


if __name__ == "__main__":
    raise SystemExit(main())
