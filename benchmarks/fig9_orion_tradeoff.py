"""Fig 9: Orion's search-time/quality trade-off (strict-light).

Sweeps the search cut-off; reports hit rate with the search time counted
into latency vs not counted (the paper's blue vs green curves)."""
from __future__ import annotations

from benchmarks import common


def run(n: int = 150, seed: int = 0, log=print):
    rows = []
    for cutoff in (5.0, 20.0, 50.0, 100.0):
        for counted in (False, True):
            tables = common.paper_tables()
            sched = common.make_scheduler("Orion", tables, cutoff_ms=cutoff)
            r = common.run_setting("Orion", "strict-light", n=n, seed=seed,
                                   tables=tables, sched=sched,
                                   count_overhead=counted)
            rows.append([cutoff, counted, f"{r['slo_hit_rate']:.4f}",
                         f"{r['mean_sched_overhead_ms']:.2f}"])
            log(f"  cutoff={cutoff:6.1f}ms counted={counted!s:5s} "
                f"hit={r['slo_hit_rate']:.3f}")
    common.write_csv("fig9_orion_tradeoff",
                     ["cutoff_ms", "search_time_counted", "slo_hit_rate",
                      "mean_search_ms"], rows)
    return rows


if __name__ == "__main__":
    run()
