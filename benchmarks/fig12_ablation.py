"""Fig 12: ablation of GPU-sharing and batching (relaxed-heavy).

The paper saturates the cluster to expose the batching effect ("we set a
heavy workload ... specifically to underline the effects of the batching
strategy"); we run the ablation on a 10-invoker cluster so queues actually
form at the paper's heavy arrival rate.  Batching's effect is directional
but modest under our latency model (per-job cost ~ b^-0.15); sharing
remains catastrophic to remove, matching the paper's ordering."""
from __future__ import annotations

from benchmarks import common


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print):
    rows = []
    variants = [("ESG", {}), ("ESG-no-sharing", {"gpu_sharing": False}),
                ("ESG-no-batching", {"batching": False})]
    for name, kw in variants:
        r = common.run_setting("ESG", "relaxed-heavy", n=n, seed=seed,
                               n_invokers=10, **kw)
        rows.append([name, f"{r['slo_hit_rate']:.4f}",
                     f"{r['total_cost']:.6f}",
                     f"{r['mean_latency_ms']:.1f}"])
        log(f"  {name:16s} hit={r['slo_hit_rate']:.3f} "
            f"cost=${r['total_cost']:.4f} lat={r['mean_latency_ms']:.0f}ms")
    common.write_csv("fig12_ablation",
                     ["variant", "slo_hit_rate", "total_cost",
                      "mean_latency_ms"], rows)
    return rows


if __name__ == "__main__":
    run()
