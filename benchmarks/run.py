"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark (us_per_call =
wall time of the benchmark routine; derived = its headline metric), plus
the per-figure detail written under benchmarks/results/*.csv.
"""
from __future__ import annotations

import sys
import time


def _run(name, fn, derive, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(out)}", flush=True)
    return out


def main() -> None:
    from benchmarks import (fig6_endtoend, fig7_latency, fig9_orion_tradeoff,
                            fig10_overhead, fig11_k_sensitivity,
                            fig12_ablation, groupsize_sensitivity,
                            roofline_table, table4_missrate)

    quick = "--quick" in sys.argv
    n = 80 if quick else 200

    print("name,us_per_call,derived")
    r6 = _run("fig6_fig8_endtoend", fig6_endtoend.run,
              lambda rs: "ESG_hit=" + "/".join(
                  f"{r['slo_hit_rate']:.2f}" for r in rs
                  if r["scheduler"] == "ESG"), n=n)
    _run("fig7_latency", fig7_latency.run,
         lambda rs: f"rows={len(rs)}", n=n)
    _run("fig9_orion_tradeoff", fig9_orion_tradeoff.run,
         lambda rs: f"rows={len(rs)}", n=min(n, 120))
    _run("table4_missrate", table4_missrate.run,
         lambda rs: "miss=" + "/".join(r[2] for r in rs), n=n)
    _run("fig10_overhead", fig10_overhead.run,
         lambda rs: f"esg_mean_ms={rs[0][1]}", n=n)
    _run("fig11_k_sensitivity", fig11_k_sensitivity.run,
         lambda rs: f"rows={len(rs)}", n=min(n, 120))
    _run("fig12_ablation", fig12_ablation.run,
         lambda rs: f"rows={len(rs)}", n=n)
    _run("groupsize_sensitivity", groupsize_sensitivity.run,
         lambda rs: f"g4_search_ms={rs[3][2]}")
    _run("roofline_table", roofline_table.run,
         lambda rs: f"cells={len(rs)}")


if __name__ == "__main__":
    main()
