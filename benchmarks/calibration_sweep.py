"""Closed-loop calibration sweep: injected profile error vs the online
calibrator, across all six serving scenarios.

The acceptance experiment for ISSUE-7's pricing loop: the *controller's*
profile tables are skewed by a +/-30% multiplicative error on exec_ms
(``FunctionProfile.exec_ms`` is exactly linear in ``t1_ms``, so scaling
``t1_ms`` is an exact multiplicative exec skew), while the emulator
keeps the true profiles as ground truth.  Every scenario then runs two
arms on the same seed and skew:

  * **off** — the skewed planner as-is (the flight recorder attached
    but passive, so the audit stream measures the misprediction);
  * **on**  — the same planner with a ``ProfileCalibrator`` subscribed
    to the audit stream: per-(app, stage) EWMA correction factors learn
    the realized/predicted ratio online and rescale the plan tables.

Per arm the sweep reports the audit stream's mean absolute
predicted-vs-realized stage-latency error, SLO attainment (sheds count
as misses), the median end-to-end SLO slack of completed requests, and
cost.  The bars (enforced unless ``--smoke``):

  * calibration cuts mean abs stage-latency error by >= 2x,
  * median SLO slack tightens (skew is overestimate-heavy, so the
    uncalibrated planner systematically overprovisions),
  * no attainment loss,

on every scenario.  Results land in
``benchmarks/results/calibration_sweep.csv``.

    PYTHONPATH=src python benchmarks/calibration_sweep.py
    PYTHONPATH=src python benchmarks/calibration_sweep.py --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent

from common import PAPER_APPS, ClusterSim, paper_tables, write_csv  # noqa: E402
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable  # noqa: E402
from repro.core.scheduler import ESGScheduler  # noqa: E402
from repro.obs import ProfileCalibrator, Recorder  # noqa: E402
from repro.serving import Gateway, get_autoscaler, get_scenario  # noqa: E402

SCENARIO_NAMES = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
                  "azure-tail", "skewed-mix"]

# Controller-side multiplicative exec_ms skew per function: +/-30%,
# overestimate-heavy (the common failure mode — offline profiling on a
# noisy shared box inflates estimates), deterministic so both arms and
# every rerun see the identical injected error.
SKEW = {
    "super_resolution": 1.3,
    "segmentation": 0.7,
    "deblur": 1.3,
    "classification": 1.3,
    "background_removal": 0.7,
    "depth": 1.3,
}

ERROR_CUT_MIN = 2.0            # ISSUE-7 acceptance: >= 2x error reduction

# Both arms carry a small planner risk margin (the existing
# ``risk_sigma`` knob — arm-neutral, so the comparison stays fair), and
# the calibrated arm publishes factors with a 2% conservative headroom:
# a *correctly* calibrated planner otherwise rides the budget edge,
# where per-task execution noise plus the EWMA's own wander tips a
# handful of tail requests over — the padding the mis-profiled tables
# happened to provide was doing the risk margin's job by accident.
RISK_SIGMA = 0.01
HEADROOM = 1.02

# The sweep showcases steady-state *tracking accuracy* under a large
# injected skew, so its calibrator runs hot: a short warmup and a fine
# 2% publication granularity.  The shipped defaults (min_samples=10,
# 5% steps) deliberately trade the last few percent of tracking for
# plan-cache friendliness — see the closed-loop bar in
# ``obs_overhead.py``: every publish invalidates cached plans, and at
# this sweep's settings an accurately-profiled stage would republish
# on pure execution noise.
MIN_SAMPLES = 5
PUBLISH_STEP = 0.02


def skewed_tables() -> dict[str, ProfileTable]:
    """The controller's (wrong) view: exec estimates off by SKEW[f]."""
    return {name: ProfileTable.build(
        dataclasses.replace(fn, t1_ms=fn.t1_ms * SKEW[name]))
        for name, fn in PAPER_FUNCTIONS.items()}


def run_arm(scenario: str, tables, n: int, seed: int, calibrate: bool):
    sched = ESGScheduler(PAPER_APPS, tables, risk_sigma=RISK_SIGMA)
    rec = Recorder(trace=False)          # audit + metrics; spans not needed
    if calibrate:
        sched.calibrator = ProfileCalibrator(
            min_samples=MIN_SAMPLES, headroom=HEADROOM,
            publish_rel_step=PUBLISH_STEP).attach(rec.audit)
    # controller plans on the skewed tables; the emulator executes on
    # the true PAPER_FUNCTIONS profiles — exactly a mis-profiled fleet
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), recorder=rec)
    gw = Gateway(sim)
    gw.inject(get_scenario(scenario, app_names=list(PAPER_APPS)), n,
              seed=seed + 1, slo_mult=1.0)
    tel = gw.run()
    cal = rec.audit.calibration()
    slacks = sorted(i.slo_ms - (i.finish_ms - i.arrival_ms)
                    for i in sim.completed)
    return {
        "arm": "on" if calibrate else "off",
        "scenario": scenario,
        "n": n,
        "completed": tel.completed,
        "shed": tel.n_shed,
        "attainment": tel.slo_attainment(),
        "mean_abs_err": cal["mean_abs_err"],
        "p90_abs_err": cal["p90_abs_err"],
        "median_slack_ms": slacks[len(slacks) // 2] if slacks else 0.0,
        "cost_per_1k": tel.cost_per_1k(),
        "factor_updates": sched.calibrator.updates if calibrate else 0,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=300,
                    help="requests injected per scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: two scenarios, fewer requests, "
                         "report-only (no acceptance gating)")
    args = ap.parse_args()
    scenarios = SCENARIO_NAMES[:2] if args.smoke else SCENARIO_NAMES
    n = min(args.n, 80) if args.smoke else args.n

    tables = skewed_tables()
    rows, failures = [], []
    for sc in scenarios:
        off = run_arm(sc, tables, n, args.seed, calibrate=False)
        on = run_arm(sc, tables, n, args.seed, calibrate=True)
        rows += [off, on]
        cut = off["mean_abs_err"] / on["mean_abs_err"] \
            if on["mean_abs_err"] else float("inf")
        print(f"[calibration] {sc}: |err| {off['mean_abs_err']:.3f} -> "
              f"{on['mean_abs_err']:.3f} ({cut:.1f}x cut), "
              f"slack {off['median_slack_ms']:.0f} -> "
              f"{on['median_slack_ms']:.0f} ms, "
              f"slo {off['attainment']:.3f} -> {on['attainment']:.3f}, "
              f"$/1k {off['cost_per_1k']:.4f} -> {on['cost_per_1k']:.4f} "
              f"({on['factor_updates']} factor updates)")
        if cut < ERROR_CUT_MIN:
            failures.append(f"{sc}: error cut {cut:.2f}x < "
                            f"{ERROR_CUT_MIN:.0f}x")
        if on["median_slack_ms"] > off["median_slack_ms"]:
            failures.append(f"{sc}: median slack widened "
                            f"({off['median_slack_ms']:.0f} -> "
                            f"{on['median_slack_ms']:.0f} ms)")
        if on["attainment"] < off["attainment"]:
            failures.append(f"{sc}: attainment lost "
                            f"({off['attainment']:.3f} -> "
                            f"{on['attainment']:.3f})")

    header = ["scenario", "arm", "n", "completed", "shed", "attainment",
              "mean_abs_err", "p90_abs_err", "median_slack_ms",
              "cost_per_1k", "factor_updates"]
    # smoke runs land in a scratch file so CI never clobbers the
    # committed full-run results
    name = "calibration_sweep_smoke" if args.smoke else "calibration_sweep"
    path = write_csv(name, header, [[r[k] for k in header] for r in rows])
    print(f"[calibration] wrote {path}")
    if args.smoke:
        if failures:
            print(f"[calibration] smoke: {len(failures)} bar(s) missed "
                  f"at reduced n (full run enforces)")
        print("[calibration] smoke OK")
        return 0
    for f in failures:
        print(f"[calibration] FAIL: {f}")
    if not failures:
        print("[calibration] OK: >=2x error cut, tighter median slack, "
              "no attainment loss on all scenarios")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
