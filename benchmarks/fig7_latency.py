"""Fig 7: per-application end-to-end latency distributions (relaxed-heavy)."""
from __future__ import annotations

from benchmarks import common
from benchmarks.fig6_endtoend import SCHEDULERS


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print):
    rows = []
    tables = common.paper_tables()
    for name in SCHEDULERS:
        r = common.run_setting(name, "relaxed-heavy", n=n, seed=seed,
                               tables=tables)
        for app, st in r["per_app"].items():
            rows.append([name, app, f"{st['mean_ms']:.1f}",
                         f"{st['p95_ms']:.1f}", f"{st['hit_rate']:.4f}"])
            log(f"  {name:12s} {app:32s} mean={st['mean_ms']:7.0f}ms "
                f"p95={st['p95_ms']:7.0f}ms hit={st['hit_rate']:.2f}")
    common.write_csv("fig7_latency",
                     ["scheduler", "app", "mean_ms", "p95_ms", "hit_rate"],
                     rows)
    return rows


if __name__ == "__main__":
    run()
