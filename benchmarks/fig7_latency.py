"""Fig 7: per-application end-to-end latency distributions (relaxed-heavy).

``--scenario`` regenerates the figure under any serving scenario from
``repro.serving.traces`` instead of the paper's uniform arrivals."""
from __future__ import annotations

import argparse

try:
    from benchmarks import common
    from benchmarks.fig6_endtoend import SCHEDULERS
except ImportError:              # script-style: benchmarks/ is sys.path[0]
    import common
    from fig6_endtoend import SCHEDULERS


def run(n: int = common.N_DEFAULT, seed: int = 0, log=print,
        scenario: str | None = None):
    rows = []
    tables = common.paper_tables()
    for name in SCHEDULERS:
        r = common.run_setting(name, "relaxed-heavy", n=n, seed=seed,
                               tables=tables, scenario=scenario)
        for app, st in r["per_app"].items():
            rows.append([name, app, f"{st['mean_ms']:.1f}",
                         f"{st['p95_ms']:.1f}", f"{st['hit_rate']:.4f}"])
            log(f"  {name:12s} {app:32s} mean={st['mean_ms']:7.0f}ms "
                f"p95={st['p95_ms']:7.0f}ms hit={st['hit_rate']:.2f}")
    suffix = f"_{scenario}" if scenario else ""
    common.write_csv(f"fig7_latency{suffix}",
                     ["scheduler", "app", "mean_ms", "p95_ms", "hit_rate"],
                     rows)
    return rows


def main():
    from repro.serving.traces import SCENARIOS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=common.N_DEFAULT)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS))
    args = ap.parse_args()
    run(n=args.n, seed=args.seed, scenario=args.scenario)


if __name__ == "__main__":
    main()
