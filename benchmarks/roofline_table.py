"""§Roofline: the 40-cell (arch x shape) baseline table from the dry-run
artifacts (single-pod mesh, per spec)."""
from __future__ import annotations

import json
import pathlib

from benchmarks import common
from repro.configs.registry import ARCH_IDS, SHAPES

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def load_cell(arch: str, shape: str, mesh: str = "single",
              tag: str = "") -> dict | None:
    name = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
    p = DRYRUN / f"{name}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def run(log=print):
    rows = []
    log("  arch                        shape        dom   comp_ms  mem_ms "
        " coll_ms  bound_ms  roofline%  useful%  fits16G")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            d = load_cell(arch, shape)
            if d is None:
                continue
            if not d.get("applicable", True):
                rows.append([arch, shape, "SKIP", "", "", "", "", "", "",
                             d.get("skip_reason", "")])
                continue
            if d.get("status") != "ok":
                rows.append([arch, shape, "ERROR", "", "", "", "", "", "",
                             d.get("error", "")[:80]])
                continue
            r = d["roofline"]
            rows.append([
                arch, shape, r["dominant"],
                f"{r['compute_s']*1e3:.2f}", f"{r['memory_s']*1e3:.2f}",
                f"{r['collective_s']*1e3:.2f}", f"{r['bound_s']*1e3:.2f}",
                f"{100*r['roofline_fraction']:.1f}",
                f"{100*min(r['useful_ratio'], 9.99):.1f}",
                str(d.get("fits_hbm16g", "")),
            ])
            log(f"  {arch:26s} {shape:12s} {r['dominant'][:4]:5s}"
                f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:7.2f} "
                f"{r['collective_s']*1e3:8.2f} {r['bound_s']*1e3:9.2f} "
                f"{100*r['roofline_fraction']:9.1f} "
                f"{100*min(r['useful_ratio'],9.99):8.1f}  "
                f"{d.get('fits_hbm16g','')}")
    common.write_csv(
        "roofline_table",
        ["arch", "shape", "dominant", "compute_ms", "memory_ms",
         "collective_ms", "bound_ms", "roofline_pct", "useful_pct",
         "fits_hbm16g"], rows)
    return rows


if __name__ == "__main__":
    run()
