"""Closed-loop observability test suite (PR 7).

Four layers of protection around ``repro.obs.calibrate`` and
``repro.obs.health``:

  * **dormancy** — the entire feedback layer defaults off: a recorder
    carrying a health engine (unwired to any consumer) replays every
    serving scenario bit-identically, and an *attached* calibrator whose
    predictions exactly match reality (``noise_sigma=0``) never
    publishes a factor, so the run stays bit-identical too;
  * **calibrator semantics** — EWMA no-op at predicted == realized,
    warmup gating, convergence to the true ratio under injected
    multiplicative skew, outlier clipping, clamping, publish
    hysteresis, conservative headroom, and parameter validation;
  * **health engine** — multi-window burn-rate alerts fire on a
    synthetic miss burst and clear on recovery (sheds spend budget),
    drift/queue/spike detectors transition correctly, alert exports
    round-trip through ``repro.obs.validate``;
  * **plumbing** — plan-cache keys grow the factor axis exactly when a
    factor is published (stale plans become unreachable), the gateway
    sheds earlier under a firing alert, the vertical autoscaler
    withholds opportunistic grows, and ``Telemetry.summary()`` carries
    the calibration and health blocks.
"""
import dataclasses
import json
import pathlib
from types import SimpleNamespace

import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.obs import (AuditLog, HealthEngine, PlanRecord,
                       ProfileCalibrator, Recorder)
from repro.obs.calibrate import RATIO_CLIP
from repro.obs.health import (ALERT_KINDS, CAL_DRIFT, CLEARED, COLD_SPIKE,
                              FIRING, PREFETCH_WASTE, QUEUE_BUILDUP,
                              SLO_BURN)
from repro.obs.validate import (main as validate_main, validate_audit,
                                validate_health, validate_metrics_csv)
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.autoscaler import AUTOSCALERS
from repro.serving.traces import SCENARIOS

APPS = list(PAPER_APPS)
N_REQ = 24


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(tables, scenario, n=N_REQ, seed=0, slo_mult=1.0, recorder=None,
         calibrator=None, **sim_kw):
    sched = ESGScheduler(PAPER_APPS, tables)
    if calibrator is not None:
        sched.calibrator = calibrator.attach(recorder.audit)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"),
                     recorder=recorder, **sim_kw)
    gw = Gateway(sim)
    gw.inject(get_scenario(scenario, app_names=APPS), n, seed=seed + 1,
              slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim, sched


def _timeline(sim):
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices,
              t.penalty_ms, t.full_penalty_ms)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    shed = [i.uid for i in sim.shed]
    return tasks, done, shed, sim.total_cost, sim.cold_starts, \
        sim.remote_transfers


def _rec(app="image_classification", stage="0:super_resolution",
         raw=None, exec_ms=None, predicted=None, realized=None, t=0.0):
    """A PlanRecord carrying only the fields the feedback layer reads."""
    return PlanRecord(t, app, stage, 1, 100.0, "exact", 0, 0, 0,
                      None, None, None, 1,
                      predicted_ms=predicted, realized_ms=realized,
                      predicted_raw_ms=raw, realized_exec_ms=exec_ms)


# ---------------------------------------------------------------------------
# dormancy: calibration off (or unpublished) never changes a run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_health_carrying_recorder_replays_bit_identically(tables, scenario):
    """A recorder with a health engine attached — but no consumer wired
    — observes every scenario without changing a single decision."""
    _, sim_off, _ = _run(tables, scenario)
    rec = Recorder(health=HealthEngine())
    _, sim_on, _ = _run(tables, scenario, recorder=rec)
    assert _timeline(sim_on) == _timeline(sim_off)


@pytest.mark.parametrize("scenario", ["mmpp", "flash-crowd"])
def test_attached_calibrator_is_noop_when_predictions_exact(tables,
                                                            scenario):
    """With zero execution noise, predicted == realized for every stage:
    an *attached* calibrator consumes the whole stream yet never
    publishes, and the schedule stays bit-identical."""
    _, sim_off, _ = _run(tables, scenario, noise_sigma=0.0)
    cal = ProfileCalibrator()
    _, sim_on, _ = _run(tables, scenario, noise_sigma=0.0,
                        recorder=Recorder(trace=False), calibrator=cal)
    assert cal.observations > 0
    assert cal.updates == 0 and cal.version == 0
    assert all(f == 1.0 for f in
               cal.factors(APPS[0], ("0:super_resolution",)))
    assert _timeline(sim_on) == _timeline(sim_off)


# ---------------------------------------------------------------------------
# calibrator unit semantics
# ---------------------------------------------------------------------------
def test_calibrator_rejects_bad_params():
    with pytest.raises(ValueError):
        ProfileCalibrator(alpha=0.0)
    with pytest.raises(ValueError):
        ProfileCalibrator(alpha=1.5)
    with pytest.raises(ValueError):
        ProfileCalibrator(clamp=(0.0, 4.0))
    with pytest.raises(ValueError):
        ProfileCalibrator(clamp=(0.5, 0.9))
    with pytest.raises(ValueError):
        ProfileCalibrator(headroom=0.9)


def test_calibrator_noop_on_exact_predictions():
    cal = ProfileCalibrator()
    for i in range(50):
        cal.observe(_rec(raw=100.0, exec_ms=100.0, t=float(i)))
    assert cal.observations == 50
    assert cal.updates == 0 and cal.version == 0
    assert cal.factor("image_classification", "0:super_resolution") == 1.0


def test_calibrator_warmup_gate_then_publish():
    cal = ProfileCalibrator(min_samples=5)
    for i in range(4):
        cal.observe(_rec(raw=100.0, exec_ms=130.0, t=float(i)))
    assert cal.factor("image_classification", "0:super_resolution") == 1.0
    assert cal.version == 0
    cal.observe(_rec(raw=100.0, exec_ms=130.0, t=4.0))
    f = cal.factor("image_classification", "0:super_resolution")
    assert f == pytest.approx(1.3)
    assert cal.version == 1 and cal.updates == 1


def test_calibrator_converges_under_noisy_ratio():
    """Alternating 1.2/1.4 ratios: the EWMA settles near the 1.3 mean."""
    cal = ProfileCalibrator(alpha=0.2, min_samples=5)
    for i in range(80):
        realized = 120.0 if i % 2 == 0 else 140.0
        cal.observe(_rec(raw=100.0, exec_ms=realized, t=float(i)))
    assert cal.factor("image_classification",
                      "0:super_resolution") == pytest.approx(1.3, abs=0.05)
    assert cal.samples("image_classification", "0:super_resolution") == 80


def test_calibrator_clamps_extreme_factors():
    lo, hi = 0.25, 4.0
    cal = ProfileCalibrator(min_samples=1, clamp=(lo, hi))
    cal.observe(_rec(raw=100.0, exec_ms=700.0))
    assert cal.factor("image_classification", "0:super_resolution") == hi
    cal2 = ProfileCalibrator(min_samples=1, clamp=(lo, hi))
    cal2.observe(_rec(raw=1000.0, exec_ms=1.0))
    assert cal2.factor("image_classification", "0:super_resolution") == lo


def test_calibrator_clips_outlier_ratio_before_ewma():
    cal = ProfileCalibrator(alpha=0.2, min_samples=1)
    for i in range(20):
        cal.observe(_rec(raw=100.0, exec_ms=100.0, t=float(i)))
    cal.observe(_rec(raw=100.0, exec_ms=1e9, t=20.0))
    # a single pathological record moves the EWMA at most
    # alpha * (RATIO_CLIP.hi - 1), not to the clamp ceiling
    f = cal.factor("image_classification", "0:super_resolution")
    assert f <= 1.0 + 0.2 * (RATIO_CLIP[1] - 1.0) + 1e-9


def test_calibrator_publish_hysteresis():
    cal = ProfileCalibrator(alpha=1.0, min_samples=1,
                            publish_rel_step=0.02)
    cal.observe(_rec(raw=100.0, exec_ms=130.0))
    assert cal.version == 1
    # a sub-2% wiggle updates the working EWMA but not the factor
    cal.observe(_rec(raw=100.0, exec_ms=131.0, t=1.0))
    assert cal.version == 1
    assert cal.factor("image_classification",
                      "0:super_resolution") == pytest.approx(1.3)
    # a real move republishes and bumps the version again
    cal.observe(_rec(raw=100.0, exec_ms=160.0, t=2.0))
    assert cal.version == 2
    assert cal.factor("image_classification",
                      "0:super_resolution") == pytest.approx(1.6)


def test_calibrator_headroom_is_a_deliberate_overcorrection():
    cal = ProfileCalibrator(min_samples=3, headroom=1.10)
    for i in range(3):
        cal.observe(_rec(raw=100.0, exec_ms=100.0, t=float(i)))
    # even a perfect profile gets the configured conservative margin
    assert cal.factor("image_classification",
                      "0:super_resolution") == pytest.approx(1.10)


def test_calibrator_ignores_incomplete_records():
    cal = ProfileCalibrator(min_samples=1)
    cal.observe(_rec(raw=None, exec_ms=100.0))
    cal.observe(_rec(raw=100.0, exec_ms=None))
    cal.observe(_rec(raw=0.0, exec_ms=100.0))
    cal.observe(_rec(raw=100.0, exec_ms=-5.0))
    assert cal.observations == 0
    assert cal.factor("image_classification", "0:super_resolution") == 1.0


def test_calibrator_summary_structure():
    cal = ProfileCalibrator(min_samples=1)
    cal.observe(_rec(raw=100.0, exec_ms=130.0))
    s = cal.summary()
    assert s["observations"] == 1 and s["updates"] == 1
    block = s["per_stage"]["image_classification/0:super_resolution"]
    assert block["n"] == 1
    assert block["factor"] == pytest.approx(1.3)
    assert block["ewma"] == pytest.approx(1.3)


def test_convergence_under_injected_multiplicative_skew(tables):
    """Controller tables 30% slow on every function: the learned factors
    converge to ~1/1.3 and the audit error collapses vs uncalibrated."""
    skewed = {n: ProfileTable.build(
        dataclasses.replace(p, t1_ms=p.t1_ms * 1.3))
        for n, p in PAPER_FUNCTIONS.items()}
    rec_off = Recorder(trace=False)
    _run(skewed, "uniform-normal", n=120, recorder=rec_off)
    # hot tracking config (mirrors the calibration sweep arm): the
    # shipped defaults trade convergence speed for plan-cache
    # friendliness and need a longer run than this test injects
    cal = ProfileCalibrator(min_samples=5, publish_rel_step=0.02)
    rec_on = Recorder(trace=False)
    _run(skewed, "uniform-normal", n=120, recorder=rec_on, calibrator=cal)
    published = [v for v in cal._published.values()]
    assert published, "no factor ever published under a 30% skew"
    true = 1.0 / 1.3
    for f in published:
        assert f == pytest.approx(true, abs=0.08)
    err_off = rec_off.audit.calibration()["mean_abs_err"]
    err_on = rec_on.audit.calibration()["mean_abs_err"]
    assert err_on < err_off / 2.0


# ---------------------------------------------------------------------------
# health engine
# ---------------------------------------------------------------------------
def test_burn_rate_fires_on_burst_and_clears_on_recovery():
    eng = HealthEngine(default_target=0.9, min_requests=10)
    for i in range(20):                          # healthy baseline
        eng.on_request("app_a", 100.0 * i, ok=True)
    assert not eng.firing()
    for i in range(15):                          # synthetic miss burst
        eng.on_request("app_a", 5000.0 + 50.0 * i, ok=False)
    active = eng.firing(kind=SLO_BURN, app="app_a")
    assert len(active) == 1
    assert active[0].state == FIRING
    assert active[0].value >= eng.burn_threshold
    assert eng.early_warning("app_a")
    # recovery: the short window ages the burst out and the alert clears
    eng.on_request("app_a", 17_000.0, ok=True)
    assert not eng.firing()
    assert not eng.early_warning("app_a")
    states = [a.state for a in eng.alerts if a.kind == SLO_BURN]
    assert states == [FIRING, CLEARED]


def test_burn_rate_min_requests_gate():
    eng = HealthEngine(default_target=0.9, min_requests=10)
    for i in range(5):
        eng.on_request("app_a", 100.0 * i, ok=False)
    assert not eng.firing()                      # evidence too thin to page


def test_sheds_spend_error_budget():
    eng = HealthEngine(default_target=0.9, min_requests=10)
    for i in range(12):
        eng.on_shed("app_a", 100.0 * i)
    assert eng.firing(kind=SLO_BURN, app="app_a")


def test_burn_rate_query():
    eng = HealthEngine(default_target=0.9)
    assert eng.burn_rate("ghost", 0.0) == (0.0, 0.0)
    for i in range(10):
        eng.on_request("app_a", float(i), ok=(i % 2 == 0))
    s, l = eng.burn_rate("app_a", 10.0)
    assert s == pytest.approx(0.5 / 0.1)         # half missing, 10% budget


def test_calibration_drift_detector_fires_on_regime_change():
    eng = HealthEngine(drift_min_samples=10)
    for i in range(30):                          # well-calibrated regime
        eng.observe_calibration(_rec(predicted=100.0, realized=100.0,
                                     t=float(i)))
    assert not eng.firing(kind=CAL_DRIFT)
    for i in range(30):                          # profiles start drifting
        eng.observe_calibration(_rec(predicted=100.0, realized=160.0,
                                     t=100.0 + i))
    assert eng.firing(kind=CAL_DRIFT, app="image_classification")


def test_queue_buildup_needs_sustained_depth():
    eng = HealthEngine(queue_depth_limit=64, queue_sustain=3)
    eng.on_window(1000.0, queue_depth=100, cold_starts=0,
                  prefetch_wasted=0)
    eng.on_window(2000.0, queue_depth=100, cold_starts=0,
                  prefetch_wasted=0)
    assert not eng.firing(kind=QUEUE_BUILDUP)    # two windows: not yet
    eng.on_window(3000.0, queue_depth=100, cold_starts=0,
                  prefetch_wasted=0)
    assert eng.firing(kind=QUEUE_BUILDUP)
    assert eng.early_warning("any_app")          # cluster-scoped alert
    eng.on_window(4000.0, queue_depth=0, cold_starts=0, prefetch_wasted=0)
    assert not eng.firing(kind=QUEUE_BUILDUP)


def test_spike_detectors_compare_against_trailing_baseline():
    eng = HealthEngine(spike_mult=4.0, spike_floor=8.0)
    for i in range(5):                           # quiet baseline
        eng.on_window(1000.0 * i, queue_depth=0, cold_starts=1,
                      prefetch_wasted=1)
    assert not eng.firing()
    eng.on_window(6000.0, queue_depth=0, cold_starts=50,
                  prefetch_wasted=40)
    assert eng.firing(kind=COLD_SPIKE)
    assert eng.firing(kind=PREFETCH_WASTE)
    # back to baseline clears both
    eng.on_window(7000.0, queue_depth=0, cold_starts=1, prefetch_wasted=1)
    assert not eng.firing()


def test_quiet_run_cannot_spike_from_zero():
    eng = HealthEngine(spike_mult=4.0, spike_floor=8.0)
    for i in range(10):
        eng.on_window(1000.0 * i, queue_depth=0, cold_starts=2,
                      prefetch_wasted=3)
    assert not eng.firing()                      # 2-3 << the absolute floor


def test_early_warning_scoping():
    eng = HealthEngine(default_target=0.9, min_requests=10)
    for i in range(12):
        eng.on_request("app_a", 100.0 * i, ok=False)
    assert eng.early_warning("app_a")
    assert not eng.early_warning("app_b")        # someone else's pager
    assert eng.early_warning()                   # cluster view sees it


def test_alert_export_roundtrips_through_validate(tmp_path):
    eng = HealthEngine(default_target=0.9, min_requests=10,
                       queue_depth_limit=64, queue_sustain=1)
    for i in range(12):
        eng.on_request("app_a", 100.0 * i, ok=False)
    eng.on_window(2000.0, queue_depth=100, cold_starts=0,
                  prefetch_wasted=0)
    eng.on_request("app_a", 20_000.0, ok=True)
    path = tmp_path / "health.jsonl"
    n = eng.export_jsonl(str(path))
    records = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(records) == n == len(eng.alerts)
    counts = validate_health(records, str(path))
    assert counts[SLO_BURN] == 2                 # fired, then cleared
    assert counts[QUEUE_BUILDUP] == 1
    assert all(r["kind"] in ALERT_KINDS for r in records)
    assert validate_main([str(path)]) == 0       # CLI sniffs .jsonl alerts


def test_health_summary_counts_transitions():
    eng = HealthEngine(default_target=0.9, min_requests=10)
    for i in range(12):
        eng.on_request("app_a", 100.0 * i, ok=False)
    s = eng.summary()
    assert s["alerts_total"] == 1
    assert s["active"] == ["slo_burn_rate[app_a]"]
    assert s["transitions"] == {"slo_burn_rate:firing": 1}


def test_health_requires_metrics_feed():
    with pytest.raises(ValueError):
        Recorder(metrics=False, health=HealthEngine())


# ---------------------------------------------------------------------------
# plumbing: scheduler, plan cache, gateway, autoscaler, telemetry
# ---------------------------------------------------------------------------
def test_profile_table_scaled(tables):
    t = tables["classification"]
    s = t.scaled(1.3)
    assert np.allclose(s.times, t.times * 1.3)
    assert np.allclose(s.job_costs, t.job_costs * 1.3)
    assert s.configs == t.configs
    assert t.scaled(1.0) is t                    # identity fast path
    with pytest.raises(ValueError):
        t.scaled(0.0)


def test_scheduler_factor_gating_and_cache_reset(tables):
    cal = ProfileCalibrator(alpha=1.0, min_samples=1)
    sched = ESGScheduler(PAPER_APPS, tables, calibrator=cal)
    stages = ("0:super_resolution", "1:segmentation", "2:classification")
    # cold calibrator: the uncorrected path (factors None, 4-tuple keys)
    assert sched._factors("image_classification", stages) is None
    cal.observe(_rec(raw=100.0, exec_ms=130.0))
    f = sched._factors("image_classification", stages)
    assert f == (pytest.approx(1.3), 1.0, 1.0)
    # a published change drops the memoized scaled tables
    sched._scaled[("sentinel",)] = ["stale"]
    cal.observe(_rec(raw=100.0, exec_ms=200.0, t=1.0))
    sched._factors("image_classification", stages)
    assert ("sentinel",) not in sched._scaled


def test_plan_cache_keys_grow_factor_axis_on_publish(tables):
    """Calibrated runs key cached plans under the factor tuple: a factor
    publish makes every stale plan unreachable instead of evicting it."""
    skewed = {n: ProfileTable.build(
        dataclasses.replace(p, t1_ms=p.t1_ms * 1.3))
        for n, p in PAPER_FUNCTIONS.items()}
    _, _, sched_off = _run(skewed, "mmpp", n=20,
                           recorder=Recorder(trace=False))
    # shape keys: (funcs, bucket, pen_key) uncalibrated
    assert all(len(k) == 3 for k in sched_off.cache._entries)
    cal = ProfileCalibrator(min_samples=3)
    _, _, sched_on = _run(skewed, "mmpp", n=60,
                          recorder=Recorder(trace=False), calibrator=cal)
    keys = list(sched_on.cache._entries)
    assert cal.updates > 0
    assert any(len(k) == 4 for k in keys), \
        "no factor-keyed plan ever cached despite published corrections"
    # the factor axis is the published tuple itself
    four = [k for k in keys if len(k) == 4]
    assert all(isinstance(k[3], tuple) for k in four)


class _AlwaysFiring:
    def early_warning(self, app=None):
        return True


def test_gateway_sheds_earlier_under_firing_alert(tables):
    """The admission check inflates predicted queueing while an alert
    relevant to the app is firing: a request that would squeak in on the
    EWMA alone is shed when the alert says the EWMA is lagging."""
    _, sim, _ = _run(tables, "mmpp", n=6)
    gw = Gateway(sim)
    gw.inject(get_scenario("mmpp", app_names=APPS), 0, seed=1)
    app = sim.apps["image_classification"]
    for stage in app.stages:
        gw._qdelay[(app.name, stage)] = 10.0
    fastest = gw._fastest_ms[app.name]
    inst = SimpleNamespace(app=app,
                           deadline_ms=sim.now + fastest + 100.0)
    assert gw._admit(sim, inst)                  # EWMA says it fits
    gw.health, gw.health_headroom = _AlwaysFiring(), 1e6
    assert not gw._admit(sim, inst)              # alert says it will not


def test_vertical_scaler_withholds_grow_under_alert():
    pol = AUTOSCALERS["vertical"]()
    stub = SimpleNamespace(queues={})             # nothing queued
    pol.health = _AlwaysFiring()
    pol._grow(stub, 0)                            # returns before invokers
    pol.health = None
    with pytest.raises(AttributeError):
        pol._grow(stub, 0)                        # proof it would proceed


def test_telemetry_carries_calibration_and_health_blocks(tables):
    cal = ProfileCalibrator()
    rec = Recorder(trace=False, health=HealthEngine())
    tel, _, _ = _run(tables, "mmpp", recorder=rec, calibrator=cal)
    s = tel.summary()
    assert s["calibration"]["observations"] == cal.observations > 0
    assert s["health"]["alerts_total"] == len(rec.health.alerts)
    # satellite: per-stage blocks carry their sample counts
    per_stage = s["predicted_vs_realized"]["per_stage"]
    assert per_stage
    for block in per_stage.values():
        assert block["n"] >= 1
        if block["n"] < 2:                       # quantiles need 2 samples
            assert block["p50_err"] is None
        else:
            assert block["p50_err"] is not None


def test_audit_per_stage_quantiles_gate_on_sample_count():
    audit = AuditLog()
    audit.on_plan(_rec(raw=100.0))
    audit.on_dispatch("image_classification", "0:super_resolution", 0,
                      None, predicted_ms=100.0, predicted_raw_ms=100.0)
    audit.on_complete(0, 110.0, realized_exec_ms=110.0)
    block = audit.calibration()["per_stage"][
        "image_classification/0:super_resolution"]
    assert block["n"] == 1
    assert block["p50_err"] is None and block["p90_abs_err"] is None
    assert block["mean_err"] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# validator extensions: offending file and record are always named
# ---------------------------------------------------------------------------
def test_validate_metrics_csv_roundtrip_and_errors(tmp_path, tables):
    rec = Recorder(trace=False)
    _run(tables, "mmpp", recorder=rec)
    good = tmp_path / "metrics.csv"
    rec.metrics.to_csv(str(good))
    assert validate_metrics_csv(str(good)) > 0
    lines = good.read_text().splitlines()
    bad = tmp_path / "corrupt.csv"
    bad.write_text("\n".join([lines[0], lines[1].rsplit(",", 1)[0]
                              + ",not_a_number"] + lines[2:]) + "\n")
    with pytest.raises(ValueError) as ei:
        validate_metrics_csv(str(bad))
    assert "corrupt.csv" in str(ei.value) and "line 2" in str(ei.value)


def test_validate_audit_names_offending_record(tmp_path, tables):
    rec = Recorder(trace=False)
    _run(tables, "mmpp", recorder=rec)
    path = tmp_path / "audit.jsonl"
    rec.export(audit_path=str(path))
    records = [json.loads(l) for l in path.read_text().splitlines()]
    counts = validate_audit(records, str(path))
    assert counts["plan"] > 0
    records[3]["t_ms"] = "yesterday"
    with pytest.raises(ValueError) as ei:
        validate_audit(records, str(path))
    assert "audit.jsonl" in str(ei.value) and "record 3" in str(ei.value)


def _retry_rec(**over):
    rec = {"type": "retry", "t_ms": 5.0, "app": "image_classification",
           "stage": "0:super_resolution", "uid": 7, "invoker": 2,
           "attempt": 1, "action": "retry", "backoff_ms": 250.0,
           "lost_ms": 12.5}
    rec.update(over)
    return rec


def test_validate_audit_counts_retry_records():
    recs = [_retry_rec(), _retry_rec(attempt=2, action="resume"),
            _retry_rec(attempt=3, action="shed", backoff_ms=0.0)]
    assert validate_audit(recs, "audit.jsonl")["retry"] == 3


def test_validate_audit_rejects_bad_retry_action():
    recs = [_retry_rec(), _retry_rec(action="requeue")]
    with pytest.raises(ValueError) as ei:
        validate_audit(recs, "audit.jsonl")
    msg = str(ei.value)
    assert "audit.jsonl" in msg and "record 1" in msg
    assert "requeue" in msg and "retry" in msg


def test_validate_audit_rejects_bad_retry_attempt():
    for attempt in (0, -1, 1.5, True, "first"):
        with pytest.raises(ValueError) as ei:
            validate_audit([_retry_rec(attempt=attempt)], "audit.jsonl")
        msg = str(ei.value)
        assert "record 0" in msg and "attempt" in msg


def test_validate_audit_rejects_negative_retry_costs():
    for field in ("backoff_ms", "lost_ms"):
        with pytest.raises(ValueError) as ei:
            validate_audit([_retry_rec(**{field: -1.0})], "audit.jsonl")
        msg = str(ei.value)
        assert "record 0" in msg and field in msg


def test_validate_audit_names_missing_retry_fields():
    rec = _retry_rec()
    del rec["uid"], rec["action"]
    with pytest.raises(ValueError) as ei:
        validate_audit([rec], "bad_audit.jsonl")
    msg = str(ei.value)
    assert "bad_audit.jsonl" in msg and "record 0" in msg
    assert "uid" in msg and "action" in msg


def test_validate_audit_bad_type_mentions_retry():
    with pytest.raises(ValueError, match=r"plan\|skip\|retry"):
        validate_audit([{"type": "redo", "t_ms": 1.0}], "audit.jsonl")


def test_validate_health_rejects_double_fire(tmp_path):
    recs = [{"type": "alert", "t_ms": 1.0, "kind": SLO_BURN, "app": "a",
             "state": FIRING, "value": 3.0, "threshold": 2.0},
            {"type": "alert", "t_ms": 2.0, "kind": SLO_BURN, "app": "a",
             "state": FIRING, "value": 4.0, "threshold": 2.0}]
    with pytest.raises(ValueError) as ei:
        validate_health(recs, "health.jsonl")
    assert "health.jsonl" in str(ei.value) and "record 1" in str(ei.value)
    recs[1]["state"] = CLEARED
    assert validate_health(recs, "health.jsonl") == {SLO_BURN: 2}


def test_validate_cli_dispatches_all_artifacts(tmp_path, tables):
    rec = Recorder(health=HealthEngine())
    _run(tables, "mmpp", recorder=rec)
    trace = tmp_path / "trace.json"
    metrics = tmp_path / "metrics.json"
    audit = tmp_path / "audit.jsonl"
    health = tmp_path / "health.jsonl"
    csv = tmp_path / "metrics.csv"
    rec.export(str(trace), str(metrics), str(audit),
               health_path=str(health))
    rec.metrics.to_csv(str(csv))
    assert validate_main([str(trace), str(metrics), str(audit),
                          str(health), str(csv)]) == 0
