"""Heterogeneous preemptible fleets: spot GPUs, mid-task reclamation,
and the fault-injection harness (PR 8).

Five layers of protection:

  * **neutrality** — every serving scenario replays bit-identically
    between the default configuration and any spelling of a single-SKU,
    no-spot fleet (``fleet=["a100"] * n``): the fleet machinery must be
    arithmetically invisible until a non-default SKU appears;
  * **unit semantics** — the SKU catalogue, ``preempt_priced`` pricing
    transform, device ``kill``/``reclaim``/``empty`` ledger operations,
    warm-up-from-zero, exec-rate scaling and spot billing discounts;
  * **fault injection** — seeded reclamation storms kill running tasks
    mid-execution; property-style random walks assert the recovery
    invariants (no request lost, charged <= full penalty, HBM ledger
    consistent after kills, every reclaimed task completes or is shed
    with an audit record);
  * **planner oracle** — brute-force expected-cost-under-preemption on
    tiny workflows must agree with ``esg_1q`` over ``preempt_priced``
    tables, in both search engines;
  * **golden fixture** — a seeded ``spot-storm`` run's outcome summary
    is pinned against a committed fixture.
"""
import itertools
import json
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to the
    from _hypothesis_fallback import (   # vendored deterministic sampler
        given, settings, strategies as st)

from repro.cluster.emulator import KEEPALIVE_MS, ClusterSim
from repro.core.astar import esg_1q
from repro.core.profiles import (PAPER_FUNCTIONS, Config, FunctionProfile,
                                 ProfileTable)
from repro.core.scheduler import (CKPT_LOSS_FRAC, PREEMPT_LOSS_FRAC,
                                  ESGScheduler)
from repro.core.workflows import PAPER_APPS
from repro.gpu import (DEFAULT_SKU, SKU_CATALOG, DeviceModel, GpuSKU,
                       OversubscribedError, resolve_sku)
from repro.obs import Recorder
from repro.obs.validate import validate_audit
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.autoscaler import AutoscalerPolicy
from repro.serving.traces import (SCENARIOS, HeteroMixScenario,
                                  SpotStormScenario)

APPS = list(PAPER_APPS)
HERE = pathlib.Path(__file__).resolve().parent
GOLDEN = HERE / "fixtures" / "golden_spot_storm.json"
N_REQ = 24

# an aggressive test fleet: spot SKUs with short reclamation horizons so
# small runs actually see kills without multi-minute simulated traces
VOLATILE = GpuSKU(name="volatile", price_factor=0.3, spot=True,
                  reclaim_mean_s=2.0, warn_ms=200.0, recover_ms=500.0)


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(tables, scenario="mmpp", n=N_REQ, seed=0, slo_mult=1.0,
         recorder=None, autoscaler="ewma", shed=True, **sim_kw):
    sched = ESGScheduler(PAPER_APPS, tables, placement="locality")
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler(autoscaler),
                     recorder=recorder, **sim_kw)
    gw = Gateway(sim, shed_doomed=shed)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim


def _timeline(sim):
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices,
              t.penalty_ms, t.full_penalty_ms)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    shed = [i.uid for i in sim.shed]
    return tasks, done, shed, sim.total_cost, sim.cold_starts, \
        sim.remote_transfers


# ---------------------------------------------------------------------------
# neutrality: a single-SKU no-spot fleet is the default emulator
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_single_sku_fleet_replays_bit_identically(tables, scenario):
    tel_d, sim_d = _run(tables, scenario)
    tel_f, sim_f = _run(tables, scenario, fleet=["a100"] * 16)
    assert _timeline(sim_f) == _timeline(sim_d)
    assert sim_f.gpu_summary() == sim_d.gpu_summary()
    assert tel_f.summary() == tel_d.summary()


def test_default_sku_object_fleet_is_also_neutral(tables):
    """Passing GpuSKU objects (not names) that equal DEFAULT_SKU must be
    detected by value, not identity."""
    clone = GpuSKU()                     # equal to DEFAULT_SKU, new object
    tel_d, sim_d = _run(tables, "uniform-normal", n=12)
    tel_f, sim_f = _run(tables, "uniform-normal", n=12, fleet=[clone])
    assert not sim_f._hetero and not sim_f._has_spot
    assert _timeline(sim_f) == _timeline(sim_d)


def test_default_fleet_has_no_reclaim_events(tables):
    _, sim = _run(tables, "uniform-normal", n=12)
    assert sim.reclaims == 0 and sim.reclaim_warnings == 0
    assert sim.preemptions == 0 and sim.retries == 0
    assert sim.sku_signature() is None


# ---------------------------------------------------------------------------
# SKU catalogue + resolution
# ---------------------------------------------------------------------------
def test_resolve_sku_accepts_name_object_and_none():
    assert resolve_sku(None) is DEFAULT_SKU
    assert resolve_sku("a100") == DEFAULT_SKU
    sku = GpuSKU(name="custom", exec_rate=2.0)
    assert resolve_sku(sku) is sku
    assert resolve_sku("h100").exec_rate > 1.0


def test_resolve_sku_unknown_name_lists_catalogue():
    with pytest.raises(KeyError, match="a100"):
        resolve_sku("no-such-gpu")


def test_catalogue_spot_skus_carry_reclamation_rates():
    for name, sku in SKU_CATALOG.items():
        assert sku.name == name
        assert sku.exec_rate > 0.0 and sku.price_factor > 0.0
        if sku.spot:
            assert sku.reclaim_mean_s > 0.0
            assert sku.price_factor < 1.0      # spot must be discounted
        else:
            assert sku.reclaim_mean_s == 0.0


# ---------------------------------------------------------------------------
# preempt_priced: the planner-facing pricing transform
# ---------------------------------------------------------------------------
def test_preempt_priced_neutral_returns_self(tables):
    t = tables["classification"]
    assert t.preempt_priced() is t
    assert t.preempt_priced(1.0, 0.0) is t


def test_preempt_priced_rejects_bad_arguments(tables):
    t = tables["classification"]
    with pytest.raises(ValueError):
        t.preempt_priced(0.0, 0.0)
    with pytest.raises(ValueError):
        t.preempt_priced(1.0, -1e-6)


def test_preempt_priced_preserves_time_sort_and_configs(tables):
    t = tables["deblur"]
    p = t.preempt_priced(1.4, 1e-4)
    assert p.configs == t.configs
    assert np.all(np.diff(p.times) >= 0.0)
    assert np.all(p.times > t.times)           # slower and risk-inflated
    assert np.all(p.job_costs > t.job_costs)


def test_preempt_priced_penalises_long_configs_superlinearly(tables):
    """The inflation ratio must grow with config latency — the pressure
    that steers the planner toward shorter stages under reclamation."""
    t = tables["segmentation"]
    p = t.preempt_priced(1.0, 1e-3)
    ratio = p.times / t.times
    assert ratio[-1] > ratio[0] > 1.0


# ---------------------------------------------------------------------------
# device-model ledger: kill / reclaim / empty
# ---------------------------------------------------------------------------
def test_device_kill_releases_slices_and_hbm():
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=512.0)
    a, _ = dev.start("f", 4, 300.0, 0.0)
    used_slices, used_hbm = dev.used_slices, dev.hbm_used_mb
    assert used_slices == 4 and used_hbm > 0.0
    dev.kill(a.aid)
    assert dev.used_slices == 0
    assert dev.hbm_used_mb < used_hbm
    dev.check()                                # ledger stays consistent


def test_device_reclaim_clears_pools_and_weights():
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=512.0)
    dev.add_warm("a", 10_000.0, 300.0, 0.0)
    dev.add_warm("b", 10_000.0, 200.0, 0.0)
    assert any(pool for pool in dev.pools.values())
    dev.reclaim()
    assert not any(pool for pool in dev.pools.values())
    assert not dev.weights and dev.hbm_used_mb == 0.0
    dev.check()


def test_device_reclaim_refuses_live_allocations():
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=512.0)
    dev.start("f", 2, 100.0, 0.0)
    with pytest.raises(OversubscribedError):
        dev.reclaim()


def test_device_empty_reflects_allocs_and_pools():
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=512.0)
    assert dev.empty(0.0)
    a, _ = dev.start("f", 2, 100.0, 0.0)
    assert not dev.empty(0.0)
    dev.kill(a.aid)
    assert dev.empty(0.0)
    dev.add_warm("f", 500.0, 100.0, 0.0)
    assert not dev.empty(0.0)
    assert dev.empty(1_000.0)                  # keep-alive expired -> gc'd


# ---------------------------------------------------------------------------
# SKU execution semantics in the emulator
# ---------------------------------------------------------------------------
def test_exec_rate_scales_task_durations(tables):
    """On a noise-free run, every exec span on an exec_rate=0.5 SKU must
    be exactly 2x the profile's deterministic latency model."""
    slow = GpuSKU(name="slow", exec_rate=0.5)
    _, slowed = _run(tables, "uniform-normal", n=10, autoscaler="none",
                     fleet=[slow], noise_sigma=0.0)
    assert slowed.tasks
    for t in slowed.tasks:
        es = t.end_ms - t.exec_start_ms
        want = 2.0 * tables[t.func].fn.exec_ms(t.config)
        assert es == pytest.approx(want, rel=1e-9)


def test_price_factor_discounts_gpu_billing(tables):
    cheap = GpuSKU(name="cheap", price_factor=0.5)
    _, base = _run(tables, "uniform-normal", n=10, autoscaler="none")
    _, disc = _run(tables, "uniform-normal", n=10, autoscaler="none",
                   fleet=[cheap])
    assert disc.total_cost < base.total_cost


def test_warmup_from_zero_charged_once_per_empty_device(tables):
    """A SKU with warmup_ms pays it only when the device is completely
    empty; once containers exist, starts are warm-path identical."""
    warm = GpuSKU(name="warmy", warmup_ms=500.0)
    _, base = _run(tables, "uniform-normal", n=10, autoscaler="none",
                   initial_warm=0, prewarm=False)
    _, cold = _run(tables, "uniform-normal", n=10, autoscaler="none",
                   initial_warm=0, prewarm=False, fleet=[warm])
    delays = sum(1 for tb, tc in zip(base.tasks, cold.tasks)
                 if tc.exec_start_ms - tc.start_ms ==
                 pytest.approx(tb.exec_start_ms - tb.start_ms + 500.0))
    assert 0 < delays < len(cold.tasks)        # first start per device only


def test_sku_signature_reflects_fleet_composition(tables):
    _, het = _run(tables, "uniform-normal", n=6,
                  fleet=["a100", "h100"])
    sig = het.sku_signature()
    assert sig is not None
    exec_factor, risk = sig
    assert exec_factor < 1.0                   # h100s speed the fleet up
    assert risk == 0.0                         # no spot capacity
    _, spot = _run(tables, "uniform-normal", n=6,
                   fleet=["a100", "a100-spot"])
    exec_factor, risk = spot.sku_signature()
    assert exec_factor == pytest.approx(1.0)
    assert risk > 0.0


def test_plan_cache_keys_fold_sku_signature(tables):
    """Same queue state, different fleet signature -> different plan-
    cache keys (mirrors the calibration keying of PR 7)."""
    sched = ESGScheduler(PAPER_APPS, tables, placement="locality")
    app = PAPER_APPS[APPS[0]]
    stage = app.stages[0]

    class J:
        def __init__(self):
            self.ready_ms = 0.0
            self.inst = type("I", (), {"arrival_ms": 0.0,
                                       "slo_ms": 5_000.0})()

    sim_d = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                       seed=0, count_overhead=False)
    sim_h = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                       seed=0, count_overhead=False,
                       fleet=["a100", "t4-spot"])
    assert ESGScheduler._fleet_sig(sim_d) is None
    sig = ESGScheduler._fleet_sig(sim_h)
    assert sig is not None and sig[0] > 1.0 and sig[1] > 0.0
    # the certified plan signature folds the fleet signature in whenever
    # it certifies at all (None means "must re-plan" and is always safe)
    sig_d = sched.plan_signature(sim_d, app, stage, [J()], 0.0)
    sig_h = sched.plan_signature(sim_h, app, stage, [J()], 0.0)
    assert sig_h is None or sig_d != sig_h
    assert sched.plan(sim_h, app, stage, [J()], 0.0)   # priced plan works
    assert sched._spot_tables                          # memoized transform


# ---------------------------------------------------------------------------
# fault injection: reclamation storms
# ---------------------------------------------------------------------------
def _storm_run(tables, seed=3, n=40, storm_mult=3.0, recorder=None,
               **sim_kw):
    return _run(tables, "spot-storm", n=n, seed=seed, recorder=recorder,
                fleet=["a100", VOLATILE, VOLATILE],
                reclaim_storms=[(0.0, 1e9, storm_mult)], **sim_kw)


def test_storm_kills_running_tasks_and_all_requests_survive(tables):
    tel, sim = _storm_run(tables)
    assert sim.reclaims > 0 and sim.recoveries == sim.reclaims
    assert sim.preemptions > 0 and sim.retries > 0
    assert sim.preempt_lost_ms > 0.0
    # no request lost: every injected arrival completed or was shed
    assert len(sim.completed) + len(sim.shed) == 40
    for t in sim.tasks:
        assert t.penalty_ms <= t.full_penalty_ms + 1e-9


def test_storm_multiplier_accelerates_reclamations(tables):
    _, calm = _storm_run(tables, storm_mult=1.0)
    _, storm = _storm_run(tables, storm_mult=60.0)
    assert storm.reclaims > calm.reclaims


def test_retry_exhaustion_sheds_with_failed_flag(tables):
    rec = Recorder(trace=False, metrics=False)
    tel, sim = _storm_run(tables, max_retries=0,
                          recorder=rec)
    assert sim.preempt_shed > 0
    failed = [i for i in sim.shed if i.failed]
    assert len(failed) == sim.preempt_shed
    sheds = [r for r in rec.audit.retries if r.action == "shed"]
    assert len(sheds) == sim.preempt_shed
    assert all(r.backoff_ms == 0.0 for r in sheds)
    assert len(sim.completed) + len(sim.shed) == 40


def test_checkpointed_stages_resume_instead_of_restarting(tables):
    ck_profiles = {n: FunctionProfile(p.name, p.t1_ms, p.cold_ms,
                                      p.input_mb, p.cpu_frac, p.model_mb,
                                      checkpoint_mb=64.0)
                   for n, p in PAPER_FUNCTIONS.items()}
    ck_tables = {n: ProfileTable.build(p) for n, p in ck_profiles.items()}
    rec = Recorder(trace=False, metrics=False)
    sched = ESGScheduler(PAPER_APPS, ck_tables, placement="locality")
    sim = ClusterSim(PAPER_APPS, ck_tables, ck_profiles, sched,
                     seed=3, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), recorder=rec,
                     fleet=["a100", VOLATILE, VOLATILE],
                     reclaim_storms=[(0.0, 1e9, 3.0)])
    gw = Gateway(sim)
    gw.inject(get_scenario("spot-storm", app_names=APPS), 40, seed=4)
    gw.run()
    assert sim.preemptions > 0
    actions = {r.action for r in rec.audit.retries}
    assert "resume" in actions
    assert len(sim.completed) + len(sim.shed) == 40


def test_retry_audit_records_validate_against_schema(tables):
    rec = Recorder(trace=False, metrics=False)
    _storm_run(tables, recorder=rec)
    assert rec.audit.retries
    records = [json.loads(json.dumps(
        {"type": "retry", **r.__dict__}, default=str))
        for r in rec.audit.retries]
    counts = validate_audit(records, "storm")
    assert counts["retry"] == len(rec.audit.retries)
    for r in rec.audit.retries:
        assert r.attempt >= 1 and r.lost_ms >= 0.0
        assert r.action in ("retry", "resume", "shed")


def test_recorder_captures_preemption_spans_and_metrics(tables):
    rec = Recorder()
    _, sim = _storm_run(tables, recorder=rec)
    events = rec.tracer.events()
    cats = {e.get("cat") for e in events}
    assert "preempt" in cats and "reclaim" in cats
    names = {e["name"] for e in events if e.get("cat") == "reclaim"}
    assert {"reclaim_warning", "reclaim", "recover"} <= names
    assert rec.metrics.total("reclamations") == sim.reclaims
    assert rec.metrics.total("preemptions") == sim.preemptions
    assert rec.metrics.total("preempt_lost_ms") == \
        pytest.approx(sim.preempt_lost_ms)
    assert rec.metrics.total("migrations") == sim.migrations


def test_drain_and_migrate_moves_warm_capacity(tables):
    _, sim = _storm_run(tables)
    assert sim.migrations > 0
    assert sim.gpu_summary()["migrations"] == sim.migrations


def test_reclaimed_invoker_rejects_placements_until_recovery(tables):
    sched = ESGScheduler(PAPER_APPS, tables)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=0,
                     count_overhead=False, fleet=["a100", VOLATILE])
    inv = next(i for i in sim.invokers if i.sku.spot)
    cfg = Config(1, 1, 1)
    func = PAPER_FUNCTIONS["classification"].name
    assert inv.fits(cfg, func, 0.0)
    inv.draining = True
    assert not inv.fits(cfg, func, 0.0)
    inv.draining, inv.down = False, True
    assert not inv.fits(cfg, func, 0.0)
    inv.down = False
    assert inv.fits(cfg, func, 0.0)


# ---------------------------------------------------------------------------
# property-style random walks over reclamation storms
# ---------------------------------------------------------------------------
def _walk_tables():
    if not hasattr(_walk_tables, "_cache"):
        _walk_tables._cache = {n: ProfileTable.build(p)
                               for n, p in PAPER_FUNCTIONS.items()}
    return _walk_tables._cache


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=5, max_value=120),
       st.integers(min_value=0, max_value=3))
def test_property_storm_walk_no_request_lost(seed, storm_mult, max_retries):
    """Whatever the reclamation pressure and retry budget, every admitted
    request must end as completed or shed — never silently dropped — and
    the billing/penalty invariants must hold on every task."""
    tables = _walk_tables()
    tel, sim = _run(tables, "spot-storm", n=20, seed=seed,
                    fleet=["a100", VOLATILE, VOLATILE],
                    reclaim_storms=[(0.0, 1e9, float(storm_mult))],
                    max_retries=max_retries)
    assert len(sim.completed) + len(sim.shed) == 20
    assert sim.recoveries == sim.reclaims
    assert sim.preempt_lost_ms >= 0.0 and sim.total_cost >= 0.0
    for t in sim.tasks:
        assert t.penalty_ms <= t.full_penalty_ms + 1e-9
        assert t.end_ms >= t.exec_start_ms >= t.start_ms
    for inst in sim.completed:
        assert not inst.failed and inst.finish_ms >= inst.arrival_ms


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=10, max_value=200))
def test_property_hbm_ledger_survives_kill_storms(seed, storm_mult):
    """The device HBM/slice ledgers self-check (OversubscribedError) on
    every mutation, so a full storm run under finite HBM is itself the
    assertion; afterwards no device may be over capacity or negative."""
    tables = _walk_tables()
    _, sim = _run(tables, "spot-storm", n=20, seed=seed,
                  hbm_per_vgpu_mb=2_000.0, shared_weights=True,
                  fleet=["a100", VOLATILE, VOLATILE],
                  reclaim_storms=[(0.0, 1e9, float(storm_mult))])
    for inv in sim.invokers:
        dev = inv.device
        dev.check()
        assert 0.0 <= dev.hbm_used_mb <= dev.hbm_total_mb + 1e-9
        assert 0 <= dev.used_slices <= dev.total_slices


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_property_reclaimed_tasks_complete_or_shed_with_audit(seed):
    """Every request touched by a preemption must either finish or be
    shed, and each shed carries a terminal audit record."""
    tables = _walk_tables()
    rec = Recorder(trace=False, metrics=False)
    _, sim = _run(tables, "spot-storm", n=20, seed=seed, recorder=rec,
                  fleet=["a100", VOLATILE, VOLATILE],
                  reclaim_storms=[(0.0, 1e9, 4.0)], max_retries=1)
    done = {i.uid for i in sim.completed}
    shed = {i.uid for i in sim.shed}
    for r in rec.audit.retries:
        assert r.uid in done | shed
        if r.action == "shed":
            assert r.uid in shed
    shed_uids = {r.uid for r in rec.audit.retries if r.action == "shed"}
    assert shed_uids == {i.uid for i in sim.shed if i.failed}


# ---------------------------------------------------------------------------
# planner-pricing oracle: brute force vs ESG_1Q under preemption pricing
# ---------------------------------------------------------------------------
def _tiny_tables(checkpoint_mb=0.0):
    fns = [FunctionProfile("s0", 90.0, 1000.0, 1.0,
                           checkpoint_mb=checkpoint_mb),
           FunctionProfile("s1", 240.0, 1000.0, 1.0,
                           checkpoint_mb=checkpoint_mb),
           FunctionProfile("s2", 55.0, 1000.0, 1.0,
                           checkpoint_mb=checkpoint_mb)]
    return [ProfileTable.build(f, batches=(1, 4), vcpus=(1, 2),
                               vgpus=(1, 2)) for f in fns]


def _expected_cost_tables(tables, exec_factor, risk):
    """Oracle: expected time/cost per config under preemption, computed
    from first principles — T' = T*f plus risk*T'*T' of expected rework,
    cost inflated by the same rework ratio."""
    out = []
    for t in tables:
        stage_risk = risk * (CKPT_LOSS_FRAC if t.fn.checkpoint_mb > 0.0
                             else PREEMPT_LOSS_FRAC)
        base = t.times * exec_factor
        rework = 1.0 + stage_risk * base
        out.append((base * rework, t.job_costs * exec_factor * rework))
    return out


def _brute_force_cheapest(priced, g_slo):
    best = None
    for combo in itertools.product(*[range(len(ts)) for ts, _ in priced]):
        tt = sum(ts[i] for (ts, _), i in zip(priced, combo))
        cc = sum(cs[i] for (_, cs), i in zip(priced, combo))
        if tt < g_slo and (best is None or cc < best):
            best = cc
    return best


@pytest.mark.parametrize("exec_factor,risk", [
    (1.0, 5e-4), (1.7, 0.0), (1.3, 2e-4), (0.8, 1e-3)])
def test_preempt_priced_matches_first_principles_oracle(exec_factor, risk):
    for ckpt in (0.0, 64.0):
        tables = _tiny_tables(ckpt)
        oracle = _expected_cost_tables(tables, exec_factor, risk)
        for t, (times, costs) in zip(tables, oracle):
            stage_risk = risk * (CKPT_LOSS_FRAC if ckpt > 0.0
                                 else PREEMPT_LOSS_FRAC)
            p = t.preempt_priced(exec_factor, stage_risk)
            np.testing.assert_allclose(p.times, times, rtol=1e-12)
            np.testing.assert_allclose(p.job_costs, costs, rtol=1e-12)


@pytest.mark.parametrize("vectorized", [True, False])
@pytest.mark.parametrize("g_slo", [400.0, 900.0, 2_500.0, 10_000.0])
def test_esg_1q_top1_matches_brute_force_under_preemption(vectorized,
                                                          g_slo):
    exec_factor, risk = 1.3, 4e-4
    tables = _tiny_tables()
    priced = [t.preempt_priced(exec_factor, risk * PREEMPT_LOSS_FRAC)
              for t in tables]
    oracle = _expected_cost_tables(tables, exec_factor, risk)
    best = _brute_force_cheapest(oracle, g_slo)
    results = esg_1q(priced, g_slo, k=3, vectorized=vectorized)
    assert results
    top = results[0]
    if best is None:
        # infeasible: the search returns the best-effort fastest path
        assert top.est_time_ms >= g_slo
    else:
        assert top.est_job_cost == pytest.approx(best, rel=1e-9)
        assert top.est_time_ms < g_slo


def test_esg_1q_engines_agree_on_priced_tables():
    tables = [t.preempt_priced(1.5, 3e-4) for t in _tiny_tables()]
    for g_slo in (300.0, 800.0, 2_000.0, 6_000.0):
        vec = esg_1q(tables, g_slo, k=5, vectorized=True)
        leg = esg_1q(tables, g_slo, k=5, vectorized=False)
        assert [(r.configs, r.est_time_ms, r.est_job_cost) for r in vec] \
            == [(r.configs, r.est_time_ms, r.est_job_cost) for r in leg]


# ---------------------------------------------------------------------------
# scenarios, migration policy, gateway coupling
# ---------------------------------------------------------------------------
def test_spot_storm_scenario_registered_and_deterministic():
    sc = get_scenario("spot-storm", app_names=APPS)
    a = sc.arrivals(APPS, 30, seed=5)
    b = get_scenario("spot-storm", app_names=APPS).arrivals(APPS, 30, seed=5)
    assert a == b
    windows = sc.storm_windows(100_000.0)
    assert len(windows) == 2
    for t0, t1, mult in windows:
        assert 0.0 < t0 < t1 < 100_000.0 and mult > 1.0
    fleet = SpotStormScenario.suggested_fleet(9)
    assert len(fleet) == 9
    assert any(resolve_sku(s).spot for s in fleet)
    assert any(not resolve_sku(s).spot for s in fleet)


def test_hetero_mix_scenario_cycles_the_catalogue():
    sc = get_scenario("hetero-mix", app_names=APPS)
    a = sc.arrivals(APPS, 30, seed=5)
    b = get_scenario("hetero-mix", app_names=APPS).arrivals(APPS, 30, seed=5)
    assert a == b
    fleet = HeteroMixScenario.suggested_fleet(10)
    rates = {resolve_sku(s).exec_rate for s in fleet}
    assert len(rates) > 1                       # genuinely heterogeneous
    assert any(resolve_sku(s).spot for s in fleet)


def test_spread_order_prefers_on_demand_under_early_warning(tables):
    sched = ESGScheduler(PAPER_APPS, tables)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=0,
                     count_overhead=False,
                     fleet=["a100-spot", "a100", "a100-spot", "a100"])
    func = "classification"
    default_order = AutoscalerPolicy.spread_order(sim, func)
    sim.prefer_on_demand = True
    alert_order = AutoscalerPolicy.spread_order(sim, func)
    k = sum(1 for i in alert_order if not i.sku.spot)
    assert all(not i.sku.spot for i in alert_order[:k])
    assert all(i.sku.spot for i in alert_order[k:])
    # stable re-sort: relative order within each class is preserved
    assert [i.idx for i in default_order if not i.sku.spot] == \
        [i.idx for i in alert_order[:k]]


def test_gateway_health_warning_steers_placement_off_spot(tables):
    class StubHealth:
        def __init__(self):
            self.warn = False

        def early_warning(self, app=None):
            return self.warn

    sched = ESGScheduler(PAPER_APPS, tables)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=0,
                     count_overhead=False, fleet=["a100", "a100-spot"])
    health = StubHealth()
    gw = Gateway(sim, health=health)
    gw.inject(get_scenario("uniform-normal", app_names=APPS), 4, seed=1)
    sim.run()
    assert sim.prefer_on_demand is False
    health.warn = True
    gw.inject(get_scenario("uniform-normal", app_names=APPS), 4, seed=2)
    sim.run()
    assert sim.prefer_on_demand is True


def test_prefer_on_demand_fleet_avoids_spot_when_possible(tables):
    """With ample on-demand capacity and prefer_on_demand set, no task
    should land on a spot invoker."""
    sched = ESGScheduler(PAPER_APPS, tables, placement="locality")
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=0,
                     count_overhead=False,
                     autoscaler=get_autoscaler("none"),
                     fleet=["a100", "a100", "a100", "a100-spot"])
    sim.prefer_on_demand = True
    spot_idx = {i.idx for i in sim.invokers if i.sku.spot}
    gw = Gateway(sim)
    gw.inject(get_scenario("uniform-normal", app_names=APPS), 12, seed=1)
    gw.run()
    assert sim.tasks
    assert all(t.invoker not in spot_idx for t in sim.tasks)


# ---------------------------------------------------------------------------
# golden fixture: seeded spot-storm outcome is pinned
# ---------------------------------------------------------------------------
def _golden_run():
    tables = {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}
    tel, sim = _run(tables, "spot-storm", n=30, seed=11,
                    fleet=["a100", VOLATILE, VOLATILE],
                    reclaim_storms=[(0.0, 1e9, 40.0)])
    s = tel.summary()
    return {
        "slo_attainment": s["slo_attainment"],
        "cost_per_1k": s["cost_per_1k"],
        "total_cost": s["total_cost"],
        "completed": len(sim.completed),
        "shed": len(sim.shed),
        "gpu": {k: sim.gpu_summary()[k] for k in
                ("reclaim_warnings", "reclamations", "recoveries",
                 "preemptions", "retries", "preempt_shed",
                 "preempt_lost_ms", "migrations")},
    }


def test_spot_storm_golden_fixture():
    got = json.loads(json.dumps(_golden_run()))
    want = json.loads(GOLDEN.read_text())
    assert got == want, (
        "seeded spot-storm outcome drifted from the committed fixture; "
        "if the change is intentional, regenerate "
        "tests/fixtures/golden_spot_storm.json "
        "(python -c 'from tests.test_preemption_fleet import _golden_run; "
        "import json; print(json.dumps(_golden_run(), indent=1))')")
