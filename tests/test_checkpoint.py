"""Checkpoint/restore, restart equivalence, elastic resharding,
gradient compression."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as ckpt
from repro.configs.registry import get_config, reduced
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.model import RunOptions
from repro.optim import adamw
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig

OPTS = RunOptions(attn_chunk=32, remat="none",
                  param_dtype=jnp.float32, act_dtype=jnp.float32)


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}, "step": jnp.asarray(7)}
    ckpt.save(tmp_path, 3, tree)
    out, step = ckpt.restore(tmp_path, tree)
    assert step == 3
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_gc_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, tree, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    _, step = ckpt.restore(tmp_path, tree)
    assert step == 5
    with pytest.raises(Exception):
        ckpt.restore(tmp_path, tree, step=1)


def test_restart_equivalence(tmp_path):
    cfg = reduced(get_config("internlm2_1_8b"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    a = Trainer(cfg, dc, TrainerConfig(steps=12, ckpt_every=4,
                                       ckpt_dir=str(tmp_path / "a"),
                                       log_every=100), OPTS,
                log_fn=lambda *_: None)
    ra = a.run()
    b1 = Trainer(cfg, dc, TrainerConfig(steps=12, ckpt_every=4,
                                        ckpt_dir=str(tmp_path / "b"),
                                        log_every=100, fail_at_step=6),
                 OPTS, log_fn=lambda *_: None)
    with pytest.raises(SimulatedFailure):
        b1.run()
    b2 = Trainer(cfg, dc, TrainerConfig(steps=12, ckpt_every=4,
                                        ckpt_dir=str(tmp_path / "b"),
                                        log_every=100), OPTS,
                 log_fn=lambda *_: None)
    rb = b2.run()
    assert ra["final_loss"] == pytest.approx(rb["final_loss"], abs=1e-6)


def test_data_pipeline_deterministic():
    dc = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=5)
    s1, s2 = TokenStream(dc), TokenStream(dc)
    for step in (0, 3, 17):
        b1, b2 = s1.batch(step), s2.batch(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(s1.batch(0)["tokens"]),
                              np.asarray(s1.batch(1)["tokens"]))


def test_host_slicing_partitions_batch():
    dc = DataConfig(vocab=128, seq_len=8, global_batch=8)
    ts = TokenStream(dc)
    full = np.asarray(ts.batch(2)["tokens"])
    parts = [np.asarray(ts.batch(2, ts.host_slice(i, 4))["tokens"])
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_grad_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 0.01,
                    jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    truth = jnp.zeros_like(g)
    for _ in range(20):
        g_hat, err = adamw.compress_residual(g, err)
        total = total + g_hat
        truth = truth + g
    # error feedback keeps the long-run average unbiased
    rel = float(jnp.abs(total - truth).max() / jnp.abs(truth).max())
    assert rel < 0.02


def test_compressed_training_still_learns(tmp_path):
    cfg = reduced(get_config("internlm2_1_8b"))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    t = Trainer(cfg, dc,
                TrainerConfig(steps=15, ckpt_every=100,
                              ckpt_dir=str(tmp_path / "c"), log_every=100),
                OPTS, opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=2,
                                                total_steps=15,
                                                compress_grads=True),
                log_fn=lambda *_: None)
    r = t.run()
    assert r["losses"][-1] < r["losses"][0]


def test_elastic_reshard(tmp_path):
    """Restore a checkpoint onto a different (smaller) device layout."""
    from repro.runtime import elastic
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(tmp_path, 1, tree)
    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs[:1]).reshape(1, 1),
                             ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    shard = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = ckpt.restore(tmp_path, tree, shardings=shard)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    smaller = elastic.shrink_mesh(mesh, "data", 1)
    moved = elastic.reshard_state(out, {"w": P(None, None)}, smaller)
    np.testing.assert_array_equal(np.asarray(moved["w"]),
                                  np.asarray(tree["w"]))
