"""Differential/property harness for the overlapped swap pipeline.

Locks the PR-4 refactor (restart penalties as an asynchronous PCIe
transfer-engine timeline with predictive prefetch) against the PR-3
additive-scalar model:

  * **differential replay** — every scenario in ``serving.traces`` runs
    with the new kwargs defaulted vs passed explicitly off: the event
    timeline must be bit-identical (the fig6 golden fixture in
    ``test_locality_scheduling`` pins the same path against checked-in
    PR-3 numbers); ``prefetch`` without ``overlap`` is rejected;
  * **monotone improvement** — with overlap on, every task's charged
    restart penalty is bounded by what the additive model would have
    charged (``penalty_ms <= full_penalty_ms``), execution never starts
    before dispatch, and the sim-level penalty ledgers equal the task
    sums;
  * **work conservation** — the transfer engine books every byte of
    every movement exactly once: a prefetch promoted to demand copies
    only the remaining bytes, and ``busy == demand + prefetch`` holds
    mid-walk under random op sequences;
  * **prefetch semantics** — hits/waste accounting, refusal conditions,
    background re-promotions paying honest residuals;
  * **satellites** — ``TraceReplay(speedup=...)`` and the Azure
    invocation-count converter.
"""
import math
import pathlib
import sys

import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.gpu import (COLD, HOT, WARM, DeviceModel, TransferEngine,
                       cold_components, swap_in_ms)
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.traces import SCENARIOS, TraceReplayScenario

APPS = list(PAPER_APPS)
HERE = pathlib.Path(__file__).resolve().parent
HBM_MB = 256.0          # finite HBM: the warm swap tier is exercised
N_REQ = 24              # per-scenario replay length (keeps the suite fast)


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(tables, scenario, n=N_REQ, seed=0, slo_mult=1.0,
         placement="locality", shared=False, hbm=None, **sim_kw):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables, placement=placement),
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"),
                     hbm_per_vgpu_mb=hbm, shared_weights=shared, **sim_kw)
    gw = Gateway(sim)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim


def _timeline(sim):
    """Every observable event of a run, including the new penalty
    fields — if any placement, tier, price, quota or charge differs,
    so does this."""
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices,
              t.penalty_ms, t.full_penalty_ms)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    return tasks, done, sim.total_cost, sim.cold_starts, sim.remote_transfers


# ---------------------------------------------------------------------------
# transfer engine: unit + work-conservation properties
# ---------------------------------------------------------------------------
def test_demand_copy_takes_exactly_its_duration():
    eng = TransferEngine()
    tr = eng.demand("f", 40.0, 100.0)
    assert tr.done_ms == 140.0 and tr.residual_ms(110.0) == 30.0
    assert tr.residual_ms(150.0) == 0.0
    assert eng.busy_ms == eng.demand_ms == 40.0


def test_prefetch_queue_is_fifo_and_pauses_under_demand():
    eng = TransferEngine()
    a = eng.prefetch("a", 30.0, 0.0)
    b = eng.prefetch("b", 20.0, 0.0)
    assert eng.eta(a, 0.0) == 30.0 and eng.eta(b, 0.0) == 50.0
    # a demand copy at t=10 blocks the link until t=50: the 10ms of `a`
    # already copied stay done, the rest resumes after
    eng.demand("c", 40.0, 10.0)
    assert eng.eta(a, 10.0) == pytest.approx(70.0)    # 20ms left after t=50
    assert eng.eta(b, 10.0) == pytest.approx(90.0)
    eng._advance(200.0)
    assert not eng.queue and a.done_ms == pytest.approx(70.0)
    assert b.done_ms == pytest.approx(90.0)
    assert eng.busy_ms == pytest.approx(30.0 + 20.0 + 40.0)
    assert eng.prefetch_ms == pytest.approx(50.0)


def test_promote_books_only_remaining_bytes():
    eng = TransferEngine()
    tr = eng.prefetch("f", 50.0, 0.0)
    eng.promote(tr, 30.0)             # 30ms already landed in background
    assert tr.done_ms == pytest.approx(50.0)
    assert eng.prefetch_ms == pytest.approx(30.0)
    assert eng.demand_ms == pytest.approx(20.0)
    assert eng.busy_ms == pytest.approx(50.0)   # one movement, booked once
    eng.check()


def test_cancel_keeps_only_performed_work():
    eng = TransferEngine()
    tr = eng.prefetch("f", 50.0, 0.0)
    eng._advance(15.0)
    eng.cancel(tr)
    assert eng.busy_ms == pytest.approx(15.0)
    assert not eng.queue and math.isinf(tr.done_ms)
    eng.check()


def test_engine_random_walk_is_work_conserving():
    rng = np.random.default_rng(5)
    eng = TransferEngine()
    now, live = 0.0, []
    for _ in range(500):
        now += float(rng.uniform(0.0, 30.0))
        op = int(rng.integers(4))
        if op == 0:
            eng.demand(f"d{_}", float(rng.uniform(1.0, 60.0)), now)
        elif op == 1:
            live.append(eng.prefetch(f"p{_}", float(rng.uniform(1.0, 60.0)),
                                     now))
        elif op == 2 and live:
            tr = live.pop(int(rng.integers(len(live))))
            if tr in eng.queue:
                done = eng.promote(tr, now).done_ms
                # done < now is fine (the copy drained in background
                # before the promote); it can never exceed a fresh
                # demand copy of the full movement
                assert done <= now + tr.total_ms + 1e-9
        elif op == 3 and live:
            eng.cancel(live.pop(int(rng.integers(len(live)))))
        eng.check()
        eng._advance(now)                 # settle completions before probing
        for tr in list(eng.queue):        # eta() itself advances lazily
            assert eng.eta(tr, now) >= now - 1e-9
    eng._advance(now + 1e6)
    eng.check()
    assert not eng.queue


# ---------------------------------------------------------------------------
# device model: overlap-mode start timelines + prefetch semantics
# ---------------------------------------------------------------------------
def _dev(shared, hbm=450.0, vgpus=2):
    # 900 MB total: f(600) + g(900-capped) cannot coexist, so starting
    # ``g`` demotes ``f`` — the WARM state the overlap tests need
    return DeviceModel(vgpus=vgpus, hbm_per_vgpu_mb=hbm,
                       shared_weights=shared, overlap=True)


def _demoted_f(shared, f_expiry=1e6):
    """Device where ``f``'s 600-MB weights sit demoted (WARM tier) and
    the HBM is free again: start f, park it, squeeze it out with g,
    then let g's keep-alive expire."""
    dev = _dev(shared)
    a, _ = dev.start("f", 1, 600.0, 0.0)
    dev.stop(a.aid, f_expiry)
    ag, _ = dev.start("g", 1, 400.0, 1.0)     # pressure: f demoted
    assert dev.residency("f", 1.0) == WARM
    dev.stop(ag.aid, 2.0)
    dev._gc(3.0)                              # g's keep-alive expires
    return dev


@pytest.mark.parametrize("shared", [False, True])
def test_warm_start_returns_completion_time(shared):
    dev = _demoted_f(shared)
    a2, tier = dev.start("f", 1, 600.0, 4.0)
    assert tier == WARM
    assert a2.ready_ms == pytest.approx(4.0 + swap_in_ms(600.0))
    assert a2.full_penalty_ms == pytest.approx(swap_in_ms(600.0))


@pytest.mark.parametrize("shared", [False, True])
def test_cold_start_overlaps_provisioning_with_weight_copy(shared):
    dev = _dev(shared)
    a, tier = dev.start("f", 1, 600.0, 10.0, cold_ms=5000.0)
    prov, w = cold_components(600.0, 5000.0)
    assert tier == COLD
    assert a.ready_ms == pytest.approx(10.0 + max(prov, w))
    assert a.full_penalty_ms == pytest.approx(5000.0)   # prov + w
    assert dev.engine.demand_ms == pytest.approx(w)


@pytest.mark.parametrize("shared", [False, True])
def test_prefetch_hides_swap_and_counts_hit(shared):
    dev = _demoted_f(shared)
    assert dev.prefetch("f", 600.0, 4.0)
    assert dev.residency("f", 4.0) == HOT         # promoted, copy in flight
    w = swap_in_ms(600.0)
    # start long after the copy landed: charged residual is zero
    a2, tier = dev.start("f", 1, 600.0, 4.0 + w + 50.0)
    assert tier == HOT and a2.ready_ms == pytest.approx(4.0 + w + 50.0)
    assert a2.full_penalty_ms == pytest.approx(w)  # additive would pay swap
    assert dev.stats.prefetch_issued == 1 and dev.stats.prefetch_hits == 1


@pytest.mark.parametrize("shared", [False, True])
def test_prefetch_hit_mid_flight_pays_only_residual(shared):
    # t=50: past the setup cold starts' demand copies, so the link is
    # idle and the prefetch starts copying immediately
    dev = _demoted_f(shared)
    dev.prefetch("f", 600.0, 50.0)
    w = swap_in_ms(600.0)
    t_hit = 50.0 + w / 2.0
    a2, tier = dev.start("f", 1, 600.0, t_hit)
    assert tier == HOT
    residual = a2.ready_ms - t_hit
    assert 0.0 < residual < w
    assert residual == pytest.approx(w / 2.0)
    assert a2.full_penalty_ms == pytest.approx(w)
    assert dev.stats.prefetch_hits == 1


@pytest.mark.parametrize("shared", [False, True])
def test_prefetch_wasted_on_demotion_and_expiry(shared):
    dev = _demoted_f(shared, f_expiry=100.0)      # f expires at t=100
    assert dev.prefetch("f", 600.0, 4.0)
    dev._gc(200.0)                                # f's container expired
    assert dev.stats.prefetch_wasted == 1
    assert dev.stats.prefetch_hits == 0
    dev.engine.check()                            # cancelled, not re-booked


def test_prefetch_refusals():
    dev = _dev(False)
    assert not dev.prefetch("f", 600.0, 0.0)      # nothing staged: COLD
    a, _ = dev.start("f", 1, 600.0, 0.0)
    dev.stop(a.aid, 1e6)
    assert not dev.prefetch("f", 600.0, 1.0)      # already HOT
    # overlap off: never
    legacy = DeviceModel(vgpus=2, hbm_per_vgpu_mb=900.0)
    legacy.add_warm("f", 1e6, 600.0, 0.0)
    assert not legacy.prefetch("f", 600.0, 1.0)
    # no free HBM: a guess never demotes somebody else's weights
    dev2 = _dev(False, hbm=300.0, vgpus=2)
    a2, _ = dev2.start("f", 1, 600.0, 0.0)
    dev2.stop(a2.aid, 1e6)
    dev2.start("g", 1, 600.0, 1.0)                # demotes f, fills HBM
    assert dev2.residency("f", 1.0) == WARM
    assert not dev2.prefetch("f", 600.0, 2.0)


def test_shared_add_warm_repromotion_pays_honest_residual():
    """Legacy mode re-promotes a demoted shared set for free; overlap
    mode puts the copy on the engine — a start arriving before the
    bytes land pays the residual, one arriving after pays nothing."""
    dev = _demoted_f(True)
    dev.add_warm("f", 1e6, 600.0, 50.0)           # prewarm re-loads f
    assert dev.residency("f", 50.0) == HOT        # (link idle by t=50)
    w = swap_in_ms(600.0)
    a2, tier = dev.start("f", 1, 600.0, 50.0 + w / 4.0)
    assert tier == HOT
    assert a2.ready_ms - (50.0 + w / 4.0) == pytest.approx(0.75 * w)
    assert a2.full_penalty_ms == pytest.approx(w)
    # but it was never a *predictive* prefetch: no hit/issue accounting
    assert dev.stats.prefetch_issued == 0 and dev.stats.prefetch_hits == 0


FUNCS = [("a", 300.0), ("b", 700.0), ("c", 150.0), ("d", 0.0)]


@pytest.mark.parametrize("shared", [False, True])
def test_overlap_device_random_walk_invariants(shared):
    """500 random start/stop/prefetch/prewarm/retire/gc steps through
    the public API with the transfer engine in the loop: ledgers and
    engine stay consistent, every start's timeline obeys
    ``now <= ready`` and ``ready - now <= full`` (monotone improvement
    over the additive model)."""
    rng = np.random.default_rng(13)
    dev = DeviceModel(vgpus=4, hbm_per_vgpu_mb=256.0, shared_weights=shared,
                      overlap=True)
    now, live = 0.0, []
    for step in range(500):
        now += float(rng.uniform(0.0, 50.0))
        op = int(rng.integers(7))
        f, mb = FUNCS[int(rng.integers(len(FUNCS)))]
        if op == 0:
            sl = int(rng.integers(1, 9))
            if dev.fits(sl, mb, f, now):
                alloc, tier = dev.start(f, sl, mb, now,
                                        cold_ms=float(rng.uniform(0, 3000)))
                assert tier in (HOT, WARM, COLD)
                assert alloc.ready_ms >= now - 1e-9
                assert alloc.ready_ms - now <= alloc.full_penalty_ms + 1e-9
                live.append(alloc)
        elif op == 1 and live:
            a = live[int(rng.integers(len(live)))]
            dev.resize(a.aid, int(rng.integers(1, 17)))
        elif op == 2 and live:
            a = live.pop(int(rng.integers(len(live))))
            dev.stop(a.aid, now + float(rng.uniform(100.0, 5000.0)))
        elif op == 3:
            dev.add_warm(f, now + float(rng.uniform(100.0, 5000.0)), mb, now)
        elif op == 4:
            dev.prefetch(f, mb, now)
        elif op == 5:
            entries = dev.warm_entries(f, now)
            if entries:
                dev.retire(f, entries[int(rng.integers(len(entries)))])
        else:
            dev._gc(now)
        dev.check()                       # includes engine work conservation
    for a in live:
        dev.stop(a.aid, now + 100.0)
    dev._gc(now + 1e9)
    assert dev.used_slices == 0 and dev.hbm_used_mb == 0.0
    assert not dev.engine.queue           # no orphaned background copies


# ---------------------------------------------------------------------------
# differential replay: legacy configurations cannot drift
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_overlap_off_is_bit_identical_to_legacy(scenario, tables):
    """(a) ``overlap=False, prefetch=False`` passed explicitly must
    replay the exact event timeline of a run that never mentions the
    new kwargs — across every serving scenario (the fig6 mmpp golden
    fixture in test_locality_scheduling pins this same path against
    checked-in PR-3 numbers)."""
    tel_d, sim_d = _run(tables, scenario, hbm=HBM_MB)
    tel_e, sim_e = _run(tables, scenario, hbm=HBM_MB,
                        overlap=False, prefetch=False)
    assert _timeline(sim_d) == _timeline(sim_e)
    assert tel_d.summary() == tel_e.summary()
    # additive accounting: charged penalty IS the full penalty
    assert all(t.penalty_ms == t.full_penalty_ms for t in sim_d.tasks)


def test_prefetch_requires_overlap(tables):
    with pytest.raises(ValueError, match="prefetch.*overlap"):
        _run(tables, "mmpp", n=1, overlap=False, prefetch=True)


# ---------------------------------------------------------------------------
# overlap on: monotone improvement + consistent accounting, all scenarios
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_overlap_never_increases_per_task_latency(scenario, tables):
    """(b) With the transfer engine in the loop, no task is ever
    charged more than the additive model would have charged it, the
    sim-level penalty ledgers equal the task sums, and no device's
    PCIe time is double-booked."""
    _, sim = _run(tables, scenario, placement="memory", shared=True,
                  hbm=HBM_MB, overlap=True, prefetch=True)
    for t in sim.tasks:
        assert t.penalty_ms >= -1e-9
        assert t.penalty_ms <= t.full_penalty_ms + 1e-9, \
            f"task {t.tid} ({t.tier}) charged {t.penalty_ms} > " \
            f"additive {t.full_penalty_ms}"
        assert t.exec_start_ms >= t.start_ms - 1e-9
    g = sim.gpu_summary()
    assert g["penalty_charged_ms"] == \
        pytest.approx(sum(t.penalty_ms for t in sim.tasks))
    assert g["penalty_full_ms"] == \
        pytest.approx(sum(t.full_penalty_ms for t in sim.tasks))
    assert g["penalty_hidden_ms"] >= -1e-9
    for inv in sim.invokers:
        inv.device.engine.check()         # busy == demand + prefetch


def test_overlap_with_prefetch_hides_warm_penalty(tables):
    """The tentpole's point, pinned on one bursty scenario under real
    memory pressure: warm restarts are charged strictly less than the
    additive swap_in_ms model, some of it thanks to scored prefetch
    hits, and telemetry surfaces the hit rate."""
    tel_a, sim_a = _run(tables, "mmpp", n=40, placement="memory",
                        shared=True, hbm=128.0)
    tel_o, sim_o = _run(tables, "mmpp", n=40, placement="memory",
                        shared=True, hbm=128.0, overlap=True, prefetch=True)
    ga, go = sim_a.gpu_summary(), sim_o.gpu_summary()
    assert ga["swap_ins"] > 0, "baseline not under pressure"
    assert ga["penalty_hidden_ms"] == 0.0
    assert go["penalty_hidden_ms"] > 0.0
    assert go["prefetch_issued"] > 0 and go["prefetch_hits"] > 0
    warm = [t for t in sim_o.tasks
            if t.tier == WARM or (t.tier == HOT and t.full_penalty_ms > 0)]
    assert warm and sum(t.penalty_ms for t in warm) < \
        sum(t.full_penalty_ms for t in warm) - 1e-9
    s = tel_o.summary()
    assert 0.0 < s["prefetch_hit_rate"] <= 1.0
    assert 0.0 < s["penalty_hidden_frac"] <= 1.0
    assert tel_a.summary()["prefetch_hit_rate"] is None


def test_overlap_run_is_deterministic(tables):
    tel1, _ = _run(tables, "flash-crowd", placement="memory", shared=True,
                   hbm=HBM_MB, overlap=True, prefetch=True)
    tel2, _ = _run(tables, "flash-crowd", placement="memory", shared=True,
                   hbm=HBM_MB, overlap=True, prefetch=True)
    assert tel1.summary() == tel2.summary()


# ---------------------------------------------------------------------------
# satellites: TraceReplay speedup + Azure converter
# ---------------------------------------------------------------------------
def test_trace_replay_speedup_compresses_time():
    rows = [(1000.0, "a"), (3000.0, "b"), (5000.0, "a")]
    base = TraceReplayScenario(rows=rows).arrivals(["a", "b"], 3)
    fast = TraceReplayScenario(rows=rows, speedup=10.0).arrivals(["a", "b"], 3)
    for b, f in zip(base, fast):
        assert f.t_ms == pytest.approx(b.t_ms / 10.0)
        assert f.app == b.app
    # composes with time_scale (which stretches)
    both = TraceReplayScenario(rows=rows, time_scale=2.0,
                               speedup=4.0).arrivals(["a", "b"], 3)
    assert both[-1].t_ms == pytest.approx(5000.0 * 2.0 / 4.0)


@pytest.mark.parametrize("bad", [0.0, -1.0, float("nan")])
def test_trace_replay_speedup_validation(bad):
    with pytest.raises(ValueError, match="speedup must be > 0"):
        TraceReplayScenario(rows=[(1.0, "a")], speedup=bad)


def _convert_azure():
    sys.path.insert(0, str(HERE.parent / "benchmarks" / "traces"))
    try:
        import convert_azure
    finally:
        sys.path.pop(0)
    return convert_azure


AZURE_FIXTURE = HERE / "fixtures" / "azure_2019_3min_sample.csv"


def test_convert_azure_fixture_roundtrip(tmp_path):
    ca = _convert_azure()
    counts = ca.load_counts(str(AZURE_FIXTURE))
    assert len(counts) == 5
    assert counts["f0e1d2c3b4a59687"] == [4, 9, 2]
    rows = ca.convert(counts, seed=0)
    assert len(rows) == sum(sum(c) for c in counts.values())
    # arrivals stay inside their minute and come out time-sorted
    assert rows == sorted(rows, key=lambda r: (r[0], r[1]))
    assert all(0.0 <= t < 3 * 60_000.0 for t, _ in rows)
    # same seed => identical trace; different seed => different jitter
    assert rows == ca.convert(counts, seed=0)
    assert rows != ca.convert(counts, seed=1)
    # the written CSV replays through the scenario engine
    out = tmp_path / "azure_trace.csv"
    ca.write_trace(rows, str(out))
    parsed = TraceReplayScenario.read_csv(str(out))
    assert len(parsed) == len(rows)
    sc = TraceReplayScenario(csv_path=str(out), speedup=100.0)
    arr = sc.arrivals(APPS, 10, seed=0)
    assert len(arr) == 10 and all(a.app in APPS for a in arr)


def test_convert_azure_apps_minutes_scale():
    ca = _convert_azure()
    counts = ca.load_counts(str(AZURE_FIXTURE))
    # --apps keeps the busiest N (f0e1... has 15, 09f8/cafebabe 9/12)
    top2 = ca.convert(counts, apps=2, seed=0)
    assert {a for _, a in top2} == {"f0e1d2c3b4a59687", "cafebabe44556677"}
    # --minutes truncates the horizon
    two_min = ca.convert(counts, minutes=2, seed=0)
    assert all(t < 2 * 60_000.0 for t, _ in two_min)
    assert len(two_min) == sum(sum(c[:2]) for c in counts.values())
    # integer scale multiplies counts exactly
    double = ca.convert(counts, scale=2.0, seed=0)
    assert len(double) == 2 * sum(sum(c) for c in counts.values())
    with pytest.raises(ValueError, match="scale must be > 0"):
        ca.convert(counts, scale=0.0)


def test_convert_azure_cli(tmp_path, capsys):
    ca = _convert_azure()
    out = tmp_path / "t.csv"
    assert ca.main([str(AZURE_FIXTURE), "--apps", "3", "--minutes", "3",
                    "--scale", "1.0", "--seed", "7",
                    "--out", str(out)]) == 0
    assert "[convert-azure]" in capsys.readouterr().out
    rows = TraceReplayScenario.read_csv(str(out))
    assert rows and len({a for _, a in rows}) == 3


def test_convert_azure_rejects_bad_schema(tmp_path):
    ca = _convert_azure()
    p = tmp_path / "bad.csv"
    p.write_text("time,function\n1,f\n")
    with pytest.raises(ValueError, match="invocation-count CSV"):
        ca.load_counts(str(p))
