"""ESG_1Q: exact K-best agreement with brute force (the paper's claim that
dual-blade pruning does not compromise quality), via hypothesis."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # optional dep: fall back to the
    from _hypothesis_fallback import (   # vendored deterministic sampler
        given, settings, strategies as st)

from repro.core.astar import PathResult, SearchStats, brute_force, esg_1q
from repro.core.profiles import Config, FunctionProfile, ProfileTable


def tiny_table(seed: int, name: str = "f") -> ProfileTable:
    rng = np.random.default_rng(seed)
    fp = FunctionProfile(name, float(rng.uniform(50, 1000)), 1000.0, 1.0,
                         float(rng.uniform(0.1, 0.5)))
    return ProfileTable.build(fp, batches=(1, 2, 4, 8), vcpus=(1, 2, 4),
                              vgpus=(1, 2, 4))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4), st.floats(1.05, 8.0), st.integers(0, 10_000),
       st.integers(1, 7))
def test_astar_matches_brute_force(n_stages, slo_mult, seed, k):
    tables = [tiny_table(seed + i, f"f{i}") for i in range(n_stages)]
    g_slo = sum(t.min_time for t in tables) * slo_mult
    res = esg_1q(tables, g_slo, k=k)
    ref = brute_force(tables, g_slo, k=k)
    assert len(res) == len(ref)
    for a, b in zip(res, ref):
        assert a.est_job_cost == pytest.approx(b.est_job_cost, abs=1e-12)
        assert a.est_time_ms < g_slo


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_astar_pareto_preserves_top1(seed):
    tables = [tiny_table(seed + i) for i in range(3)]
    g_slo = sum(t.min_time for t in tables) * 2.0
    full = esg_1q(tables, g_slo, k=1)
    pareto = esg_1q([t.pareto() for t in tables], g_slo, k=1)
    assert full[0].est_job_cost == pytest.approx(
        pareto[0].est_job_cost, rel=1e-9)


def test_infeasible_returns_fastest_path():
    tables = [tiny_table(1), tiny_table(2)]
    res = esg_1q(tables, g_slo_ms=1e-3, k=5)
    assert len(res) == 1
    fastest = sum(t.min_time for t in tables)
    assert res[0].est_time_ms == pytest.approx(fastest)


def test_pruning_actually_prunes():
    tables = [tiny_table(i) for i in range(3)]
    g_slo = sum(t.min_time for t in tables) * 1.5
    stats = SearchStats()
    esg_1q(tables, g_slo, k=5, stats=stats)
    n_total = np.prod([len(t.configs) for t in tables])
    assert stats.nodes_pushed < n_total / 3
    assert stats.pruned_time + stats.pruned_cost > 0


def test_sorted_by_cost_and_feasible():
    tables = [tiny_table(i + 50) for i in range(3)]
    g_slo = sum(t.min_time for t in tables) * 3.0
    res = esg_1q(tables, g_slo, k=8)
    costs = [r.est_job_cost for r in res]
    assert costs == sorted(costs)
    assert all(r.est_time_ms < g_slo for r in res)
