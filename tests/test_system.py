"""End-to-end behaviour tests: the paper's qualitative claims hold in the
reproduction (see EXPERIMENTS.md for the quantitative record)."""
import pytest

from repro.cluster.emulator import ClusterSim
from repro.cluster.workload import generate, min_config_latency
from repro.core.profiles import Config, PAPER_FUNCTIONS, ProfileTable
from repro.core.workflows import PAPER_APPS
from repro.core.scheduler import ESGScheduler
from repro.core.baselines.aquatope import AquatopeScheduler
from repro.core.baselines.orion import OrionScheduler


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def _run(sched, tables, setting, n=100, seed=0, **kw):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched, seed=seed,
                     **kw)
    generate(sim, setting, n, PAPER_FUNCTIONS, seed=seed + 1)
    sim.run()
    return sim


def test_L_matches_table3_sums(tables):
    # image_classification L = SR + seg + cls at min config
    app = PAPER_APPS["image_classification"]
    L = min_config_latency(app, PAPER_FUNCTIONS)
    parts = sum(PAPER_FUNCTIONS[f].exec_ms(Config(1, 1, 1))
                for f in ["super_resolution", "segmentation",
                          "classification"])
    assert L == pytest.approx(parts)


def test_esg_latency_below_but_close_to_slo(tables):
    """Fig 7's qualitative claim, relaxed-heavy."""
    sim = _run(ESGScheduler(PAPER_APPS, tables), tables, "relaxed-heavy")
    lats = [(i.finish_ms - i.arrival_ms) / i.slo_ms for i in sim.completed]
    med = sorted(lats)[len(lats) // 2]
    assert 0.4 < med <= 1.0


def test_esg_scheduling_overhead_small(tables):
    """Fig 10: avg search overhead < 10ms (paper)."""
    sim = _run(ESGScheduler(PAPER_APPS, tables), tables, "moderate-normal")
    s = sim.summary()
    assert s["mean_sched_overhead_ms"] < 25.0


def test_static_planners_miss_configs(tables):
    """Table 4: Aquatope's offline plans miss when queues are shorter than
    the planned batch."""
    sim = _run(AquatopeScheduler(PAPER_APPS, tables), tables, "strict-light")
    assert sim.plan_uses > 0
    assert sim.config_misses / sim.plan_uses > 0.3


def test_prewarming_eliminates_most_cold_starts(tables):
    warm = _run(ESGScheduler(PAPER_APPS, tables), tables, "moderate-normal")
    cold = _run(ESGScheduler(PAPER_APPS, tables), tables, "moderate-normal",
                prewarm=False)
    assert warm.cold_starts <= cold.cold_starts
    assert warm.slo_hit_rate() >= cold.slo_hit_rate()


def test_adaptivity_beats_static_plan(tables):
    """ESG re-plans every stage; Orion plans once — under the dynamic
    moderate-normal setting ESG's hit rate must win."""
    esg = _run(ESGScheduler(PAPER_APPS, tables), tables, "moderate-normal")
    orion = _run(OrionScheduler(PAPER_APPS, tables), tables,
                 "moderate-normal")
    assert esg.slo_hit_rate() > orion.slo_hit_rate()
