"""Real-compute bridge: ops-level kernel parity, measured profiles,
compile-cache invariants, and emulator bit-identity with the executor
attached.

Kernel tests run the *ops-layer* wrappers (the exact entry points the
serving executor and the model use, jit + layout adapters + CPU
interpret fallback included) against the jnp references — the
kernel-layer parity lives in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ATOL = 2e-5          # float32 interpret mode: numerically tight
RTOL = 2e-5
WKV_TOL = 5e-3       # chunked scan reassociates the state recurrence


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ---- ops-level parity: flash_attention ------------------------------------

@pytest.mark.parametrize("kw", [
    {"causal": True},
    {"causal": True, "window": 16},
    {"causal": True, "local_block": 8},
])
def test_flash_attention_ops_parity(kw):
    from repro.kernels.flash_attention.ops import (flash_attention,
                                                   flash_attention_oracle)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, kvh, d = 2, 64, 4, 2, 16
    q = _rand(ks[0], (b, s, h, d))
    k = _rand(ks[1], (b, s, kvh, d))
    v = _rand(ks[2], (b, s, kvh, d))
    out = flash_attention(q, k, v, **kw)
    ref = flash_attention_oracle(q, k, v, **kw)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


# ---- ops-level parity: flash_decode (dynamic t) ---------------------------

@pytest.mark.parametrize("kw,t", [
    ({}, 17),                                  # linear cache, mid-fill
    ({}, 63),                                  # linear cache, last slot
    ({"window": 16}, 40),                      # sliding-window ring
    ({"local_block": 8}, 29),                  # chunked-local ring
])
def test_flash_decode_at_ops_parity(kw, t):
    from repro.kernels.flash_decode.ops import flash_decode_at
    from repro.kernels.flash_decode.ref import decode_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, h, kvh, d = 2, 64, 4, 2, 16
    q = _rand(ks[0], (b, h, d))
    kc = _rand(ks[1], (b, s, kvh, d))
    vc = _rand(ks[2], (b, s, kvh, d))
    out = flash_decode_at(q, kc, vc, t, **kw)
    ref = decode_ref(q, kc, vc, t=t, **kw)
    np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)


def test_flash_decode_at_one_executable_for_all_t():
    """The point of scalar prefetch: every position t reuses ONE jit
    cache entry — a static t would compile per token and break the
    executor's zero-recompile invariant."""
    from repro.kernels.flash_decode.ops import flash_decode_at
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, kvh, d = 1, 32, 2, 1, 8
    q = _rand(ks[0], (b, h, d))
    kc = _rand(ks[1], (b, s, kvh, d))
    vc = _rand(ks[2], (b, s, kvh, d))
    flash_decode_at(q, kc, vc, 0)              # prime the jit cache
    before = flash_decode_at._cache_size()
    for t in (1, 7, 31):
        flash_decode_at(q, kc, vc, t)
    assert flash_decode_at._cache_size() == before


# ---- ops-level parity: rwkv6 wkv6 -----------------------------------------

def test_wkv6_ops_parity():
    from repro.kernels.rwkv6.ops import wkv6
    from repro.kernels.rwkv6.ref import wkv6_ref
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, t, h, k = 2, 48, 2, 8                   # t=48: exercises padding
    r = _rand(ks[0], (b, t, h, k))
    kk = _rand(ks[1], (b, t, h, k))
    v = _rand(ks[2], (b, t, h, k))
    lw = -jnp.exp(_rand(ks[3], (b, t, h, k)))  # log-decay < 0
    u = _rand(ks[4], (h, k))
    s0 = jnp.zeros((b, h, k, k), jnp.float32)
    y, s_fin = wkv6(r, kk, v, lw, u, s0)
    yr, sr = wkv6_ref(jnp.moveaxis(r, 1, 2), jnp.moveaxis(kk, 1, 2),
                      jnp.moveaxis(v, 1, 2), jnp.moveaxis(lw, 1, 2),
                      u, s0)
    np.testing.assert_allclose(y, jnp.moveaxis(yr, 1, 2),
                               atol=WKV_TOL, rtol=WKV_TOL)
    np.testing.assert_allclose(s_fin, sr, atol=WKV_TOL, rtol=WKV_TOL)


# ---- measured profiles ----------------------------------------------------

def _artifact():
    return {
        "schema": "repro.measured_profile.v1",
        "arch": "toy",
        "backend": "cpu", "interpret": True,
        "prompt_len": 8, "gen_len": 2,
        "batch_lattice": [1, 2, 4], "quota_lattice": [1.0, 0.5],
        "cells": [
            {"batch": 1, "quota": 1.0, "e2e_ms": 10.0,
             "prefill_ms": 4.0, "decode_ms": 6.0, "reps": 3},
            {"batch": 2, "quota": 1.0, "e2e_ms": 14.0,
             "prefill_ms": 6.0, "decode_ms": 8.0, "reps": 3},
            {"batch": 4, "quota": 1.0, "e2e_ms": 22.0,
             "prefill_ms": 10.0, "decode_ms": 12.0, "reps": 3},
            {"batch": 2, "quota": 0.5, "e2e_ms": 26.0,
             "prefill_ms": 11.0, "decode_ms": 15.0, "reps": 3},
        ],
        "cold_ms": 100.0, "input_mb": 0.02,
    }


def test_measured_profile_lattice_lookup():
    from repro.core.profiles import Config, ProfileTable
    t = ProfileTable.from_measured(_artifact())
    assert t.fn.provenance == "measured"
    assert t.batch_lattice == (1, 2, 4)
    assert t.fn.cold_ms == 100.0
    # exact lattice cells
    assert t.fn.exec_ms(Config(1, 1, 1)) == 10.0
    assert t.fn.exec_ms(Config(4, 1, 1)) == 22.0
    # off-lattice batch rounds UP to the covering bucket
    assert t.fn.exec_ms(Config(3, 1, 1)) == 22.0
    # beyond the lattice: waves of the largest bucket
    assert t.fn.exec_ms(Config(8, 1, 1)) == 44.0
    # measured fractional-quota cell wins over the power-law model
    assert t.fn.exec_ms(Config(2, 1, 1), quota_vgpu=0.5) == 26.0
    # unmeasured quota falls back to the power law on the bucket base
    model = 10.0 * t.fn.quota_factor(Config(1, 1, 1), 0.5)
    assert t.fn.exec_ms(Config(1, 1, 1), quota_vgpu=0.5) == \
        pytest.approx(model)


def test_measured_profile_requires_full_quota_cells():
    from repro.core.profiles import ProfileTable
    art = _artifact()
    art["cells"] = [c for c in art["cells"] if c["quota"] != 1.0]
    with pytest.raises(ValueError):
        ProfileTable.from_measured(art)


def test_zoo_profiles_report_zoo_provenance():
    from repro.cluster.tpu_profiles import zoo_tables
    t = next(iter(zoo_tables().values()))
    assert getattr(t.fn, "provenance", "zoo") == "zoo"


# ---- executor compile cache ----------------------------------------------

@pytest.fixture(scope="module")
def executor():
    from repro.serving.executor import RealExecutor
    ex = RealExecutor("internlm2_1_8b", batch_lattice=(1, 2),
                      quotas=(1.0, 0.5), prompt_len=8, gen_len=2, seed=0)
    ex.warmup()
    yield ex
    ex.shutdown()


class _FakeTask:
    _next = iter(range(10_000))

    def __init__(self, n_jobs, slices=4):
        from repro.core.profiles import Config
        self.tid = next(self._next)
        self.func = "internlm2_1_8b"
        self.stage = "0:internlm2_1_8b"
        self.jobs = [None] * n_jobs
        self.config = Config(n_jobs, 1, 1)
        self.quota_slices = slices


def test_executor_zero_recompiles_after_warmup(executor):
    compiles_before = executor.compiles
    for n, slices in [(1, 4), (2, 4), (2, 2), (1, 2), (2, 4), (1, 4)]:
        executor.submit(_FakeTask(n, slices))
    executor.drain()
    assert executor.compiles == compiles_before      # zero new XLA compiles
    assert executor.cache_misses == 0
    assert executor.stats()["post_warmup_hit_rate"] == 1.0


def test_executor_bucketing_and_quota_snap(executor):
    assert executor.bucket_of(1) == 1
    assert executor.bucket_of(2) == 2
    assert executor.bucket_of(3) == 2                # clamps to max bucket
    assert executor.quota_of(_FakeTask(1, slices=4)) == 1.0
    assert executor.quota_of(_FakeTask(1, slices=2)) == 0.5
    assert executor.quota_of(_FakeTask(1, slices=3)) == 1.0  # nearest


def test_executor_quota_is_serialized_passes(executor):
    full = executor.measure(1, 1.0, reps=3)
    half = executor.measure(1, 0.5, reps=3)
    # the half-quota cell runs 2 serialized passes: strictly slower,
    # loosely ~2x (wall-clock noise precludes a tight bound)
    assert half.wall_ms > full.wall_ms * 1.2


# ---- emulator coupling ----------------------------------------------------

def test_sim_digest_unchanged_by_attached_executor(executor):
    """Attaching the real executor must not perturb simulated time: the
    digest with the bridge on equals the digest with it off (defaults-
    off paths replay bit-identically)."""
    import json

    from repro.cluster.emulator import ClusterSim
    from repro.core.profiles import ProfileTable
    from repro.core.scheduler import ESGScheduler
    from repro.core.workflows import Workflow
    from repro.launch.profile_kernels import build_artifact
    from repro.serving import Gateway, get_scenario

    art = build_artifact(executor, reps=1, log=lambda *_: None)
    assert art["schema"] == "repro.measured_profile.v1"
    json.dumps(art)                                  # JSON-serializable

    arch = executor.arch
    digests = []
    for ex in (None, executor):
        table = ProfileTable.from_measured(art)
        apps = {arch: Workflow.pipeline(arch, [arch])}
        sched = ESGScheduler(apps, {arch: table}, risk_sigma=0.05)
        # count_overhead=False: with it on, wall-clock planning time
        # enters simulated time and no two runs digest identically
        sim = ClusterSim(apps, {arch: table}, {arch: table.fn}, sched,
                         n_invokers=1, vcpus=8, vgpus=1,
                         noise_sigma=0.0, seed=0, count_overhead=False,
                         track_digest=True, executor=ex)
        gw = Gateway(sim)
        gw.inject(get_scenario("mmpp", app_names=[arch]), 6, seed=1,
                  slo_mult=8.0)
        tel = gw.run()
        digests.append(sim.run_digest())
        assert tel.summary()["profile_provenance"] == {arch: "measured"}
    executor.drain()
    assert digests[0] == digests[1]
