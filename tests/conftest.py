import jax
import pytest

# Smoke tests and kernels must see the single real CPU device; ONLY the
# dry-run (repro.launch.dryrun) sets xla_force_host_platform_device_count.
jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running property/replay tests (deselect with "
        "-m 'not slow' for the fast CI job)")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
