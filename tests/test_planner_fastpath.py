"""Differential/property harness for the PR-5 planner fast path.

Locks the three optimizations — the memoized dominator-budget plan
cache, the vectorized ESG_1Q engine and the event-sparse emulator core
— against the pre-optimization reference they replace:

  * **engine parity** — vectorized vs legacy ``esg_1q`` on the paper
    tables and on randomized profile tables (random penalties, random
    budgets, every budget regime): identical ``PathResult`` lists,
    bit for bit;
  * **plan-cache soundness** — ``PlanCache.lookup`` equals a fresh
    search across a budget sweep spanning the floor, exact and
    budget-free regimes, and the certified regimes actually hit;
  * **differential replay** — every serving scenario runs with the fast
    path on (cache + vectorized engine + sparse emulator, the defaults)
    vs entirely off: schedules, SLO hit rates and ``gpu_summary()``
    counters must be bit-identical — including congested/finite-HBM
    configurations where the sparse emulator provably skips futile
    retries (``sparse_skips > 0``), and memory-aware + overlapped-swap
    configurations where penalty signatures join the cache key;
  * **satellites** — streaming ``TraceReplayScenario.iter_csv``
    (generator rows, blank-row skip, ValueError naming file+line) and
    the bisect-based ``note_upper``.
"""
import pathlib

import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.core.astar import (SearchStats, _esg_1q_legacy, brute_force,
                              esg_1q)
from repro.core.plancache import PlanCache
from repro.core.profiles import (PAPER_FUNCTIONS, FunctionProfile,
                                 ProfileTable)
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.traces import SCENARIOS, TraceReplayScenario

APPS = list(PAPER_APPS)
HERE = pathlib.Path(__file__).resolve().parent
N_REQ = 24


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


def flat(results):
    return [(r.configs, r.est_time_ms, r.est_job_cost) for r in results]


# ---------------------------------------------------------------------------
# engine parity: vectorized vs legacy ESG_1Q
# ---------------------------------------------------------------------------
def test_vectorized_matches_legacy_on_paper_tables(tables):
    tbls = [tables[f] for f in
            ("super_resolution", "segmentation", "classification")]
    for g in (1e-3, 200.0, 800.0, 1500.0, 3000.0, 12000.0, 1e7):
        for k in (1, 3, 5, 8):
            assert flat(esg_1q(tbls, g, k=k)) == \
                flat(_esg_1q_legacy(tbls, g, k=k)), (g, k)


def test_vectorized_matches_legacy_with_penalties(tables):
    tbls = [tables[f] for f in ("deblur", "depth")]
    pen = [700.0, 0.0]
    for g in (500.0, 2500.0, 9000.0):
        assert flat(esg_1q(tbls, g, k=5, penalties_ms=pen)) == \
            flat(_esg_1q_legacy(tbls, g, k=5, penalties_ms=pen))
    with pytest.raises(ValueError):
        esg_1q(tbls, 1000.0, penalties_ms=[1.0])
    with pytest.raises(ValueError):
        _esg_1q_legacy(tbls, 1000.0, penalties_ms=[1.0])


def test_vectorized_matches_legacy_randomized():
    rng = np.random.default_rng(7)
    for trial in range(150):
        n = int(rng.integers(1, 4))
        tbls = []
        for s in range(n):
            fn = FunctionProfile(f"r{trial}_{s}",
                                 float(rng.uniform(20, 2000)), 1000.0, 1.0,
                                 cpu_frac=float(rng.uniform(0.05, 0.5)))
            tbls.append(ProfileTable.build(
                fn, batches=(1, 2, 4, 8), vcpus=(1, 2), vgpus=(1, 2, 4)))
        if rng.random() < 0.4:
            tbls = [t.pareto() for t in tbls]
        if rng.random() < 0.4:
            tbls[0] = tbls[0].restrict_batch(int(rng.integers(1, 8)))
        pen = [float(rng.uniform(0, 300)) for _ in tbls] \
            if rng.random() < 0.5 else None
        lo = sum(float(t.times[0]) for t in tbls)
        g = float(rng.uniform(0.2 * lo, 10 * lo))
        k = int(rng.integers(1, 7))
        a = esg_1q(tbls, g, k=k, penalties_ms=pen)
        b = _esg_1q_legacy(tbls, g, k=k, penalties_ms=pen)
        assert flat(a) == flat(b), (trial, g, k)
        # brute-force oracle only applies when the budget is feasible
        # (the search returns a best-effort fastest path otherwise)
        bf = brute_force(tbls, g, k=k, penalties_ms=pen)
        if bf:
            assert flat(a) == flat(bf), (trial, g, k)


def test_vectorized_stats_still_prune(tables):
    tbls = [tables[f] for f in ("super_resolution", "segmentation")]
    stats = SearchStats()
    esg_1q(tbls, 2000.0, k=5, stats=stats)
    n_total = len(tbls[0].configs) * len(tbls[1].configs)
    assert stats.nodes_expanded > 0
    assert stats.nodes_pushed < n_total
    assert stats.pruned_time + stats.pruned_cost > 0


def test_with_penalty_array_form_matches_table_form(tables):
    t = tables["segmentation"]
    pt = t.with_penalty(123.4)
    ts, cs = t.priced_arrays(123.4)
    assert np.array_equal(pt.times, ts) and np.array_equal(pt.job_costs, cs)
    assert t.priced_arrays(0.0) == (t.times, t.job_costs)
    assert t.with_penalty(0.0) is t


def test_batch_lattice_buckets_are_lossless(tables):
    t = tables["deblur"]
    lat = t.batch_lattice
    for n in (1, 2, 3, 5, 8, 11, 129):
        i = np.searchsorted(lat, n, side="right")
        bucket = lat[i - 1] if i else 0
        a, b = t.restrict_batch(n), t.restrict_batch(bucket)
        assert a.configs == b.configs


# ---------------------------------------------------------------------------
# plan cache: soundness across the three budget regimes
# ---------------------------------------------------------------------------
def test_plan_cache_equals_fresh_search_across_budgets(tables):
    tbls = [tables[f] for f in ("super_resolution", "segmentation")]
    cache = PlanCache(k=5)
    t_min = sum(float(t.times[0]) for t in tbls)
    budgets = [0.5 * t_min, t_min, t_min * 1.01, t_min * 1.5, t_min * 2,
               t_min * 5, t_min * 50, 1e9]
    for g in budgets + budgets:          # second lap: pure cache hits
        assert flat(cache.lookup("key", g, tbls)) == \
            flat(esg_1q(tbls, g, k=5)), g
    s = cache.stats
    assert s.builds == 1
    assert s.hits_floor > 0 and s.hits_budget_free > 0 and s.hits_exact > 0
    assert s.hits + s.misses == 2 * len(budgets)


def test_plan_cache_penalties_separate_entries(tables):
    tbls = [tables[f] for f in ("deblur",)]
    cache = PlanCache(k=3)
    a = cache.lookup(("k", None), 1e6, tbls, None)
    b = cache.lookup(("k", (500.0,)), 1e6, tbls, [500.0])
    assert flat(b) == flat(esg_1q(tbls, 1e6, k=3, penalties_ms=[500.0]))
    assert flat(a) != flat(b)            # the penalty re-prices the paths
    assert cache.stats.builds == 2


def test_plan_cache_budget_free_token(tables):
    tbls = [tables[f] for f in ("classification",)]
    cache = PlanCache(k=5)
    assert cache.budget_free_token("k", 1e9) is None     # entry not built
    cache.lookup("k", 1e9, tbls)
    entry = cache.peek("k")
    assert cache.budget_free_token("k", entry.t_max * 1.01) is not None
    assert cache.budget_free_token("k", entry.t_max) is None
    assert cache.budget_free_token("k", 0.5 * entry.t_min) is None


def test_plan_cache_eviction_bounds_memory(tables):
    tbls = [tables["depth"]]
    cache = PlanCache(k=2, max_entries=4, max_exact=8)
    for i in range(10):
        cache.lookup(f"k{i}", 1e9, tbls)
    assert len(cache._entries) <= 4 and cache.stats.evictions >= 6
    e_key = next(iter(cache._entries))
    entry = cache._entries[e_key]
    lo, hi = entry.t_min, entry.t_max
    for g in np.linspace(lo * 1.001, hi, 20):
        cache.lookup(e_key, float(g), tbls)
    assert len(entry.exact) <= 8


def test_scheduler_plan_cache_off_matches_on(tables):
    """Live plan() calls with cache on vs off, same inputs."""
    on = ESGScheduler(PAPER_APPS, tables)
    off = ESGScheduler(PAPER_APPS, tables, plan_cache=False,
                       vectorized=False)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, on, seed=0)

    class J:
        def __init__(self, arrival, slo):
            self.inst = type("I", (), {"arrival_ms": arrival,
                                       "slo_ms": slo})()
    rng = np.random.default_rng(3)
    for app in PAPER_APPS.values():
        for stage in app.stages:
            for _ in range(6):
                now = float(rng.uniform(0, 5000))
                jobs = [J(now - float(rng.uniform(0, 800)),
                          float(rng.uniform(500, 20000)))
                        for _ in range(int(rng.integers(1, 6)))]
                assert on.plan(sim, app, stage, jobs, now) == \
                    off.plan(sim, app, stage, jobs, now), (app.name, stage)
    assert on.cache.stats.hits > 0


# ---------------------------------------------------------------------------
# differential replay: fast path vs full-scan/legacy, every scenario
# ---------------------------------------------------------------------------
def _run(tables, scenario, n=N_REQ, seed=0, slo_mult=1.0, fast=True,
         placement="locality", autoscaler="ewma", shed=True, **sim_kw):
    sched = ESGScheduler(PAPER_APPS, tables, placement=placement,
                         plan_cache=fast, vectorized=fast)
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS, sched,
                     seed=seed, count_overhead=False,
                     autoscaler=get_autoscaler(autoscaler),
                     sparse=fast, **sim_kw)
    gw = Gateway(sim, shed_doomed=shed)
    sc = get_scenario(scenario, app_names=APPS)
    gw.inject(sc, n, seed=seed + 1, slo_mult=slo_mult)
    tel = gw.run()
    return tel, sim


def _timeline(sim):
    tasks = [(t.start_ms, t.end_ms, t.exec_start_ms, t.invoker, t.stage,
              t.func, t.config, t.tier, t.cold, t.cost, t.quota_slices,
              t.penalty_ms, t.full_penalty_ms)
             for t in sim.tasks]
    done = [(i.uid, i.arrival_ms, i.finish_ms) for i in sim.completed]
    shed = [i.uid for i in sim.shed]
    return tasks, done, shed, sim.total_cost, sim.cold_starts, \
        sim.remote_transfers


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_fast_path_replays_bit_identically(tables, scenario):
    tel_f, sim_f = _run(tables, scenario, fast=True)
    tel_l, sim_l = _run(tables, scenario, fast=False)
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.slo_hit_rate() == sim_l.slo_hit_rate()
    assert sim_f.gpu_summary() == sim_l.gpu_summary()
    assert tel_f.summary()["slo_attainment"] == \
        tel_l.summary()["slo_attainment"]


@pytest.mark.parametrize("kw", [
    dict(hbm_per_vgpu_mb=256.0, slo_mult=0.9, n=40),
    dict(hbm_per_vgpu_mb=256.0, placement="memory", shared_weights=True,
         n=40),
    dict(hbm_per_vgpu_mb=256.0, placement="memory", shared_weights=True,
         overlap=True, prefetch=True, n=40),
    dict(autoscaler="finegrained", n=40),
], ids=["finite-hbm", "memory", "memory-overlap-pf", "finegrained"])
def test_fast_path_identical_under_memory_pressure(tables, kw):
    kw = dict(kw)
    n = kw.pop("n")
    _, sim_f = _run(tables, "mmpp", n=n, fast=True, **kw)
    _, sim_l = _run(tables, "mmpp", n=n, fast=False, **kw)
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.gpu_summary() == sim_l.gpu_summary()


def test_fast_path_identical_on_large_fleet(tables):
    """24 invokers puts predecessor-frequency ties past numpy's argsort
    stability threshold — the regime where any 'equivalent' rewrite of
    the locality order would silently diverge from the pre-PR code."""
    _, sim_f = _run(tables, "skewed-mix", n=60, fast=True, n_invokers=24)
    _, sim_l = _run(tables, "skewed-mix", n=60, fast=False, n_invokers=24)
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.gpu_summary() == sim_l.gpu_summary()


def test_sparse_emulator_skips_futile_retries_identically(tables):
    """Capacity squeeze + wide slack: the sparse emulator must actually
    exercise the futile-retry proof (skips > 0, strictly fewer plan
    calls) while replaying the full-scan schedule bit for bit."""
    kw = dict(n=100, slo_mult=8.0, shed=False, n_invokers=2)
    _, sim_f = _run(tables, "flash-crowd", fast=True, **kw)
    _, sim_l = _run(tables, "flash-crowd", fast=False, **kw)
    assert sim_f.sparse_skips > 0
    assert len(sim_f.sched_overheads_ms) < len(sim_l.sched_overheads_ms)
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.gpu_summary() == sim_l.gpu_summary()
    assert sim_f.slo_hit_rate() == sim_l.slo_hit_rate()


def test_sparse_with_vertical_autoscaler_never_skips(tables):
    """A congestion hook with side effects disables the futility proof:
    every retry must run (and the replay still matches full-scan)."""
    kw = dict(n=60, slo_mult=6.0, shed=False, n_invokers=2,
              autoscaler="vertical")
    _, sim_f = _run(tables, "flash-crowd", fast=True, **kw)
    _, sim_l = _run(tables, "flash-crowd", fast=False, **kw)
    assert sim_f.sparse_skips == 0
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.gpu_summary() == sim_l.gpu_summary()


def test_sparse_keepalive_expiry_unblocks(tables):
    """A run long enough to cross keep-alive expiries (the watermark
    path) still replays identically."""
    import repro.cluster.emulator as emu
    old = emu.KEEPALIVE_MS
    emu.KEEPALIVE_MS = 2_000.0
    try:
        _, sim_f = _run(tables, "uniform-heavy", n=60, slo_mult=4.0,
                        shed=False, fast=True, n_invokers=2)
        _, sim_l = _run(tables, "uniform-heavy", n=60, slo_mult=4.0,
                        shed=False, fast=False, n_invokers=2)
    finally:
        emu.KEEPALIVE_MS = old
    assert _timeline(sim_f) == _timeline(sim_l)
    assert sim_f.gpu_summary() == sim_l.gpu_summary()


# ---------------------------------------------------------------------------
# satellites: streaming trace reader
# ---------------------------------------------------------------------------
def test_trace_replay_accepts_generator_rows():
    def gen():
        yield from ((float(t), "*") for t in (10, 30, 20))
    sc = TraceReplayScenario(rows=gen())
    assert sc.rows == [(10.0, "*"), (20.0, "*"), (30.0, "*")]
    arr = sc.arrivals(["a", "b"], 5, seed=0)
    assert [round(a.t_ms, 3) for a in arr] == \
        [round(x.t_ms, 3) for x in
         TraceReplayScenario(rows=[(10, "*"), (30, "*"), (20, "*")])
         .arrivals(["a", "b"], 5, seed=0)]


def test_iter_csv_streams_and_matches_read_csv(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("t_ms,app,extra\n10,alpha,x\n\n  , ,\n20,beta,y\n\n")
    it = TraceReplayScenario.iter_csv(str(p))
    assert next(it) == (10.0, "alpha")           # truly lazy
    assert list(it) == [(20.0, "beta")]
    assert TraceReplayScenario.read_csv(str(p)) == \
        [(10.0, "alpha"), (20.0, "beta")]


def test_iter_csv_errors_keep_naming_file_and_line(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("t_ms,app\n10,alpha\nnope,beta\n")
    with pytest.raises(ValueError, match=r"bad\.csv line 3.*t_ms"):
        list(TraceReplayScenario.iter_csv(str(p)))
    p2 = tmp_path / "miss.csv"
    p2.write_text("t_ms,app\n10,\n")
    with pytest.raises(ValueError, match=r"miss\.csv line 2"):
        TraceReplayScenario(csv_path=str(p2))
    p3 = tmp_path / "hdr.csv"
    p3.write_text("time,function\n1,a\n")
    with pytest.raises(ValueError, match="t_ms,app"):
        list(TraceReplayScenario.iter_csv(str(p3)))


def test_trace_replay_empty_csv_raises(tmp_path):
    p = tmp_path / "empty.csv"
    p.write_text("t_ms,app\n\n")
    with pytest.raises(ValueError, match="empty trace"):
        TraceReplayScenario(csv_path=str(p))
