"""Per-arch smoke tests (reduced configs, CPU): one forward/train step,
shape + finiteness asserts; prefill->decode == full forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, ShapeSpec, get_config, reduced
from repro.models.model import RunOptions, get_model

OPTS = RunOptions(attn_chunk=16, remat="none",
                  param_dtype=jnp.float32, act_dtype=jnp.float32)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    cfg = reduced(get_config(arch))
    m = get_model(cfg, OPTS)
    params = m.init(key)
    batch = m.dummy_inputs(ShapeSpec("t", 64, 2, "train"), key)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert jnp.isfinite(loss)
    assert loss.shape == ()
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch, key):
    cfg = reduced(get_config(arch))
    m = get_model(cfg, OPTS)
    params = m.init(key)
    batch = m.dummy_inputs(ShapeSpec("t", 64, 2, "prefill"), key)
    logits, cache = m.prefill(params, batch, max_len=80)
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = reduced(get_config(arch))
    m = get_model(cfg, OPTS)
    params = m.init(key)
    S, extra = 48, 3
    tokens = jax.random.randint(key, (2, S + extra), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.frontend == "vit" and cfg.n_prefix:
        batch["prefix_embeds"] = jax.random.normal(
            key, (2, cfg.n_prefix, cfg.d_model), jnp.float32)
    full, _ = m.forward(params, batch)
    off = cfg.n_prefix if (cfg.frontend == "vit" and cfg.n_prefix) else 0
    pb = dict(batch)
    pb["tokens"] = tokens[:, :S]
    lg, cache = m.prefill(params, pb, max_len=S + off + extra + 1)
    scale = float(jnp.abs(full).max())
    assert jnp.abs(lg - full[:, off + S - 1]).max() < 1e-3 * scale
    for i in range(extra):
        lg, cache = m.decode(params, cache, tokens[:, S + i:S + i + 1])
        assert jnp.abs(lg - full[:, off + S + i]).max() < 1e-3 * scale


def test_moe_routing_flop_exact():
    """Capacity+gather MoE computes at most cf x active-expert slots."""
    from repro.models import moe
    cfg = reduced(get_config("mixtral_8x22b"))
    key = jax.random.PRNGKey(1)
    r, t, d, e, f = 2, 32, cfg.d_model, cfg.n_experts, cfg.d_ff
    x = jax.random.normal(key, (r, t, d), jnp.float32)
    router = jax.random.normal(key, (d, e), jnp.float32) * 0.1
    w1 = jax.random.normal(key, (e, d, f), jnp.float32) * 0.05
    w2 = jax.random.normal(key, (e, d, f), jnp.float32) * 0.05
    w3 = jax.random.normal(key, (e, f, d), jnp.float32) * 0.05
    out, aux = moe.moe_ffn(x, router, w1, w2, w3, n_experts=e,
                           top_k=2, capacity_factor=4.0)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    assert jnp.isfinite(aux)
    cap = moe.capacity(t, e, 2, 4.0)
    assert cap <= t * 2


def test_moe_matches_dense_mixture():
    """With capacity ample, gather-MoE == explicit dense top-k mixture."""
    from repro.models import moe
    key = jax.random.PRNGKey(2)
    r, t, d, e, f, k = 1, 16, 8, 4, 12, 2
    x = jax.random.normal(key, (r, t, d), jnp.float32)
    ks = jax.random.split(key, 4)
    router = jax.random.normal(ks[0], (d, e), jnp.float32)
    w1 = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.3
    w2 = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.3
    w3 = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.3
    out, _ = moe.moe_ffn(x, router, w1, w2, w3, n_experts=e, top_k=k,
                         capacity_factor=e * 2.0)
    # dense reference
    probs = jax.nn.softmax(x @ router, axis=-1)
    gv, gi = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    h = jnp.einsum("rtd,edf->rtef", x, w1) * jax.nn.silu(
        jnp.einsum("rtd,edf->rtef", x, w2))
    ye = jnp.einsum("rtef,efd->rted", h, w3)
    mask = jax.nn.one_hot(gi, e).sum(-2) * 0  # build combine weights
    comb = jnp.zeros((r, t, e))
    for j in range(k):
        comb = comb + jax.nn.one_hot(gi[..., j], e) * gv[..., j:j + 1]
    ref = jnp.einsum("rted,rte->rtd", ye, comb)
    assert jnp.abs(out - ref).max() < 1e-4 * float(jnp.abs(ref).max() + 1)
