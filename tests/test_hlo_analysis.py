"""HLO analyzers: exact dot-FLOP counting through nested while loops, and
the collective parser's wire-byte model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import collectives, hlo_analysis


def test_flops_exact_through_scan():
    L, B, D = 7, 8, 64

    def loss(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        x, _ = jax.lax.scan(body, x, w)
        return (x ** 2).sum()

    def step(x, w):
        return jax.value_and_grad(loss, argnums=1)(x, w)

    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    comp = jax.jit(step).lower(xs, ws).compile()
    res = hlo_analysis.analyze(comp.as_text())
    true = 3 * L * 2 * B * D * D            # fwd + dx + dw
    assert res["flops"] == pytest.approx(true, rel=0.02)


def test_nested_scan_multiplies():
    L_out, L_in, D = 3, 5, 32

    def f(x, w):
        def outer(c, wo):
            def inner(ci, _):
                return ci @ wo, None
            c, _ = jax.lax.scan(inner, c, None, length=L_in)
            return c, None
        x, _ = jax.lax.scan(outer, x, w)
        return x.sum()

    xs = jax.ShapeDtypeStruct((4, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L_out, D, D), jnp.float32)
    comp = jax.jit(f).lower(xs, ws).compile()
    res = hlo_analysis.analyze(comp.as_text())
    true = L_out * L_in * 2 * 4 * D * D
    assert res["flops"] == pytest.approx(true, rel=0.02)


def test_collective_wire_bytes_model():
    txt = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[8,16]) -> f32[] {
  %p = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%p), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %ag = f32[32,16]{1,0} all-gather(%ar), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %r = f32[] reduce(%ag)
}
"""
    out = collectives.parse_collectives(txt, 8)
    ar_bytes = 8 * 16 * 4
    ag_bytes = 32 * 16 * 4
    expected = 2 * (3 / 4) * ar_bytes + (3 / 4) * ag_bytes
    assert out["total_wire_bytes"] == pytest.approx(expected)
    assert out["n_collectives"] == 2


def test_collectives_inside_while_multiplied():
    import re

    def f(x):
        def body(c, _):
            return c * jax.lax.psum(c.sum(), "i"), None
        c, _ = jax.lax.scan(body, x, None, length=6)
        return c.sum()

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # shard_map over 1 device still emits the collective structure
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(devs[:1]), ("i",))
    from repro.models.layers import shard_map
    fm = jax.jit(shard_map(f, mesh=mesh, in_specs=P("i"), out_specs=P()))
    comp = fm.lower(jax.ShapeDtypeStruct((4, 8), jnp.float32)).compile()
    out = collectives.parse_collectives(comp.as_text(), 1)
    # the in-loop psum must appear with count 6 (or be optimised out on 1
    # device — accept either, but if present it must carry the multiplier)
    counts = [c[3] for c in out["items"]]
    if counts:
        assert max(counts) >= 6
