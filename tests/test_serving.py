"""Serving-layer integration: zoo profiles, ESG over LM pipelines, and the
real-compute single-host serve loop."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.tpu_profiles import ServingSpec, TPUFunctionProfile, zoo_tables
from repro.configs.registry import get_config
from repro.core.profiles import Config


def test_tpu_profile_monotonicity():
    fp = TPUFunctionProfile(get_config("internlm2_20b"), overhead=1.5)
    t1 = fp.exec_ms(Config(1, 1, 1))
    t_more_chips = fp.exec_ms(Config(1, 1, 8))
    t_more_batch = fp.exec_ms(Config(8, 1, 1))
    assert t_more_chips < t1          # chips speed a single inference up
    assert t_more_batch > t1          # batches take longer in total
    # ... but less per job:
    assert t_more_batch / 8 < t1


def test_zoo_tables_all_archs():
    tables = zoo_tables()
    assert len(tables) == 10
    for name, t in tables.items():
        assert t.min_time > 0
        assert np.all(np.diff(t.times) >= 0)       # sorted by latency


def test_emulated_zoo_serving_esg_hits():
    from repro.launch.serve import emulate
    s = emulate(setting="relaxed-heavy", n=60, log=lambda *_: None)
    assert s["completed"] == 60
    assert s["slo_hit_rate"] > 0.5


def test_real_serving_loop_smoke():
    from repro.launch.serve import serve_real
    out = serve_real(arch="internlm2_1_8b", n_requests=6,
                     batches=(1, 2), quotas=(1.0,), gen_len=2,
                     prompt_len=16, reps=1, log=lambda *_: None)
    assert out["n_requests"] == 6
    assert out["executor"]["executed"] > 0
    # the CI-asserted invariant: zero recompiles after warmup
    assert out["executor"]["post_warmup_hit_rate"] == 1.0
    assert out["telemetry"]["profile_provenance"] == {
        "internlm2_1_8b": "measured"}
