"""Cluster-emulator invariants: every job scheduled exactly once, resource
caps never violated, accounting consistent — for all five schedulers."""
import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.cluster.workload import generate
from repro.core.profiles import PAPER_FUNCTIONS, ProfileTable
from repro.core.workflows import PAPER_APPS
from repro.core.scheduler import ESGScheduler
from repro.core.baselines.infless import INFlessScheduler
from repro.core.baselines.fastgshare import FaSTGShareScheduler
from repro.core.baselines.orion import OrionScheduler
from repro.core.baselines.aquatope import AquatopeScheduler


@pytest.fixture(scope="module")
def tables():
    return {n: ProfileTable.build(p) for n, p in PAPER_FUNCTIONS.items()}


SCHEDS = [ESGScheduler, INFlessScheduler, FaSTGShareScheduler,
          OrionScheduler, AquatopeScheduler]


@pytest.mark.parametrize("sched_cls", SCHEDS, ids=lambda c: c.name)
def test_all_jobs_complete_once(tables, sched_cls):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     sched_cls(PAPER_APPS, tables), seed=0)
    n = 60
    generate(sim, "moderate-normal", n, PAPER_FUNCTIONS, seed=1)
    sim.run()
    assert len(sim.completed) == n
    # each instance's every stage ran exactly once
    stage_runs = {}
    for t in sim.tasks:
        for j in t.jobs:
            key = (j.inst.uid, t.stage)
            stage_runs[key] = stage_runs.get(key, 0) + 1
    assert all(v == 1 for v in stage_runs.values())
    for inst in sim.completed:
        assert len([1 for (uid, _s) in stage_runs if uid == inst.uid]) == \
            len(inst.app.stages)


def test_resource_caps_never_violated(tables):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=0)
    generate(sim, "relaxed-heavy", 80, PAPER_FUNCTIONS, seed=2)
    sim.run()
    # replay the task intervals; per-invoker concurrent usage <= capacity
    events = []
    for t in sim.tasks:
        events.append((t.start_ms, t.config.vcpu, t.config.vgpu, t.invoker, 1))
        events.append((t.end_ms, t.config.vcpu, t.config.vgpu, t.invoker, -1))
    events.sort()
    use = {i: [0, 0] for i in range(len(sim.invokers))}
    for _, c, g, inv, sgn in events:
        use[inv][0] += sgn * c
        use[inv][1] += sgn * g
        assert use[inv][0] <= 16 and use[inv][1] <= 8
        assert use[inv][0] >= 0 and use[inv][1] >= 0


def test_cost_accounting_consistent(tables):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=0)
    generate(sim, "strict-light", 40, PAPER_FUNCTIONS, seed=3)
    sim.run()
    assert sim.total_cost == pytest.approx(sum(t.cost for t in sim.tasks))
    assert sim.total_cost > 0


def test_batching_respects_queue(tables):
    sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                     ESGScheduler(PAPER_APPS, tables), seed=0)
    generate(sim, "relaxed-heavy", 60, PAPER_FUNCTIONS, seed=4)
    sim.run()
    assert all(1 <= t.config.batch <= 128 for t in sim.tasks)


def test_esg_beats_baselines_moderate(tables):
    """The paper's headline: highest hit rate at the lowest cost."""
    results = {}
    for cls in SCHEDS:
        sim = ClusterSim(PAPER_APPS, tables, PAPER_FUNCTIONS,
                         cls(PAPER_APPS, tables), seed=0)
        generate(sim, "moderate-normal", 120, PAPER_FUNCTIONS, seed=5)
        sim.run()
        results[cls.name] = sim.summary()
    esg = results["ESG"]
    for name, r in results.items():
        if name == "ESG":
            continue
        assert esg["slo_hit_rate"] >= r["slo_hit_rate"] - 0.05, \
            f"ESG hit {esg['slo_hit_rate']} < {name} {r['slo_hit_rate']}"
    assert esg["slo_hit_rate"] > 0.8
