"""Distribution layer on a 1x1 test mesh: the step builders compile AND
produce the same values as the unsharded model paths (exercises the
shard_map flash-decode and the constraint plumbing end-to-end)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SHAPES, ShapeSpec, get_config, reduced
from repro.launch import shardings as sh
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_prefill_step, build_serve_step, \
    build_train_step
from repro.models.model import RunOptions, get_model

OPTS = RunOptions(attn_chunk=16, remat="none",
                  param_dtype=jnp.float32, act_dtype=jnp.float32)
SMALL = ShapeSpec("small_decode", 64, 2, "decode")
SMALL_TRAIN = ShapeSpec("small_train", 32, 2, "train")


@pytest.mark.parametrize("arch", ["internlm2_20b", "mixtral_8x22b",
                                  "llama4_maverick_400b_a17b"])
def test_serve_step_matches_model_decode(arch):
    cfg = reduced(get_config(arch))
    mesh = make_test_mesh()
    fn, in_sh, out_sh, specs, donate = build_serve_step(cfg, SMALL, mesh, OPTS)
    model = get_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(SMALL.global_batch, SMALL.seq_len)
    cache["t"] = jnp.asarray(10, jnp.int32)
    tok = jnp.ones((SMALL.global_batch, 1), jnp.int32)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        logits_sharded, _ = jitted(params, jax.tree.map(jnp.copy, cache), tok)
    logits_plain, _ = model.decode(params, cache, tok)
    np.testing.assert_allclose(np.asarray(logits_sharded),
                               np.asarray(logits_plain), atol=2e-4, rtol=2e-4)


def test_train_step_runs_on_mesh():
    cfg = reduced(get_config("internlm2_1_8b"))
    mesh = make_test_mesh()
    fn, in_sh, out_sh, specs, donate = build_train_step(
        cfg, SMALL_TRAIN, mesh, OPTS)
    model = get_model(cfg, OPTS)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import adamw
    opt = adamw.init(params)
    batch = model.dummy_inputs(SMALL_TRAIN, jax.random.PRNGKey(1))
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        p2, o2, m = jitted(params, opt, batch)
    assert jnp.isfinite(m["loss"])


def test_prefill_step_compiles_abstract():
    cfg = reduced(get_config("hymba_1_5b"))
    mesh = make_test_mesh()
    shape = ShapeSpec("small_prefill", 64, 2, "prefill")
    fn, in_sh, out_sh, specs, donate = build_prefill_step(
        cfg, shape, mesh, OPTS)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*specs).compile()
    assert compiled.cost_analysis() is not None


def test_sanitize_pspec_drops_nondivisible():
    from jax.sharding import PartitionSpec as P
    mesh = make_test_mesh((1, 1))
    # both axes size 1 -> everything divisible; now fake a size check
    spec = sh.sanitize_pspec(P("model", "data"), (32001, 1600), mesh)
    assert spec == P("model", "data")     # size-1 axes always divide


def test_tp_policy():
    cfg_small = get_config("musicgen_medium")
    cfg_big = get_config("qwen1_5_110b")
    assert not sh.tp_applies(cfg_small, SHAPES["train_4k"])
    assert sh.tp_applies(cfg_big, SHAPES["train_4k"])
    assert sh.tp_applies(cfg_small, SHAPES["decode_32k"])
    assert sh.weight_stationary_serving(get_config("internlm2_20b"))
    assert not sh.weight_stationary_serving(cfg_big)
