"""Sharded replay engine: fidelity, determinism, exact merge.

The claims under test (the same ones ``benchmarks/replay_bench.py``
commits at scale):

  * 1-shard sharded == legacy single-process emulator, bit-identical
    (schedule digests), on every planner-bench scenario — streaming
    retention, pooled tasks and lazy arrivals change no arithmetic;
  * for a fixed shard count, worker processes are pure mechanism:
    parallel == sequential, digest for digest;
  * the merge is exact: counters add, histograms fold, nothing is
    approximated twice;
  * ``LatencyHistogram.merge`` is partition-invariant at day scale;
  * the calibration reservoir in the audit log is bounded and keeps
    exact first moments.
"""
from __future__ import annotations

import gzip
import math

import numpy as np
import pytest

from repro.cluster.emulator import ClusterSim
from repro.cluster.shard import (ReplayConfig, fleet_split, make_apps,
                                 merge_results, paper_tables, run_shard,
                                 run_sharded, shard_of, shard_seed)
from repro.core.profiles import PAPER_FUNCTIONS
from repro.core.scheduler import ESGScheduler
from repro.core.workflows import PAPER_APPS
from repro.serving import Gateway, get_autoscaler, get_scenario
from repro.serving.telemetry import LatencyHistogram
from repro.serving.traces import TraceReplayScenario

SCENARIOS = ["uniform-normal", "diurnal", "mmpp", "flash-crowd",
             "azure-tail", "trace-replay"]


def _scenario_kw(name: str):
    if name == "trace-replay":
        rows = [((i + 1) * 37.5, "unknown-fn-%d" % (i % 7))
                for i in range(64)]
        return {"rows": rows, "speedup": 2.0}
    return {}


def _legacy_sim(cfg: ReplayConfig, retain: str = "full",
                stream_arrivals: bool = False):
    """The pre-sharding path: one ClusterSim over the paper apps."""
    tables = paper_tables()
    sched = ESGScheduler(dict(PAPER_APPS), tables, plan_cache=True,
                         vectorized=True)
    sim = ClusterSim(dict(PAPER_APPS), tables, PAPER_FUNCTIONS, sched,
                     n_invokers=cfg.n_invokers, noise_sigma=cfg.noise_sigma,
                     seed=cfg.seed, count_overhead=False,
                     autoscaler=get_autoscaler("ewma"), sparse=True,
                     retain=retain, track_digest=True)
    gw = Gateway(sim)
    sc = get_scenario(cfg.scenario, app_names=list(PAPER_APPS),
                      **dict(cfg.scenario_kw))
    gw.inject(sc, cfg.n, seed=cfg.seed + 1, slo_mult=cfg.slo_mult,
              stream=stream_arrivals)
    sim.run()
    gw.telemetry.collect(sim)
    return sim, gw


# ---------------------------------------------------------------------------
# fidelity: 1 shard == legacy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", SCENARIOS)
def test_one_shard_matches_legacy(name):
    cfg = ReplayConfig(scenario=name, scenario_kw=_scenario_kw(name),
                       n=300, seed=7)
    r = run_shard(cfg, 0, 1)
    sim, _ = _legacy_sim(cfg)
    assert r.digest == sim.run_digest()
    assert r.summary["completed"] == sim.summary()["completed"]
    assert r.summary["shed"] == sim.summary()["shed"]


def test_stream_retention_digest_matches_full():
    cfg = ReplayConfig(scenario="azure-tail", n=400, seed=11)
    full, _ = _legacy_sim(cfg, retain="full")
    stream, gw = _legacy_sim(cfg, retain="stream")
    assert full.run_digest() == stream.run_digest()
    fs, ss = full.summary(), stream.summary()
    assert fs["completed"] == ss["completed"]
    assert fs["shed"] == ss["shed"]
    assert fs["total_cost"] == pytest.approx(ss["total_cost"], rel=0, abs=0)
    assert fs["slo_hit_rate"] == pytest.approx(ss["slo_hit_rate"])
    assert fs["mean_latency_ms"] == pytest.approx(ss["mean_latency_ms"])
    # stream mode keeps O(1) state: nothing retained, pools populated
    assert stream.tasks == [] and stream.completed == []
    assert len(stream._task_pool) > 0


def test_lazy_arrival_stream_matches_preinjected():
    cfg = ReplayConfig(scenario="mmpp", n=400, seed=13)
    pre, _ = _legacy_sim(cfg, stream_arrivals=False)
    lazy, _ = _legacy_sim(cfg, stream_arrivals=True)
    assert pre.run_digest() == lazy.run_digest()


# ---------------------------------------------------------------------------
# workers are mechanism: parallel == sequential
# ---------------------------------------------------------------------------
def test_parallel_equals_sequential():
    cfg = ReplayConfig(scenario="azure-tail", n=1500, n_apps=12, seed=5)
    seq = run_sharded(cfg, 3, workers=1)
    par = run_sharded(cfg, 3, workers=3)
    assert seq["digest"] == par["digest"]
    for a, b in zip(seq["per_shard"], par["per_shard"]):
        assert a["digest"] == b["digest"]
        assert a["completed"] == b["completed"]
    assert seq["completed"] == par["completed"]
    assert seq["slo_attainment"] == pytest.approx(par["slo_attainment"],
                                                  rel=0, abs=0)
    assert seq["total_cost"] == pytest.approx(par["total_cost"],
                                              rel=0, abs=0)


def test_merge_is_exact():
    cfg = ReplayConfig(scenario="azure-tail", n=1200, n_apps=8, seed=9)
    results = [run_shard(cfg, i, 2) for i in range(2)]
    merged = merge_results(results)
    # the union of per-shard arrival slices is the whole trace
    assert merged["arrivals"] == cfg.n
    assert merged["completed"] + merged["shed"] == cfg.n
    assert merged["completed"] == sum(r.summary["completed"]
                                      for r in results)
    assert merged["total_cost"] == pytest.approx(
        sum(r.summary["total_cost"] for r in results), rel=0, abs=1e-9)
    assert merged["cold_starts"] == sum(r.summary["cold_starts"]
                                        for r in results)
    # merged e2e histogram holds every completion exactly once
    tel_n = sum(r.telemetry.e2e.n for r in results)
    assert tel_n == merged["completed"]


# ---------------------------------------------------------------------------
# partitioning machinery
# ---------------------------------------------------------------------------
def test_shard_partition_is_disjoint_and_total():
    apps = make_apps(37)
    assert len(apps) == 37
    for n_shards in (2, 3, 5):
        owned = [set() for _ in range(n_shards)]
        for a in apps:
            owned[shard_of(a, n_shards)].add(a)
        assert set().union(*owned) == set(apps)
        assert sum(len(o) for o in owned) == len(apps)
        assert fleet_split(16, n_shards) and \
            sum(fleet_split(16, n_shards)) == 16


def test_fleet_split_rejects_empty_shards():
    with pytest.raises(ValueError, match="empty shard fleets"):
        fleet_split(4, 8)


def test_shard_seed_identity_at_one_shard():
    assert shard_seed(42, 0, 1) == 42
    assert shard_seed(42, 0, 2) != shard_seed(42, 1, 2)


def test_make_apps_none_is_paper_apps():
    assert make_apps(None) == dict(PAPER_APPS)
    clones = make_apps(8)
    # clones share function suffixes with their prototypes (plan-cache
    # shape sharing depends on it)
    protos = list(PAPER_APPS.values())
    for k, (name, wf) in enumerate(clones.items()):
        proto = protos[k % len(protos)]
        assert [wf.func_of[s] for s in wf.stages] == \
            [proto.func_of[s] for s in proto.stages]


# ---------------------------------------------------------------------------
# histogram merge: partition-invariant at day scale
# ---------------------------------------------------------------------------
def test_histogram_merge_random_partition_day_scale():
    rng = np.random.default_rng(0)
    values = rng.lognormal(mean=5.0, sigma=1.2, size=1_000_000)
    whole = LatencyHistogram()
    whole.record_many(values)
    parts = [LatencyHistogram() for _ in range(8)]
    assign = rng.integers(0, 8, size=values.size)
    for i, h in enumerate(parts):
        h.record_many(values[assign == i])
    merged = LatencyHistogram()
    for h in parts:
        merged.merge(h)
    assert merged.n == whole.n == values.size
    assert np.array_equal(merged.counts, whole.counts)
    assert merged.total == pytest.approx(whole.total, rel=1e-9)
    assert merged.max_ms == whole.max_ms
    for q in (50, 95, 99):
        assert merged.percentile(q) == whole.percentile(q)


def test_record_many_matches_record_loop():
    vals = [0.0, 1.0, 3.7, 99.9, 1e6, 5.0, 5.0]
    a, b = LatencyHistogram(), LatencyHistogram()
    a.record_many(np.asarray(vals))
    for v in vals:
        b.record(v)
    assert np.array_equal(a.counts, b.counts)
    assert a.n == b.n and a.total == pytest.approx(b.total)
    assert a.max_ms == b.max_ms


# ---------------------------------------------------------------------------
# audit-log calibration reservoir: bounded, exact first moments
# ---------------------------------------------------------------------------
def test_audit_reservoir_bounded_with_exact_moments():
    from repro.obs.audit import CAL_RESERVOIR_CAP, _ErrAcc
    acc = _ErrAcc()
    rng = np.random.default_rng(1)
    errs = rng.normal(0.0, 0.3, size=100_000)
    for e in errs:
        acc.add(float(e))
    assert acc.n == errs.size
    assert len(acc.samples) <= CAL_RESERVOIR_CAP
    assert acc.sum_err == pytest.approx(float(errs.sum()), rel=1e-9)
    assert acc.sum_abs == pytest.approx(float(np.abs(errs).sum()),
                                        rel=1e-9)
    # deterministic: same inputs, same retained reservoir
    acc2 = _ErrAcc()
    for e in errs:
        acc2.add(float(e))
    assert acc.samples == acc2.samples


# ---------------------------------------------------------------------------
# presorted trace streaming
# ---------------------------------------------------------------------------
def _write_trace(path, rows, compress=False):
    opener = gzip.open if compress else open
    with opener(path, "wt", newline="") as f:
        f.write("t_ms,app\n")
        for t, a in rows:
            f.write(f"{t},{a}\n")


@pytest.mark.parametrize("compress", [False, True])
def test_presorted_streaming_matches_materialized(tmp_path, compress):
    rows = [(i * 11.0, f"fn{i % 5}") for i in range(200)]
    p = tmp_path / ("t.csv.gz" if compress else "t.csv")
    _write_trace(str(p), rows, compress)
    apps = list(PAPER_APPS)
    mat = TraceReplayScenario(csv_path=str(p)).arrivals(apps, 450, seed=0)
    streamed = list(TraceReplayScenario(csv_path=str(p), presorted=True)
                    .iter_arrivals(apps, 450, seed=0))
    assert [(a.t_ms, a.app, a.uid) for a in mat] == \
        [(a.t_ms, a.app, a.uid) for a in streamed]


def test_presorted_rejects_unsorted_trace(tmp_path):
    p = tmp_path / "bad.csv"
    _write_trace(str(p), [(100.0, "a"), (50.0, "b")])
    sc = TraceReplayScenario(csv_path=str(p), presorted=True)
    with pytest.raises(ValueError, match="not time-sorted"):
        list(sc.iter_arrivals(list(PAPER_APPS), 2, seed=0))


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
def test_stream_retention_rejects_recorder():
    from repro.obs import Recorder
    tables = paper_tables()
    sched = ESGScheduler(dict(PAPER_APPS), tables)
    with pytest.raises(ValueError, match="stream"):
        ClusterSim(dict(PAPER_APPS), tables, PAPER_FUNCTIONS, sched,
                   retain="stream", recorder=Recorder())


def test_arrival_stream_rejects_double_attach():
    tables = paper_tables()
    sched = ESGScheduler(dict(PAPER_APPS), tables)
    sim = ClusterSim(dict(PAPER_APPS), tables, PAPER_FUNCTIONS, sched)
    app = next(iter(PAPER_APPS))
    sim.add_arrival_stream(iter([(app, 1.0, 1e4, 0)]), 4)
    with pytest.raises(ValueError):
        sim.add_arrival_stream(iter([(app, 2.0, 1e4, 1)]), 4)


def test_record_requires_full_retention():
    cfg = ReplayConfig(scenario="azure-tail", n=10, record=True)
    with pytest.raises(ValueError, match="retain='full'"):
        run_shard(cfg, 0, 1)
